"""Analytic FLOP/byte models per (arch x shape) cell.

Why analytic: XLA's HloCostAnalysis counts every while/scan body ONCE, and
this framework is scan-over-layers with flash-attention scans inside the
layer body -- raw ``cost_analysis`` under-counts by orders of magnitude.  The
roofline therefore uses closed-form per-layer math (the same formulas MFU
accounting uses everywhere), with the dry-run's compiled HLO supplying what
analysis cannot: the collective schedule (op types, counts, bytes) and the
per-device memory picture.  Raw HLO numbers are reported alongside for
reference; collectives inside the layer loop are multiplied by the trip
count (see launch/dryrun.py::collective_bytes).

Conventions:
  MODEL_FLOPS  = 6 * N_active * tokens (train), 2 * N_active * tokens
                 (prefill), 2 * N_active * batch (decode per token)
  attention    = 4 * B * S^2 * H * Dh per layer fwd (x0.5 causal),
                 x3 for train (fwd + recompute-free bwd convention)
  HLO_FLOPS    = MODEL_FLOPS * (1 + remat_overhead): the scanned train step
                 rematerializes each layer once in the backward pass, so the
                 compiled compute is ~(8/6) x MODEL_FLOPS for train.
"""
from __future__ import annotations

from typing import Dict

from repro.configs.base import ArchConfig, ShapeSet

BF16 = 2
F32 = 4

# TPU v5e-class hardware constants (assignment-specified)
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
LINK_BW = 50e9               # B/s / link (ICI)


def _attn_layers(cfg: ArchConfig) -> int:
    if cfg.family in ("dense", "vlm", "moe"):
        return cfg.n_layers
    if cfg.family == "hybrid":
        return cfg.n_layers // cfg.attn_every
    if cfg.family == "encdec":
        return cfg.n_enc_layers + 2 * cfg.n_layers   # self + cross
    return 0


def _head_dim(cfg: ArchConfig) -> int:
    return cfg.head_dim() if cfg.n_heads else 0


def model_flops(cfg: ArchConfig, shape: ShapeSet) -> Dict[str, float]:
    """Global FLOPs for one step of this cell."""
    b, s = shape.global_batch, shape.seq_len
    n_act = cfg.active_param_count()
    h, dh, la = cfg.n_heads, _head_dim(cfg), _attn_layers(cfg)
    if shape.kind == "train":
        tokens = b * s
        matmul = 6.0 * n_act * tokens
        attn = 3.0 * la * 4.0 * b * s * s * h * dh * 0.5
    elif shape.kind == "prefill":
        tokens = b * s
        matmul = 2.0 * n_act * tokens
        attn = la * 4.0 * b * s * s * h * dh * 0.5
    else:  # decode: one token against an S-long cache
        matmul = 2.0 * n_act * b
        attn = la * 4.0 * b * s * h * dh
    # SSD flops (chunked scan): ~ 2*S*(2*d_inner*N + chunk*d_inner) per layer
    ssd = 0.0
    if cfg.family in ("ssm", "hybrid"):
        di, n = cfg.d_inner, cfg.ssm_state
        toks = b * (s if shape.kind != "decode" else 1)
        per_tok = 2 * di * n * 2 + 2 * di * cfg.ssm_chunk
        mult = 3.0 if shape.kind == "train" else 1.0
        ssd = mult * cfg.n_layers * toks * per_tok
    total = matmul + attn + ssd
    # the layer scan is rematerialized in training: one extra forward
    hlo = total * (8.0 / 6.0) if shape.kind == "train" else total
    return {"model_flops": total, "hlo_flops_est": hlo,
            "matmul_flops": matmul, "attn_flops": attn}


def kv_cache_bytes(cfg: ArchConfig, shape: ShapeSet) -> float:
    b, s = shape.global_batch, shape.seq_len
    hk, dh = cfg.n_kv_heads, _head_dim(cfg)
    if cfg.family in ("dense", "vlm"):
        return 2.0 * cfg.n_layers * b * s * hk * dh * BF16
    if cfg.family == "moe":
        return 2.0 * cfg.n_layers * b * s * hk * dh * BF16
    if cfg.family == "hybrid":
        la = cfg.n_layers // cfg.attn_every
        ssm = cfg.n_layers * b * cfg.ssm_heads * cfg.ssm_headdim \
            * cfg.ssm_state * F32
        return 2.0 * la * b * s * hk * dh * BF16 + ssm
    if cfg.family == "ssm":
        return cfg.n_layers * b * cfg.ssm_heads * cfg.ssm_headdim \
            * cfg.ssm_state * F32
    if cfg.family == "encdec":
        return 4.0 * cfg.n_layers * b * s * hk * dh * BF16   # self + cross
    return 0.0


def hbm_bytes(cfg: ArchConfig, shape: ShapeSet) -> float:
    """Global HBM traffic for one step (the memory-roofline numerator)."""
    n = cfg.param_count()
    b, s = shape.global_batch, shape.seq_len
    d = cfg.d_model
    if shape.kind == "train":
        weights = n * BF16 * 3           # read fwd + read bwd(remat) + write
        opt = n * F32 * 2 * 2            # m, v read+write
        grads = n * F32 * 2
        acts = 12.0 * cfg.n_layers * b * s * d * BF16
        return weights + opt + grads + acts
    if shape.kind == "prefill":
        return n * BF16 + 10.0 * cfg.n_layers * b * s * d * BF16 \
            + kv_cache_bytes(cfg, shape)
    # decode: weights (active experts only for MoE) + full KV cache read
    active_w = cfg.active_param_count() * BF16
    return active_w + kv_cache_bytes(cfg, shape) \
        + 10.0 * cfg.n_layers * b * d * BF16


def roofline_terms(cfg: ArchConfig, shape: ShapeSet, chips: int,
                   collective_bytes_per_dev: float) -> Dict[str, float]:
    f = model_flops(cfg, shape)
    compute_s = f["hlo_flops_est"] / (chips * PEAK_FLOPS)
    memory_s = hbm_bytes(cfg, shape) / (chips * HBM_BW)
    collective_s = collective_bytes_per_dev / LINK_BW
    dominant = max((("compute", compute_s), ("memory", memory_s),
                    ("collective", collective_s)), key=lambda kv: kv[1])
    total = max(compute_s, memory_s, collective_s)
    return {
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s, "dominant": dominant[0],
        "bound_s": total,
        "roofline_frac": compute_s / total if total > 0 else 0.0,
        **f,
    }
