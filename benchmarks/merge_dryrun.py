"""Merge the final optimized single-pod dry-run into dryrun.json.

The multi-pod records (compile proof for the 512-chip mesh) are kept from
the full two-mesh run; single-pod records are replaced by the re-run with
the optimized sharding (EXPERIMENTS.md §Perf) and the loop-aware collective
parser, which is what §Roofline reads.
"""
import json
import sys


def main(two_mesh="dryrun.json", single="dryrun_final_single.json",
         out="dryrun.json"):
    base = json.load(open(two_mesh))
    final_single = json.load(open(single))
    multi = [r for r in base if "multi" in r["mesh"]]
    merged = final_single + multi
    json.dump(merged, open(out, "w"), indent=1)
    ok = sum(r["status"] == "ok" for r in merged)
    sk = sum(r["status"] == "skipped" for r in merged)
    print(f"merged {len(merged)} cells -> {out} ({ok} ok, {sk} skipped)")


if __name__ == "__main__":
    main(*sys.argv[1:])
