"""Render EXPERIMENTS.md tables from artifacts (dryrun/roofline/bench CSV).

Usage: PYTHONPATH=src:. python -m benchmarks.report_experiments
Replaces the <!-- *_TABLE --> markers in EXPERIMENTS.md in place.
"""
from __future__ import annotations

import json
import os
import re
import sys


def dryrun_table(path="dryrun.json") -> str:
    rs = json.load(open(path))
    ok = [r for r in rs if r["status"] == "ok"]
    lines = ["| mesh | arch | shape | compile s | HLO flops/dev (raw) | "
             "temp GB/dev | args GB/dev | collectives AG/AR/RS/A2A/CP |",
             "|---|---|---|---|---|---|---|---|"]
    for r in sorted(ok, key=lambda x: (x["mesh"], x["arch"], x["shape"])):
        ca = r.get("cost_analysis", {})
        ma = r.get("memory_analysis", {})
        cc = r.get("collective_counts", {})
        cols = "/".join(str(cc.get(k, 0)) for k in
                        ("all-gather", "all-reduce", "reduce-scatter",
                         "all-to-all", "collective-permute"))
        mesh = "multi" if "multi" in r["mesh"] else "single"
        lines.append(
            f"| {mesh} | {r['arch']} | {r['shape']} | {r.get('compile_s','')}"
            f" | {ca.get('flops', 0):.2e} |"
            f" {ma.get('temp_size_in_bytes', 0)/1e9:.1f} |"
            f" {r.get('arg_bytes_per_device', 0)/1e9:.2f} | {cols} |")
    sk = sorted({r["arch"] + "/" + r["shape"] for r in rs
                 if r["status"] == "skipped"})
    lines.append("")
    lines.append(f"Skipped by rule ({len(sk)} arch/shape pairs x 2 meshes): "
                 + ", ".join(sk))
    return "\n".join(lines)


def roofline_tables(path="roofline.json"):
    rows = json.load(open(path))
    single = [r for r in rows if "single" in r["mesh"]]
    lines = ["| arch | shape | compute s | memory s | collective s | "
             "dominant | roofline frac | MODEL/HLO | what moves it |",
             "|---|---|---|---|---|---|---|---|---|"]
    doms = {}
    for r in sorted(single, key=lambda x: (x["arch"], x["shape"])):
        doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"{r['dominant']} | {r['roofline_frac']:.3f} | "
            f"{r['model_vs_hlo']:.2f} | {r['note'].split(':')[0]} |")
    summary = (f"Across {len(single)} single-pod cells: "
               + ", ".join(f"{v} {k}-bound" for k, v in sorted(doms.items()))
               + ". Training/prefill cells of the dense/MoE archs sit at "
               "the compute roof (fraction 1.0 = the step is FLOP-limited "
               "even with every collective exposed); decode cells are "
               "collective/memory-bound as expected at batch<=128 per 256 "
               "chips; the SSM/hybrid family's terms are dominated by "
               "whatever the residual-stream sharding makes of the "
               "projections -- see §Perf.")
    return "\n".join(lines), summary


def claims_table(bench_path="bench_output.txt") -> str:
    if not os.path.exists(bench_path):
        return "(populate by running `python -m benchmarks.run | tee "\
               "bench_output.txt`)"
    txt = open(bench_path).read()
    rows = {}
    for line in txt.splitlines():
        if line.startswith("#") or "," not in line:
            continue
        name, _, derived = line.split(",", 2)
        rows[name] = derived
    avg = rows.get("fig4.AVG", "")
    f5 = rows.get("fig5.AVG", "")
    t6 = rows.get("table6.AVG", "")
    f6 = rows.get("fig6.AVG", "")
    f8a = rows.get("fig8.16c.AVG", "")
    f8b = rows.get("fig8.256c.AVG", "")
    t7 = rows.get("table7.256cores", "")
    lines = [
        "| paper claim | paper value | reproduced (this run) |",
        "|---|---|---|",
        f"| Fig.4 Tardis ≈ MSI throughput (64c) | 1.00 ±0.005 | {avg} |",
        "| Fig.4 speculation off | 0.93 | (nospec_thr above) |",
        "| Fig.4 traffic overhead | 1.19–1.21 | (traffic above) |",
        f"| Fig.5 misspeculation < 1% | <0.01 | {f5} |",
        f"| Table VI ts rate / self-inc share | 263 cyc, 26.6% | {t6} |",
        f"| Fig.6 OoO: spec matters less | ≈MSI both | {f6} |",
        f"| Fig.8 16 cores | ≈MSI | {f8a} |",
        f"| Fig.8 256 cores, period 10 vs 100 | p10 ≈ MSI | {f8b} |",
        f"| Table VII storage @256c | 256/64/40 bits | {t7} |",
    ]
    for b in ("volrend", "cholesky", "fft"):
        if f"fig7.{b}" in rows:
            lines.append(f"| Fig.7 period sweep ({b}) | spin-sensitive | "
                         f"{rows[f'fig7.{b}']} |")
    for b in ("volrend", "cholesky"):
        if f"fig9.{b}" in rows:
            lines.append(f"| Fig.9 ts width ({b}) | 20b ≈ 64b | "
                         f"{rows[f'fig9.{b}']} |")
    for b in ("cholesky", "fft"):
        if f"fig10.{b}" in rows:
            lines.append(f"| Fig.10 lease sweep ({b}) | flat-ish | "
                         f"{rows[f'fig10.{b}']} |")
    return "\n".join(lines)


def main():
    exp = open("EXPERIMENTS.md").read()
    exp = exp.replace("<!-- DRYRUN_TABLE -->", dryrun_table())
    if os.path.exists("roofline.json"):
        table, summary = roofline_tables()
        exp = exp.replace("<!-- ROOFLINE_TABLE -->", table)
        exp = exp.replace("<!-- ROOFLINE_SUMMARY -->", summary)
    exp = exp.replace("<!-- CLAIMS_TABLE -->", claims_table())
    open("EXPERIMENTS.md", "w").write(exp)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
