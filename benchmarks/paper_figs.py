"""One function per paper table/figure (Tardis, ICPP'15).

Each prints CSV rows ``name,us_per_call,derived`` and returns a dict of the
headline numbers so EXPERIMENTS.md and tests can assert the paper's claims.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core.timestamps import storage_bits_per_line

from .common import BENCHES, N_CORES, QUICK, SUBSET, header, row, run


def fig4_throughput() -> Dict:
    """Fig. 4: 64-core throughput + network traffic vs. baseline MSI.

    Paper claims: Tardis within ~0.5% of MSI; ~+19% traffic; spec-off -7%."""
    header(f"fig4: throughput/traffic @ {N_CORES} cores (norm. to MSI)")
    rel_thr, rel_thr_nospec, rel_traf, ack_thr = [], [], [], []
    for b in BENCHES:
        msi, t_msi = run(b, "directory")
        ack, t_ack = run(b, "directory", ackwise_k=4)
        trd, t_trd = run(b, "tardis")
        trd_ns, t_ns = run(b, "tardis", speculate=False)
        thr = msi.cycles / max(1, trd.cycles)
        thr_ns = msi.cycles / max(1, trd_ns.cycles)
        traf = trd.stats["traffic"] / max(1, msi.stats["traffic"])
        rel_thr.append(thr)
        rel_thr_nospec.append(thr_ns)
        rel_traf.append(traf)
        ack_thr.append(msi.cycles / max(1, ack.cycles))
        row(f"fig4.{b}", t_trd * 1e6,
            f"tardis_thr={thr:.3f};nospec_thr={thr_ns:.3f};"
            f"ackwise_thr={ack_thr[-1]:.3f};traffic={traf:.3f}")
    out = {"tardis_vs_msi": float(np.mean(rel_thr)),
           "nospec_vs_msi": float(np.mean(rel_thr_nospec)),
           "ackwise_vs_msi": float(np.mean(ack_thr)),
           "traffic_vs_msi": float(np.mean(rel_traf))}
    row("fig4.AVG", 0.0,
        f"tardis_thr={out['tardis_vs_msi']:.3f};"
        f"nospec_thr={out['nospec_vs_msi']:.3f};"
        f"traffic={out['traffic_vs_msi']:.3f}")
    return out


def fig5_renew() -> Dict:
    """Fig. 5: renew + misspeculation rates (out of LLC accesses)."""
    header("fig5: renew / misspeculation rates")
    renew_rates, misspec_rates = [], []
    for b in BENCHES:
        res, t = run(b, "tardis")
        llc = max(1, res.stats["n_llc_req"])
        rr = res.stats["n_renew"] / llc
        mr = res.stats["n_misspec"] / llc
        renew_rates.append(rr)
        misspec_rates.append(mr)
        row(f"fig5.{b}", t * 1e6, f"renew_rate={rr:.4f};misspec={mr:.5f}")
    out = {"avg_renew": float(np.mean(renew_rates)),
           "avg_misspec": float(np.mean(misspec_rates)),
           "max_renew": float(np.max(renew_rates))}
    row("fig5.AVG", 0.0, f"renew={out['avg_renew']:.4f};"
        f"misspec={out['avg_misspec']:.5f}")
    return out


def table6_ts() -> Dict:
    """Table VI: timestamp increment rate + self-increment share."""
    header("table6: timestamp statistics")
    rates, shares = [], []
    for b in BENCHES:
        res, t = run(b, "tardis")
        incr = max(1.0, res.stats["n_ts_incr"])
        rate = res.cycles * res.pts.shape[0] / incr   # core-cycles per +1
        share = res.stats["n_selfinc"] / incr
        rates.append(rate)
        shares.append(share)
        row(f"table6.{b}", t * 1e6,
            f"cycles_per_ts={rate:.0f};selfinc_share={share:.3f}")
    out = {"avg_cycles_per_ts": float(np.mean(rates)),
           "avg_selfinc_share": float(np.mean(shares))}
    row("table6.AVG", 0.0, f"cycles_per_ts={out['avg_cycles_per_ts']:.0f};"
        f"selfinc_share={out['avg_selfinc_share']:.3f}")
    return out


def fig6_ooo() -> Dict:
    """Fig. 6: out-of-order cores -- speculation matters much less."""
    header("fig6: OoO cores (hide window = 40 cycles)")
    d_on, d_off = [], []
    for b in SUBSET[:4]:
        msi, _ = run(b, "directory", ooo_hide=40)
        on, t = run(b, "tardis", ooo_hide=40)
        off, _ = run(b, "tardis", ooo_hide=40, speculate=False)
        d_on.append(msi.cycles / max(1, on.cycles))
        d_off.append(msi.cycles / max(1, off.cycles))
        row(f"fig6.{b}", t * 1e6,
            f"spec_thr={d_on[-1]:.3f};nospec_thr={d_off[-1]:.3f}")
    out = {"ooo_spec": float(np.mean(d_on)), "ooo_nospec": float(np.mean(d_off))}
    row("fig6.AVG", 0.0, f"spec={out['ooo_spec']:.3f};"
        f"nospec={out['ooo_nospec']:.3f}")
    return out


def fig7_selfinc() -> Dict:
    """Fig. 7: self-increment period sweep (spin-heavy workloads degrade
    at large periods; larger periods always reduce traffic)."""
    header("fig7: self-increment period sweep")
    out = {}
    periods = [10, 100, 1000]
    for b in (["fmm", "cholesky", "fft", "volrend"] if not QUICK
              else ["cholesky", "fft"]):
        msi, _ = run(b, "directory")
        perf, traf = [], []
        for p in periods:
            res, t = run(b, "tardis", selfinc_period=p)
            perf.append(msi.cycles / max(1, res.cycles))
            traf.append(res.stats["traffic"] / max(1, msi.stats["traffic"]))
        out[b] = dict(zip(periods, perf))
        row(f"fig7.{b}", t * 1e6,
            ";".join(f"p{p}_thr={x:.3f}" for p, x in zip(periods, perf))
            + ";" + ";".join(f"p{p}_traf={x:.3f}"
                             for p, x in zip(periods, traf)))
    return out


def fig8_scale() -> Dict:
    """Fig. 8: 16 and 256 cores (256-core spin workloads need period=10)."""
    header("fig8: scalability 16 / 256 cores")
    out = {}
    for n, scale in ((16, 0.5), (256, 0.08 if not QUICK else 0.05)):
        rel, rel_p10 = [], []
        benches = SUBSET[:3] if n == 256 else BENCHES
        for b in benches:
            msi, _ = run(b, "directory", n_cores=n, scale=scale)
            trd, t = run(b, "tardis", n_cores=n, scale=scale)
            p10, _ = run(b, "tardis", n_cores=n, scale=scale,
                         selfinc_period=10)
            rel.append(msi.cycles / max(1, trd.cycles))
            rel_p10.append(msi.cycles / max(1, p10.cycles))
            row(f"fig8.{n}c.{b}", t * 1e6,
                f"p100_thr={rel[-1]:.3f};p10_thr={rel_p10[-1]:.3f}")
        out[n] = {"p100": float(np.mean(rel)), "p10": float(np.mean(rel_p10))}
        row(f"fig8.{n}c.AVG", 0.0,
            f"p100={out[n]['p100']:.3f};p10={out[n]['p10']:.3f}")
    return out


def table7_storage() -> Dict:
    """Table VII: per-LLC-line coherence storage (bits)."""
    header("table7: storage overhead (bits / LLC line)")
    out = {}
    for n in (16, 64, 256):
        bits = {s: storage_bits_per_line(
            n, s, ackwise_ptrs=(8 if n == 256 else 4))
            for s in ("full-map", "ackwise", "tardis")}
        out[n] = bits
        row(f"table7.{n}cores", 0.0,
            f"full_map={bits['full-map']};ackwise={bits['ackwise']};"
            f"tardis={bits['tardis']}")
    return out


def fig9_tssize() -> Dict:
    """Fig. 9: delta-timestamp width sweep (rebase overhead)."""
    header("fig9: timestamp size sweep")
    out = {}
    benches = ["volrend", "cholesky", "water_nsq"] if not QUICK else ["volrend"]
    for b in benches:
        msi, _ = run(b, "directory")
        perf = {}
        for bits in (8, 14, 20, 0):       # 0 = uncompressed 64-bit
            res, t = run(b, "tardis", ts_bits=bits)
            name = f"{bits}b" if bits else "64b"
            perf[name] = msi.cycles / max(1, res.cycles)
        out[b] = perf
        row(f"fig9.{b}", t * 1e6,
            ";".join(f"{k}_thr={v:.3f}" for k, v in perf.items()))
    return out


def fig10_lease() -> Dict:
    """Fig. 10: lease sweep (insensitive except spin-heavy; traffic falls
    as the lease grows)."""
    header("fig10: lease sweep")
    out = {}
    benches = ["volrend", "cholesky", "fft", "barnes"] if not QUICK \
        else ["cholesky", "fft"]
    for b in benches:
        msi, _ = run(b, "directory")
        perf, traf = {}, {}
        for lease in (5, 10, 20, 50):
            res, t = run(b, "tardis", lease=lease)
            perf[lease] = msi.cycles / max(1, res.cycles)
            traf[lease] = res.stats["traffic"] / max(1, msi.stats["traffic"])
        out[b] = perf
        row(f"fig10.{b}", t * 1e6,
            ";".join(f"l{k}_thr={v:.3f}" for k, v in perf.items()) + ";"
            + ";".join(f"l{k}_traf={v:.3f}" for k, v in traf.items()))
    return out


def ext_estate() -> Dict:
    """Beyond-paper: section IV-D's E-state extension, which the paper
    defers to future work.  Private/read-once lines are granted exclusively
    and never renew -- this attacks the renewal traffic the paper names as
    Tardis's main overhead (WATER-SP's 3x outlier in particular)."""
    header("ext: E-state (paper IV-D, evaluated here)")
    out = {}
    for b in ["water_sp", "lu_c", "fft", "barnes"]:
        base, _ = run(b, "tardis")
        est, t = run(b, "tardis", estate=True)
        dr = (base.stats["n_renew"] - est.stats["n_renew"]) / max(
            1, base.stats["n_renew"])
        dt = est.stats["traffic"] / max(1, base.stats["traffic"])
        out[b] = {"renew_cut": dr, "traffic": dt}
        row(f"ext_estate.{b}", t * 1e6,
            f"renew_cut={dr:.3f};traffic_vs_base={dt:.3f};"
            f"egrants={est.stats['n_egrant']:.0f};"
            f"thr_vs_base={base.cycles/max(1, est.cycles):.3f}")
    return out


ALL = [fig4_throughput, fig5_renew, table6_ts, fig6_ooo, fig7_selfinc,
       fig8_scale, table7_storage, fig9_tssize, fig10_lease, ext_estate]
