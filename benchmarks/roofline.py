"""Roofline report: three terms per (arch x shape x mesh) from the dry-run.

Reads the dryrun.json artifact (launch/dryrun.py), combines the compiled
HLO's collective schedule with the analytic FLOP/byte models
(benchmarks/analytic.py -- see its docstring for why analytic), and emits
one row per cell:

  compute_s   = HLO_FLOPs / (chips * 197 TFLOP/s)
  memory_s    = HLO_bytes / (chips * 819 GB/s)
  collective_s= collective_bytes / (chips * 50 GB/s)

plus the dominant term, MODEL_FLOPS / HLO_FLOPs, and a what-would-move-it
note.  The full table lands in EXPERIMENTS.md section Roofline.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

from repro.configs import SHAPE_BY_NAME, get_arch

from .analytic import LINK_BW, roofline_terms
from .common import header, row

MOVE_NOTE = {
    "compute": "compute-bound: only lower-precision math or fewer remat "
               "passes move it",
    "memory": "HBM-bound: raise arithmetic intensity (bigger per-chip batch,"
              " fused kernels, avoid cache re-reads)",
    "collective": "ICI-bound: reshard to cut the big collectives "
                  "(FSDP prefetch, TP->data swaps, overlap)",
}


def _scan_multiplier(arch: str) -> int:
    cfg = get_arch(arch)
    if cfg.family == "hybrid":
        return cfg.attn_every               # per-group scan length
    if cfg.family == "encdec":
        return cfg.n_layers
    if cfg.family == "moe":
        return cfg.n_layers - cfg.first_dense_layers
    return cfg.n_layers


def cell_report(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    cfg = get_arch(rec["arch"])
    shape = SHAPE_BY_NAME[rec["shape"]]
    chips = 512 if "multi" in rec["mesh"] else 256
    coll = rec.get("collective_bytes", {}) or {}
    in_loop = rec.get("collective_bytes_in_loop", {}) or {}
    if "error" in coll:
        coll, in_loop = {}, {}
    mult = _scan_multiplier(rec["arch"])
    # per-device bytes: out-of-loop once + in-loop x scan length
    total_coll = sum(v for k, v in coll.items()) if coll else 0
    loop_coll = sum(v for k, v in in_loop.items()) if in_loop else 0
    corrected = (total_coll - loop_coll) + mult * loop_coll
    terms = roofline_terms(cfg, shape, chips, corrected)
    raw_flops = rec.get("cost_analysis", {}).get("flops", 0.0)
    mem = rec.get("memory_analysis", {})
    out = {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "chips": chips,
        "collective_bytes_per_dev": corrected,
        "collective_counts": rec.get("collective_counts", {}),
        "hlo_flops_raw_per_dev": raw_flops,
        "temp_bytes_per_dev": mem.get("temp_size_in_bytes", 0),
        "arg_bytes_per_dev": rec.get("arg_bytes_per_device", 0),
        "model_vs_hlo": terms["model_flops"] / max(
            1.0, terms["hlo_flops_est"]),
        "note": MOVE_NOTE[terms["dominant"]],
        **terms,
    }
    return out


def report(dryrun_path: str = "dryrun.json",
           out_path: str = "roofline.json") -> List[Dict]:
    recs = json.load(open(dryrun_path))
    header(f"roofline: {len(recs)} dry-run cells from {dryrun_path}")
    rows = []
    for rec in recs:
        r = cell_report(rec)
        if r is None:
            continue
        rows.append(r)
        row(f"roofline.{r['mesh']}.{r['arch']}.{r['shape']}",
            0.0,
            f"compute_s={r['compute_s']:.4f};memory_s={r['memory_s']:.4f};"
            f"collective_s={r['collective_s']:.4f};dom={r['dominant']};"
            f"frac={r['roofline_frac']:.3f};"
            f"model_vs_hlo={r['model_vs_hlo']:.3f}")
    if out_path:
        json.dump(rows, open(out_path, "w"), indent=1)
    return rows


def markdown_table(rows: List[Dict], mesh_filter: str = "single") -> str:
    lines = ["| arch | shape | compute s | memory s | collective s | "
             "dominant | roofline frac | MODEL/HLO |",
             "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if mesh_filter not in r["mesh"]:
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"{r['dominant']} | {r['roofline_frac']:.3f} | "
            f"{r['model_vs_hlo']:.3f} |")
    return "\n".join(lines)


# -- disaggregated prefill/decode split -------------------------------------

def disagg_rows(rows: List[Dict], prefill_shape: str = "prefill_32k",
                decode_shape: str = "decode_32k") -> List[Dict]:
    """Pair each (arch, mesh)'s prefill and decode cells into one
    split-roofline row: the disaggregation pitch is that the two phases
    are bound by DIFFERENT terms (prefill by flops, decode by the
    collectives), so a prefill pod and a decode pod each run against
    their own ceiling instead of the worse of both.  ``split_wins`` marks
    the cells where the dry-run-calibrated terms actually show that
    asymmetry."""
    by_key = {(r["arch"], r["mesh"], r["shape"]): r for r in rows}
    out = []
    for (arch, mesh, shape), pre in sorted(by_key.items()):
        if shape != prefill_shape:
            continue
        dec = by_key.get((arch, mesh, decode_shape))
        if dec is None:
            continue
        out.append({
            "arch": arch, "mesh": mesh,
            "prefill_dominant": pre["dominant"],
            "prefill_compute_s": pre["compute_s"],
            "prefill_collective_s": pre["collective_s"],
            "decode_dominant": dec["dominant"],
            "decode_compute_s": dec["compute_s"],
            "decode_collective_s": dec["collective_s"],
            "split_wins": (pre["dominant"] == "compute"
                           and dec["dominant"] != "compute"),
        })
    return out


def markdown_disagg_table(rows: List[Dict],
                          mesh_filter: str = "multi") -> str:
    """The split-roofline table EXPERIMENTS.md embeds: one row per arch,
    prefill-pod vs decode-pod bound terms side by side."""
    lines = ["| arch | prefill dom | prefill compute s | "
             "decode dom | decode collective s | split wins |",
             "|---|---|---|---|---|---|"]
    for r in disagg_rows(rows):
        if mesh_filter not in r["mesh"]:
            continue
        lines.append(
            f"| {r['arch']} | {r['prefill_dominant']} | "
            f"{r['prefill_compute_s']:.4f} | {r['decode_dominant']} | "
            f"{r['decode_collective_s']:.4f} | "
            f"{'yes' if r['split_wins'] else 'no'} |")
    return "\n".join(lines)
