"""LeaseEngine microbench: kernel vs numpy mirror, blocks/s.

Times the two hot LeaseEngine transitions -- the masked lease-check pass
(read/renew) and the write jump-ahead -- through both backends over block
tables of serving-realistic sizes, touching a random half of the table per
op.  Prints the repo-standard ``name,us_per_call,derived`` CSV rows
(benchmarks/common.py convention) with blocks/s as the derived figure.

On TPU the pallas backend runs the compiled kernel; on CPU it runs in
interpret mode, so the numpy mirror wins there -- the point of the bench is
to *record* the ratio per platform (EXPERIMENTS.md), not to assert it.

Run:  PYTHONPATH=src python benchmarks/lease_bench.py [--sizes 4096,65536]
"""
import argparse
import os
import sys
import time

import numpy as np


def bench_engine(n_blocks: int, backend: str, iters: int):
    from repro.core import LeaseEngine

    from benchmarks.common import row

    eng = LeaseEngine(n_blocks, lease=64, backend=backend)
    rng = np.random.default_rng(0)
    idx = rng.choice(n_blocks, n_blocks // 2, replace=False)
    req = eng.wts[idx]
    pts = 0

    pts = eng.read(idx, pts, req_wts=req).new_pts      # warm up / compile
    t0 = time.perf_counter()
    for _ in range(iters):
        pts = eng.read(idx, pts, req_wts=req).new_pts
    dt_read = (time.perf_counter() - t0) / iters

    pts = eng.write(idx, pts)
    t0 = time.perf_counter()
    for _ in range(iters):
        pts = eng.write(idx, pts)
    dt_write = (time.perf_counter() - t0) / iters

    blocks = len(idx)
    row(f"lease_check/{backend}/n{n_blocks}", dt_read * 1e6,
        f"{blocks / dt_read:.3e} blocks/s")
    row(f"write_advance/{backend}/n{n_blocks}", dt_write * 1e6,
        f"{blocks / dt_write:.3e} blocks/s")
    return {"read_blocks_per_s": blocks / dt_read,
            "write_blocks_per_s": blocks / dt_write}


def main():
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    import jax

    from benchmarks.common import header

    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", default="4096,16384,65536")
    ap.add_argument("--iters", type=int, default=20)
    args = ap.parse_args()

    plat = jax.default_backend()
    header(f"LeaseEngine throughput (platform={plat}; pallas backend runs "
           f"{'compiled' if plat == 'tpu' else 'in interpret mode'})")
    results = {}
    for n in [int(s) for s in args.sizes.split(",")]:
        for backend in ("pallas", "numpy"):
            results[(n, backend)] = bench_engine(n, backend, args.iters)
    for n in [int(s) for s in args.sizes.split(",")]:
        k, m = results[(n, "pallas")], results[(n, "numpy")]
        print(f"# n={n}: pallas/numpy read ratio "
              f"{k['read_blocks_per_s'] / m['read_blocks_per_s']:.3f}, "
              f"write ratio "
              f"{k['write_blocks_per_s'] / m['write_blocks_per_s']:.3f}")


if __name__ == "__main__":
    main()
