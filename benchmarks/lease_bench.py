"""LeaseEngine microbench: kernel vs mirror, per-wave batching, paged decode.

Times the hot LeaseEngine transitions -- the masked lease-check pass
(read/renew) and the write jump-ahead -- through both backends over block
tables of serving-realistic sizes, touching a random half of the table per
op, plus the per-wave batched path: a wave of B requesters sharing a
system prompt resolved in ONE ``read_many`` dispatch vs B per-request
``read`` dispatches, plus the **paged-vs-dense decode** microbench: one
continuous-batch decode step through LeaseEngine pool pages
(``models.decode_step_paged``: pool gather + token-row append kernel) vs
the dense per-request cache step (``models.decode_step``).  Prints the
repo-standard ``name,us_per_call,derived`` CSV rows (benchmarks/common.py
convention) and writes the same numbers machine-readable to
``BENCH_lease.json`` so the perf trajectory is trackable across PRs.

On TPU the pallas backend runs the compiled kernels; on CPU it runs in
interpret mode, so the numpy mirror wins there -- the point of the bench is
to *record* the ratio per platform (EXPERIMENTS.md), not to assert it.

Run:  PYTHONPATH=src python benchmarks/lease_bench.py [--sizes 4096,65536]
                                                      [--json BENCH_lease.json]
      PYTHONPATH=src python benchmarks/lease_bench.py --smoke   # CI lane
"""
import argparse
import json
import os
import sys
import time

import numpy as np


def bench_engine(n_blocks: int, backend: str, iters: int):
    from repro.core import LeaseEngine

    from benchmarks.common import row

    eng = LeaseEngine(n_blocks, lease=64, backend=backend)
    rng = np.random.default_rng(0)
    idx = rng.choice(n_blocks, n_blocks // 2, replace=False)
    req = eng.wts[idx]
    pts = 0

    pts = eng.read(idx, pts, req_wts=req).new_pts      # warm up / compile
    t0 = time.perf_counter()
    for _ in range(iters):
        pts = eng.read(idx, pts, req_wts=req).new_pts
    dt_read = (time.perf_counter() - t0) / iters

    pts = eng.write(idx, pts)
    t0 = time.perf_counter()
    for _ in range(iters):
        pts = eng.write(idx, pts)
    dt_write = (time.perf_counter() - t0) / iters

    blocks = len(idx)
    row(f"lease_check/{backend}/n{n_blocks}", dt_read * 1e6,
        f"{blocks / dt_read:.3e} blocks/s")
    row(f"write_advance/{backend}/n{n_blocks}", dt_write * 1e6,
        f"{blocks / dt_write:.3e} blocks/s")
    return {"read_us": dt_read * 1e6, "write_us": dt_write * 1e6,
            "read_blocks_per_s": blocks / dt_read,
            "write_blocks_per_s": blocks / dt_write}


def bench_wave(n_blocks: int, backend: str, iters: int, wave: int,
               blocks_per_req: int):
    """A wave of ``wave`` requesters sharing the same prefix blocks:
    one batched read_many dispatch vs ``wave`` per-request dispatches."""
    from repro.core import LeaseEngine

    from benchmarks.common import row

    rng = np.random.default_rng(0)
    shared = rng.choice(n_blocks, blocks_per_req, replace=False)
    groups = [shared] * wave

    eng_b = LeaseEngine(n_blocks, lease=64, backend=backend)
    eng_s = LeaseEngine(n_blocks, lease=64, backend=backend)
    req = {int(b): 0 for b in shared}
    req_seq = [0] * blocks_per_req
    pts = int(eng_b.read_many(groups, 0, req_wts=req).new_pts.max())
    for g in groups:
        eng_s.read(g, 0, req_wts=req_seq)

    t0 = time.perf_counter()
    for _ in range(iters):
        pts = int(eng_b.read_many(groups, pts, req_wts=req).new_pts.max())
    dt_wave = (time.perf_counter() - t0) / iters

    pts = 0
    t0 = time.perf_counter()
    for _ in range(iters):
        for g in groups:
            pts = eng_s.read(g, pts, req_wts=req_seq).new_pts
    dt_seq = (time.perf_counter() - t0) / iters

    row(f"wave_read_many/{backend}/n{n_blocks}/B{wave}", dt_wave * 1e6,
        f"1 dispatch, {dt_seq / dt_wave:.2f}x vs per-request")
    row(f"wave_per_request/{backend}/n{n_blocks}/B{wave}", dt_seq * 1e6,
        f"{wave} dispatches")
    return {"wave": wave, "blocks_per_req": blocks_per_req,
            "per_wave_us": dt_wave * 1e6, "per_request_us": dt_seq * 1e6,
            "speedup": dt_seq / dt_wave,
            "dispatches_batched": 1, "dispatches_per_request": wave}


def bench_decode(iters: int, steps: int, batch: int = 4,
                 prompt: int = 64, cache_len: int = 256,
                 page_tokens: int = 16):
    """Paged decode (pool pages + append kernel) vs dense-cache decode:
    ``steps`` continuous-batch decode steps each, same reduced model."""
    import warnings

    import jax
    import jax.numpy as jnp

    from repro.configs import get_arch, reduced
    from repro.core import LeaseEngine
    from repro.models import (decode_step, decode_step_paged, init_cache,
                              init_params, prefill)

    from benchmarks.common import row

    cfg = reduced(get_arch("tinyllama-1.1b"), n_layers=2, d_model=64,
                  vocab=256)
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    rng = np.random.default_rng(0)
    toks = rng.integers(1, cfg.vocab, (batch, prompt)).astype(np.int32)
    interp = jax.default_backend() != "tpu"

    # dense: per-request caches, lockstep positions
    dense_fn = jax.jit(lambda p, c, t, i: decode_step(cfg, p, c, t, i))
    cache, logits = jax.jit(lambda p, b: prefill(cfg, p, b, cache_len))(
        params, {"tokens": jnp.asarray(toks)})
    nt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]

    def run_dense():
        c, t, cur = cache, nt, jnp.int32(prompt)
        for _ in range(steps):
            c, lg = dense_fn(params, c, t, cur)
            t = jnp.argmax(lg[:, -1], -1).astype(jnp.int32)[:, None]
            cur = cur + 1
        jax.block_until_ready(lg)

    run_dense()                                        # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        run_dense()
    dt_dense = (time.perf_counter() - t0) / (iters * steps)

    # paged: same shapes through LeaseEngine pool pages
    hk, dh = cfg.n_kv_heads, cfg.head_dim()
    eng = LeaseEngine(batch * (cache_len // page_tokens) + 8,
                      kv_block_shape=(page_tokens, 2,
                                      cfg.n_layers * hk, dh))
    pages_per = cache_len // page_tokens
    page_rows = np.stack([np.asarray(eng.alloc_pages(pages_per), np.int32)
                          for _ in range(batch)])
    lengths = np.full(batch, prompt, np.int32)
    paged_fn = jax.jit(
        lambda p, pool, pr, ln, tk: decode_step_paged(
            cfg, p, pool, pr, ln, tk, chunk=page_tokens, interpret=interp),
        donate_argnums=(1,))

    def run_paged():
        pool, t, ln = eng.kv_rows_view(), nt, jnp.asarray(lengths)
        with warnings.catch_warnings():
            warnings.filterwarnings("ignore", message=".*donat.*")
            for _ in range(steps):
                pool, lg = paged_fn(params, pool, jnp.asarray(page_rows),
                                    ln, t)
                t = jnp.argmax(lg[:, -1], -1).astype(jnp.int32)[:, None]
                ln = ln + 1
        eng.set_kv_rows(pool, tokens_appended=batch * steps)
        jax.block_until_ready(lg)

    run_paged()                                        # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        run_paged()
    dt_paged = (time.perf_counter() - t0) / (iters * steps)

    row(f"decode_dense/B{batch}/T{cache_len}", dt_dense * 1e6,
        f"{batch / dt_dense:.3e} tok/s")
    row(f"decode_paged/B{batch}/T{cache_len}", dt_paged * 1e6,
        f"{batch / dt_paged:.3e} tok/s, "
        f"{dt_paged / dt_dense:.2f}x vs dense")
    return {"batch": batch, "cache_len": cache_len, "steps": steps,
            "dense_us_per_step": dt_dense * 1e6,
            "paged_us_per_step": dt_paged * 1e6,
            "paged_over_dense": dt_paged / dt_dense}


def main():
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    import jax

    from benchmarks.common import header

    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", default="4096,16384,65536")
    ap.add_argument("--iters", type=int, default=20)
    ap.add_argument("--wave", type=int, default=8,
                    help="requesters per wave for the batched-read bench")
    ap.add_argument("--decode-steps", type=int, default=8,
                    help="decode steps per timed run (paged-vs-dense)")
    ap.add_argument("--json", default="BENCH_lease.json",
                    help="machine-readable output path ('' to skip)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes/iters so CI exercises every bench "
                         "path in seconds (writes no JSON)")
    args = ap.parse_args()
    if args.smoke:
        args.sizes, args.iters, args.decode_steps = "1024", 2, 2
        args.json = ""

    plat = jax.default_backend()
    header(f"LeaseEngine throughput (platform={plat}; pallas backend runs "
           f"{'compiled' if plat == 'tpu' else 'in interpret mode'})")
    sizes = [int(s) for s in args.sizes.split(",")]
    out = {"platform": plat, "iters": args.iters,
           "engine": {}, "wave": {}, "decode": {}}
    for n in sizes:
        for backend in ("pallas", "numpy"):
            out["engine"][f"{backend}/n{n}"] = bench_engine(
                n, backend, args.iters)
    header(f"per-wave batched leasing (B={args.wave} requesters sharing "
           f"a prefix)")
    for n in sizes:
        for backend in ("pallas", "numpy"):
            out["wave"][f"{backend}/n{n}"] = bench_wave(
                n, backend, args.iters, args.wave, blocks_per_req=8)
    header("paged-vs-dense decode (continuous-batch step, reduced model)")
    out["decode"]["B4/T256"] = bench_decode(max(2, args.iters // 4),
                                            args.decode_steps)
    for n in sizes:
        k = out["engine"][f"pallas/n{n}"]
        m = out["engine"][f"numpy/n{n}"]
        print(f"# n={n}: pallas/numpy read ratio "
              f"{k['read_blocks_per_s'] / m['read_blocks_per_s']:.3f}, "
              f"write ratio "
              f"{k['write_blocks_per_s'] / m['write_blocks_per_s']:.3f}, "
              f"wave speedup pallas "
              f"{out['wave'][f'pallas/n{n}']['speedup']:.2f}x / numpy "
              f"{out['wave'][f'numpy/n{n}']['speedup']:.2f}x")
    d = out["decode"]["B4/T256"]
    print(f"# paged decode {d['paged_us_per_step']:.0f} us/step vs dense "
          f"{d['dense_us_per_step']:.0f} us/step "
          f"({d['paged_over_dense']:.2f}x)")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
