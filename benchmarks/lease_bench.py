"""LeaseEngine microbench: kernel vs mirror, per-wave batching, paged decode.

Times the hot LeaseEngine transitions -- the masked lease-check pass
(read/renew) and the write jump-ahead -- through both backends over block
tables of serving-realistic sizes, touching a random half of the table per
op, plus the per-wave batched path: a wave of B requesters sharing a
system prompt resolved in ONE ``read_many`` dispatch vs B per-request
``read`` dispatches, plus the **paged-vs-dense decode** microbench: one
continuous-batch decode step through LeaseEngine pool pages
(``models.decode_step_paged``: pool gather + token-row append kernel) vs
the dense per-request cache step (``models.decode_step``).  Prints the
repo-standard ``name,us_per_call,derived`` CSV rows (benchmarks/common.py
convention) and writes the same numbers machine-readable to
``BENCH_lease.json`` so the perf trajectory is trackable across PRs.

On TPU the pallas backend runs the compiled kernels; on CPU it runs in
interpret mode, so the numpy mirror wins there -- the point of the bench is
to *record* the ratio per platform (EXPERIMENTS.md), not to assert it.

The decode bench times TWO rows: the dense family and a moe family whose
DUAL cache stacks page through the engine's named pools (interleaved token
rows) -- the paged-vs-dense ratio is tracked per row.

``--check-against BENCH_lease.json`` is the CI **bench-regression gate**:
it re-measures the baseline's gated shapes (best of ``--check-repeats``
passes, min-over-iterations estimator) and exits 1 if any tracked
dimensionless ratio -- wave batched-vs-sequential speedup, kernel-vs-
mirror throughput ratio, paged-over-dense decode ratio -- regresses past
its tolerance vs the checked-in baseline (25%; the decode rows gate at 2x
-- see ``DECODE_TOLERANCE``).  Absolute microseconds are never gated (CI
runners drift); ratios compare the machine against itself.

Run:  PYTHONPATH=src python benchmarks/lease_bench.py [--sizes 4096,65536]
                                                      [--json BENCH_lease.json]
      PYTHONPATH=src python benchmarks/lease_bench.py --smoke   # CI lane
      PYTHONPATH=src python benchmarks/lease_bench.py --smoke \
          --check-against BENCH_lease.json          # CI regression gate
"""
import argparse
import json
import os
import sys
import time

import numpy as np


def bench_engine(n_blocks: int, backend: str, iters: int):
    from repro.core import LeaseEngine

    from benchmarks.common import row

    eng = LeaseEngine(n_blocks, lease=64, backend=backend)
    rng = np.random.default_rng(0)
    idx = rng.choice(n_blocks, n_blocks // 2, replace=False)
    req = eng.wts[idx]
    pts = 0

    # min over per-op timings: the mean drags scheduler/GC noise into the
    # kernel-vs-mirror ratio the CI gate tracks; the min estimates the
    # cost floor and is stable across runs and process histories
    pts = eng.read(idx, pts, req_wts=req).new_pts      # warm up / compile
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        pts = eng.read(idx, pts, req_wts=req).new_pts
        times.append(time.perf_counter() - t0)
    dt_read = min(times)

    pts = eng.write(idx, pts)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        pts = eng.write(idx, pts)
        times.append(time.perf_counter() - t0)
    dt_write = min(times)

    blocks = len(idx)
    row(f"lease_check/{backend}/n{n_blocks}", dt_read * 1e6,
        f"{blocks / dt_read:.3e} blocks/s")
    row(f"write_advance/{backend}/n{n_blocks}", dt_write * 1e6,
        f"{blocks / dt_write:.3e} blocks/s")
    return {"read_us": dt_read * 1e6, "write_us": dt_write * 1e6,
            "read_blocks_per_s": blocks / dt_read,
            "write_blocks_per_s": blocks / dt_write}


def bench_wave(n_blocks: int, backend: str, iters: int, wave: int,
               blocks_per_req: int):
    """A wave of ``wave`` requesters sharing the same prefix blocks:
    one batched read_many dispatch vs ``wave`` per-request dispatches."""
    from repro.core import LeaseEngine

    from benchmarks.common import row

    rng = np.random.default_rng(0)
    shared = rng.choice(n_blocks, blocks_per_req, replace=False)
    groups = [shared] * wave

    eng_b = LeaseEngine(n_blocks, lease=64, backend=backend)
    eng_s = LeaseEngine(n_blocks, lease=64, backend=backend)
    req = {int(b): 0 for b in shared}
    req_seq = [0] * blocks_per_req
    pts = int(eng_b.read_many(groups, 0, req_wts=req).new_pts.max())
    for g in groups:
        eng_s.read(g, 0, req_wts=req_seq)

    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        pts = int(eng_b.read_many(groups, pts, req_wts=req).new_pts.max())
        times.append(time.perf_counter() - t0)
    dt_wave = min(times)       # min over iterations, like bench_engine

    pts = 0
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        for g in groups:
            pts = eng_s.read(g, pts, req_wts=req_seq).new_pts
        times.append(time.perf_counter() - t0)
    dt_seq = min(times)

    row(f"wave_read_many/{backend}/n{n_blocks}/B{wave}", dt_wave * 1e6,
        f"1 dispatch, {dt_seq / dt_wave:.2f}x vs per-request")
    row(f"wave_per_request/{backend}/n{n_blocks}/B{wave}", dt_seq * 1e6,
        f"{wave} dispatches")
    return {"wave": wave, "blocks_per_req": blocks_per_req,
            "per_wave_us": dt_wave * 1e6, "per_request_us": dt_seq * 1e6,
            "speedup": dt_seq / dt_wave,
            "dispatches_batched": 1, "dispatches_per_request": wave}


def bench_decode(iters: int, steps: int, batch: int = 4,
                 prompt: int = 64, cache_len: int = 256,
                 page_tokens: int = 16, arch: str = "tinyllama-1.1b"):
    """Paged decode (pool pages + append kernel) vs dense-cache decode:
    ``steps`` continuous-batch decode steps each, same reduced model.
    ``arch`` picks the family -- the moe row pages BOTH cache stacks
    through the engine's named pools (interleaved token rows)."""
    import warnings

    import jax
    import jax.numpy as jnp

    from repro.configs import get_arch, reduced
    from repro.core import LeaseEngine
    from repro.models import (decode_step, decode_step_paged, init_params,
                              pool_layout, prefill)

    from benchmarks.common import row

    # d256 keeps the step compute-dominated: at d64 the ~1ms step is mostly
    # Python/XLA dispatch, whose cost drifts with process history and makes
    # the gated paged/dense ratio swing ~2x between runs
    cfg = reduced(get_arch(arch), n_layers=2, d_model=256, d_ff=512,
                  vocab=256)
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    rng = np.random.default_rng(0)
    toks = rng.integers(1, cfg.vocab, (batch, prompt)).astype(np.int32)
    interp = jax.default_backend() != "tpu"

    # dense: per-request caches, lockstep positions
    dense_fn = jax.jit(lambda p, c, t, i: decode_step(cfg, p, c, t, i))
    cache, logits = jax.jit(lambda p, b: prefill(cfg, p, b, cache_len))(
        params, {"tokens": jnp.asarray(toks)})
    nt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]

    def run_dense():
        c, t, cur = cache, nt, jnp.int32(prompt)
        for _ in range(steps):
            c, lg = dense_fn(params, c, t, cur)
            t = jnp.argmax(lg[:, -1], -1).astype(jnp.int32)[:, None]
            cur = cur + 1
        jax.block_until_ready(lg)

    # the gate tracks paged/dense: use the MIN over iterations (each one
    # a full `steps`-step run) -- the mean drags scheduler noise into the
    # ratio, the min estimates the cost floor and is stable run to run
    run_dense()                                        # compile
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        run_dense()
        times.append(time.perf_counter() - t0)
    dt_dense = min(times) / steps

    # paged: same shapes through LeaseEngine pool pages -- one named pool
    # per cache stack (moe: dense + moe interleaved in each token row)
    hk, dh = cfg.n_kv_heads, cfg.head_dim()
    eng = LeaseEngine(batch * (cache_len // page_tokens) + 8,
                      kv_pools={s.pool: (page_tokens, 2, s.n_layers * hk, dh)
                                for s in pool_layout(cfg)})
    pages_per = cache_len // page_tokens
    page_rows = np.stack([np.asarray(eng.alloc_pages(pages_per), np.int32)
                          for _ in range(batch)])
    lengths = np.full(batch, prompt, np.int32)
    paged_fn = jax.jit(
        lambda p, pool, pr, ln, tk: decode_step_paged(
            cfg, p, pool, pr, ln, tk, chunk=page_tokens, interpret=interp),
        donate_argnums=(1,))

    def run_paged():
        pool, t, ln = eng.kv_rows_view(), nt, jnp.asarray(lengths)
        with warnings.catch_warnings():
            warnings.filterwarnings("ignore", message=".*donat.*")
            for _ in range(steps):
                pool, lg = paged_fn(params, pool, jnp.asarray(page_rows),
                                    ln, t)
                t = jnp.argmax(lg[:, -1], -1).astype(jnp.int32)[:, None]
                ln = ln + 1
        eng.set_kv_rows(pool, tokens_appended=batch * steps)
        jax.block_until_ready(lg)

    run_paged()                                        # compile
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        run_paged()
        times.append(time.perf_counter() - t0)
    dt_paged = min(times) / steps

    fam = cfg.family
    row(f"decode_dense/{fam}/B{batch}/T{cache_len}", dt_dense * 1e6,
        f"{batch / dt_dense:.3e} tok/s")
    row(f"decode_paged/{fam}/B{batch}/T{cache_len}", dt_paged * 1e6,
        f"{batch / dt_paged:.3e} tok/s, "
        f"{dt_paged / dt_dense:.2f}x vs dense")
    return {"arch": arch, "family": fam, "batch": batch,
            "cache_len": cache_len, "steps": steps,
            "dense_us_per_step": dt_dense * 1e6,
            "paged_us_per_step": dt_paged * 1e6,
            "paged_over_dense": dt_paged / dt_dense}


def bench_directory(n_blocks: int, iters: int):
    """Sharded-directory rows: remote-vs-local lease wave latency (timed,
    recorded but NOT gated -- wall-clock), messages-per-wave vs shard
    count, and the cross-host prefix-reuse replay (both deterministic
    counters, gated: a multicast or per-block chatter regression moves
    them no matter how noisy the runner is)."""
    from repro.core import ShardedLeaseDirectory

    from benchmarks.common import row

    # remote vs local lease hit: identical 8-block waves, owner differing.
    # even gids live on shard 0 (host 0: local), odd gids on shard 1
    d = ShardedLeaseDirectory(n_blocks, 2, n_hosts=2, lease=64)
    rng = np.random.default_rng(0)
    base = rng.choice(n_blocks // 2, 8, replace=False)
    local = [int(b) * 2 for b in base]
    remote = [b + 1 for b in local]
    out = {}
    for name, bids in (("local", local), ("remote", remote)):
        pts = int(d.wave(0, 0, read_groups=[bids]).new_pts)   # warm up
        times = []
        for _ in range(iters):
            t0 = time.perf_counter()
            pts = int(d.wave(0, pts, read_groups=[bids]).new_pts)
            times.append(time.perf_counter() - t0)
        out[f"{name}_us"] = min(times) * 1e6
        row(f"dir_lease_{name}/n{n_blocks}", min(times) * 1e6,
            f"{len(bids)} blocks, "
            f"{'1 owner-shard msg pair' if name == 'remote' else 'no msgs'}")
    out["remote_over_local"] = out["remote_us"] / out["local_us"]

    # one message pair per contacted owner shard, vs shard count
    out["msgs_per_wave"] = {}
    for n_shards in (2, 4, 8):
        ds = ShardedLeaseDirectory(max(n_blocks, 8 * n_shards), n_shards,
                                   n_hosts=n_shards, lease=64)
        res = ds.wave(0, 0, read_groups=[list(range(n_shards * 4))])
        bound = 2 * (n_shards - 1)        # host 0 owns shard 0: it is free
        out["msgs_per_wave"][f"S{n_shards}"] = {
            "msgs": res.msgs, "remote_shards": res.shards_contacted,
            "bound": bound}
        print(f"# dir_msgs_per_wave/S{n_shards}: {res.msgs} msgs "
              f"({res.shards_contacted} remote shards, bound {bound})")

    # cross-host prefix reuse: host 0 writes+publishes P prefix pages,
    # host 1 leases+fetches them all in ONE wave
    n_prefix = 16
    dr = ShardedLeaseDirectory(n_blocks, 2, n_hosts=2, lease=64,
                               kv_pools={"kv": (1, 16)},
                               kv_dtype=np.float32, block_bytes=64)
    bids = list(range(n_prefix))
    res = dr.wave(0, 0, write_bids=bids, tag_writes_with_ts=True)
    for b in bids:
        dr.defer_publish(0, b, {"kv": np.zeros((1, 1, 16), np.float32)})
    dr.flush_deferred(0)
    msgs_before = dr.stats.msgs
    res = dr.wave(1, res.new_pts, read_groups=[bids], fetch_bids=bids)
    reused = len(res.fetched)
    fetch_msgs = dr.stats.msgs - msgs_before
    out["reuse"] = {"blocks": n_prefix, "reused": reused,
                    "fraction": reused / n_prefix,
                    "fetch_msgs": fetch_msgs,
                    "msgs_per_reused_block": fetch_msgs / max(reused, 1),
                    "multicasts": dr.stats.multicasts,
                    "invalidation_msgs": dr.stats.invalidation_msgs}
    print(f"# dir_reuse: {reused}/{n_prefix} prefix pages migrated in "
          f"{fetch_msgs} msgs "
          f"({out['reuse']['msgs_per_reused_block']:.3f} msgs/block), "
          f"{dr.stats.multicasts} multicasts")

    # disaggregated decode pod: the prefill pod (host 0) publishes an
    # 8-block prefix, the decode pod (host 1) subscribes, gets the
    # publish-then-notify wake, migrates the pages once, then idles in
    # steady state -- its per-tick lease traffic is batched data-less
    # renewals only.  All message ledgers, fully deterministic.  Replayed
    # three ways: the static-SC baseline, and the Tardis 2.0 lanes --
    # adaptive per-block leases under SC (renewal waves thin out as the
    # predictor learns the blocks are read-only) and under TSO (the decode
    # pod serves tag-checked expired copies with no renewal at all).
    def _disagg_replay(policy, ticks):
        kw = dict(kv_pools={"kv": (1, 16)}, kv_dtype=np.float32,
                  block_bytes=64)
        if policy is None:
            dd = ShardedLeaseDirectory(n_blocks, 2, n_hosts=2, lease=16,
                                       **kw)
        else:
            dd = ShardedLeaseDirectory(n_blocks, 2, n_hosts=2,
                                       policy=policy, **kw)
        skip = policy.skip_expired_renewal() if policy else False
        bids = list(range(8))
        res = dd.wave(0, 0, write_bids=bids, tag_writes_with_ts=True)
        handoff0 = dd.stats.msgs
        assert dd.subscribe(1, bids) == []     # cold: watch, don't poll
        for b in bids:
            dd.defer_publish(0, b, {"kv": np.zeros((1, 1, 16), np.float32)})
        dd.flush_deferred(0)                   # fires the notify wave
        woken = sorted(dd.pop_notifications(1))
        res = dd.wave(1, int(res.new_pts), read_groups=[bids],
                      fetch_bids=bids)
        handoff_msgs = dd.stats.msgs - handoff0
        pts = int(res.new_pts)
        leases = dict(res.leases)
        renew_waves, skipped, msgs0 = 0, 0, dd.stats.msgs
        for _ in range(ticks):
            pts += 1                           # one decode step
            expired = {b: leases[b][0] for b in bids
                       if pts > leases[b][1]}
            if expired and skip:
                # tso/rc: the copies are tag-checked and read-only --
                # serve them locally, no renewal round-trip, no pts move
                skipped += len(expired)
            elif expired:
                r2 = dd.wave(1, pts, read_groups=[list(expired)],
                             req_wts=expired)
                pts = int(r2.new_pts)
                leases.update(r2.leases)
                renew_waves += 1
        decode_msgs = dd.stats.msgs - msgs0
        return {
            "blocks": len(bids), "woken": len(woken),
            "consistency": policy.consistency if policy else "sc",
            "predictor": bool(policy and policy.predictor),
            "handoff_msgs": handoff_msgs,
            "decode_ticks": ticks, "renew_waves": renew_waves,
            "renewals_skipped": skipped,
            "decode_msgs": decode_msgs,
            "decode_msgs_per_tick": decode_msgs / ticks,
            "pred_lease_hi": int(dd.pred_lease.max()),
            "multicasts": dd.stats.multicasts,
            "invalidation_msgs": dd.stats.invalidation_msgs}

    from repro.core import CoherencePolicy
    out["disagg"] = _disagg_replay(None, 64)
    out["disagg_pred_sc"] = _disagg_replay(
        CoherencePolicy(consistency="sc", lease=16, predictor=True), 256)
    out["disagg_pred_tso"] = _disagg_replay(
        CoherencePolicy(consistency="tso", lease=16, predictor=True), 256)
    for name in ("disagg", "disagg_pred_sc", "disagg_pred_tso"):
        dg = out[name]
        print(f"# dir_{name}: {dg['woken']}/{dg['blocks']} pages woke the "
              f"decode pod ({dg['handoff_msgs']} hand-off msgs), then "
              f"{dg['decode_msgs']} msgs over {dg['decode_ticks']} decode "
              f"ticks ({dg['decode_msgs_per_tick']:.4f} msgs/tick, "
              f"{dg['renew_waves']} renewal waves, "
              f"{dg['renewals_skipped']} renewals skipped, "
              f"{dg['multicasts']} multicasts)")
    return out


# decode rows: JSON key -> the arch whose reduced config is timed ("B4/..."
# keeps its historical dense key; the moe row pages dual cache stacks)
DECODE_ROWS = {
    "B4/T256": "tinyllama-1.1b",
    "moe/B4/T256": "kimi-k2-1t-a32b",
}

# the CI regression gate's tolerance: a tracked ratio may not regress more
# than 25% vs the checked-in baseline.  The decode rows get a looser bound:
# on CPU the paged/dense step ratio carries irreducible process-history
# noise (measured spread ~1.6-2.9x across otherwise identical runs even
# with the min estimator), so they gate at 2x -- still far below what any
# real paged-path rot (a per-token full-table gather, a lost kernel route)
# produces, without permanent flakes.
CHECK_TOLERANCE = 1.25
DECODE_TOLERANCE = 2.0


def run_suite(args, sizes, decode_rows):
    """One full measurement pass; returns the machine-readable dict."""
    import jax

    from benchmarks.common import header

    plat = jax.default_backend()
    header(f"LeaseEngine throughput (platform={plat}; pallas backend runs "
           f"{'compiled' if plat == 'tpu' else 'in interpret mode'})")
    out = {"platform": plat, "iters": args.iters,
           "engine": {}, "wave": {}, "decode": {}}
    for n in sizes:
        for backend in ("pallas", "numpy"):
            out["engine"][f"{backend}/n{n}"] = bench_engine(
                n, backend, args.iters)
    header(f"per-wave batched leasing (B={args.wave} requesters sharing "
           f"a prefix)")
    for n in sizes:
        for backend in ("pallas", "numpy"):
            out["wave"][f"{backend}/n{n}"] = bench_wave(
                n, backend, args.iters, args.wave, blocks_per_req=8)
    header("paged-vs-dense decode (continuous-batch step, reduced model; "
           "moe row pages dual cache stacks through named pools)")
    for key, arch in decode_rows.items():
        # the decode rows feed the gate's tracked ratio: a 2-iteration
        # timing swings ~2x run to run on CPU, so floor the repetitions
        # high enough that the ratio is a property of the code, not of
        # the scheduler's mood
        out["decode"][key] = bench_decode(max(6, args.iters // 2),
                                          args.decode_steps, arch=arch)
    header("sharded lease directory (remote-vs-local waves, msgs/wave vs "
           "shard count, cross-host prefix reuse)")
    out["directory"] = bench_directory(sizes[-1], args.iters)
    for n in sizes:
        k = out["engine"][f"pallas/n{n}"]
        m = out["engine"][f"numpy/n{n}"]
        print(f"# n={n}: pallas/numpy read ratio "
              f"{k['read_blocks_per_s'] / m['read_blocks_per_s']:.3f}, "
              f"write ratio "
              f"{k['write_blocks_per_s'] / m['write_blocks_per_s']:.3f}, "
              f"wave speedup pallas "
              f"{out['wave'][f'pallas/n{n}']['speedup']:.2f}x / numpy "
              f"{out['wave'][f'numpy/n{n}']['speedup']:.2f}x")
    for key, d in out["decode"].items():
        print(f"# paged decode [{key}] {d['paged_us_per_step']:.0f} us/step "
              f"vs dense {d['dense_us_per_step']:.0f} us/step "
              f"({d['paged_over_dense']:.2f}x)")
    return out


def tracked_ratios(out):
    """The gate's dimensionless ratios: key -> (value, higher_is_better,
    tolerance).

    Only ratios are gated -- absolute microseconds drift with the CI
    runner's load, but batched-vs-sequential speedups, kernel-vs-mirror
    throughput ratios, and the paged-over-dense step ratio measure the
    same machine against itself.  Engine/wave ratios are tracked at the
    LARGEST measured table only: the small-table variants run in
    microseconds where scheduler jitter dominates any real regression.
    Decode rows carry :data:`DECODE_TOLERANCE` (see its comment).
    """
    r = {}
    sizes = sorted({int(k.split("/n")[1]) for k in out.get("engine", {})}
                   | {int(k.split("/n")[1]) for k in out.get("wave", {})})
    if sizes:
        n = sizes[-1]
        for backend in ("pallas", "numpy"):
            w = out.get("wave", {}).get(f"{backend}/n{n}")
            if w:
                r[f"wave_speedup/{backend}/n{n}"] = (
                    w["speedup"], True, CHECK_TOLERANCE)
        p = out.get("engine", {}).get(f"pallas/n{n}")
        m = out.get("engine", {}).get(f"numpy/n{n}")
        if p and m:
            r[f"engine_read_ratio/n{n}"] = (
                p["read_blocks_per_s"] / m["read_blocks_per_s"], True,
                CHECK_TOLERANCE)
            r[f"engine_write_ratio/n{n}"] = (
                p["write_blocks_per_s"] / m["write_blocks_per_s"], True,
                CHECK_TOLERANCE)
    for k, d in out.get("decode", {}).items():
        r[f"decode_paged_over_dense/{k}"] = (
            d["paged_over_dense"], False, DECODE_TOLERANCE)
    # sharded-directory counters: deterministic (message ledgers, not
    # wall-clock), so any drift past tolerance is a real protocol change.
    # The remote/local latency ratio is recorded in the JSON but NOT
    # gated -- it is wall-clock.
    d = out.get("directory")
    if d:
        for sk, v in sorted(d.get("msgs_per_wave", {}).items()):
            r[f"dir_msgs_per_wave/{sk}"] = (
                float(v["msgs"]), False, CHECK_TOLERANCE)
        rs = d.get("reuse")
        if rs:
            r["dir_reuse_fraction"] = (rs["fraction"], True,
                                       CHECK_TOLERANCE)
            r["dir_msgs_per_reused_block"] = (
                rs["msgs_per_reused_block"], False, CHECK_TOLERANCE)
        dg = d.get("disagg")
        if dg:
            r["dir_decode_msgs_per_tick"] = (
                dg["decode_msgs_per_tick"], False, CHECK_TOLERANCE)
        # Tardis 2.0 replays: adaptive leases must keep thinning the
        # decode pod's renewal traffic (sc), and tso must keep it at
        # zero -- any new message past tolerance is a protocol change
        for suffix in ("pred_sc", "pred_tso"):
            dg = d.get(f"disagg_{suffix}")
            if dg:
                r[f"dir_decode_renewal_msgs_per_tick/{suffix}"] = (
                    dg["decode_msgs_per_tick"], False, CHECK_TOLERANCE)
    return r


def check_against(baseline, runs):
    """Compare the best of ``runs`` against the baseline's tracked ratios.

    Returns ``(regressions, best)``: the regressions (worse than the
    baseline by more than the key's tolerance, or a baseline key the
    current run did not measure at all -- a silently-dropped row must fail
    the gate, not sail through green) and the folded best-of-runs ratio
    per key (reused verbatim for the artifact's ``gate`` block so the
    JSON reconstructs this verdict).
    """
    base = tracked_ratios(baseline)
    best = {}
    for out in runs:
        for k, (v, hib, tol) in tracked_ratios(out).items():
            if k not in best:
                best[k] = (v, hib)
            else:
                best[k] = (max(best[k][0], v) if hib
                           else min(best[k][0], v), hib)
    regressions = []
    for k, (bv, hib, tol) in sorted(base.items()):
        if k not in best:
            print(f"# bench gate: {k:44s} baseline {bv:8.3f} current "
                  f" missing [REGRESSION]")
            regressions.append((k, bv, None))
            continue
        cv = best[k][0]
        bad = cv < bv / tol if hib else cv > bv * tol
        mark = "REGRESSION" if bad else "ok"
        print(f"# bench gate: {k:44s} baseline {bv:8.3f} current {cv:8.3f} "
              f"[{mark}, tol {tol:.2f}x]")
        if bad:
            regressions.append((k, bv, cv))
    return regressions, {k: v for k, (v, _h) in best.items()}


def main():
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    import jax

    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", default="4096,16384,65536")
    ap.add_argument("--iters", type=int, default=20)
    ap.add_argument("--wave", type=int, default=8,
                    help="requesters per wave for the batched-read bench")
    ap.add_argument("--decode-steps", type=int, default=8,
                    help="decode steps per timed run (paged-vs-dense)")
    ap.add_argument("--json", default=None,
                    help="machine-readable output path ('' to skip); "
                         "defaults to BENCH_lease.json, or bench_ci.json "
                         "under --check-against so a gate run can never "
                         "clobber a checked-in baseline")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes/iters so CI exercises every bench "
                         "path in seconds (writes no JSON unless checking)")
    ap.add_argument("--check-against", default="",
                    help="baseline JSON: fail (exit 1) if any tracked "
                         "ratio regresses past its tolerance vs it (25%%; "
                         "decode rows 2x -- see DECODE_TOLERANCE).  Runs "
                         "the BASELINE's gated shapes (best of "
                         "--check-repeats passes) so keys line up")
    ap.add_argument("--check-repeats", type=int, default=3,
                    help="measurement passes for the gate (best-of, to "
                         "shave CI runner noise)")
    args = ap.parse_args()
    if args.json is None:
        args.json = "bench_ci.json" if args.check_against \
            else "BENCH_lease.json"
    if args.smoke and not args.check_against:
        args.sizes, args.iters, args.decode_steps = "1024", 2, 2
        args.json = ""

    baseline = None
    decode_rows = dict(DECODE_ROWS)
    if args.check_against:
        with open(args.check_against) as f:
            baseline = json.load(f)
        # measure exactly the baseline's gated shapes AND iteration regime
        # so every key lines up and the timing amortization matches (a
        # 2-iter smoke against a 10-iter baseline flags pure noise); only
        # the largest table is gated, so only it is re-measured
        bsizes = sorted({int(k.split("/n")[1]) for k in baseline["engine"]})
        args.sizes = str(bsizes[-1])
        args.iters = int(baseline.get("iters", args.iters))
        decode_rows = {k: DECODE_ROWS[k] for k in baseline.get("decode", {})
                       if k in DECODE_ROWS}
        if os.path.abspath(args.json or "") \
                == os.path.abspath(args.check_against):
            args.json = "bench_ci.json"   # never clobber the baseline
        plat = jax.default_backend()
        if baseline.get("platform") != plat:
            print(f"# bench gate: baseline platform "
                  f"{baseline.get('platform')} != {plat}; ratios are not "
                  f"comparable, skipping the gate")
            baseline = None

    sizes = [int(s) for s in args.sizes.split(",")]
    repeats = args.check_repeats if baseline else 1
    runs = [run_suite(args, sizes, decode_rows) for _ in range(repeats)]
    regressions = best = None
    if baseline:
        regressions, best = check_against(baseline, runs)
    if args.json:
        out = dict(runs[0])
        if baseline:
            # the artifact must reconstruct the VERDICT, which is computed
            # from the best-of-repeats ratios, not from run 0's raw times
            out["gate"] = {
                "baseline": args.check_against,
                "repeats": repeats,
                # per-key tolerances: decode rows gate looser than the
                # engine/wave ratios, and the artifact must reconstruct
                # the verdict exactly
                "tolerances": {k: t for k, (_v, _h, t)
                               in tracked_ratios(baseline).items()},
                "baseline_ratios": {k: v for k, (v, _h, _t)
                                    in tracked_ratios(baseline).items()},
                "best_ratios": best,
                "per_run_ratios": [
                    {k: v for k, (v, _h, _t) in tracked_ratios(run).items()}
                    for run in runs],
                "regressions": [list(r) for r in regressions],
            }
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2)
        print(f"# wrote {args.json}")
    if baseline:
        if regressions:
            for k, bv, cv in regressions:
                cur = "unmeasured" if cv is None else f"{cv:.3f}"
                print(f"# bench gate FAILED: {k} regressed "
                      f"{bv:.3f} -> {cur} (past tolerance, or dropped)")
            sys.exit(1)
        print("# bench gate: all tracked ratios within tolerance of "
              "baseline")


if __name__ == "__main__":
    main()
