"""Benchmark driver: one function per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV rows.  REPRO_BENCH_QUICK=1 shrinks
core counts / trace scales for CI; the full run reproduces the paper's
figures at 64 cores (Fig. 8 at 16/256).
"""
import os
import sys


def main() -> None:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks import paper_figs, roofline

    results = {}
    for fn in paper_figs.ALL:
        results[fn.__name__] = fn()

    dry = os.environ.get("REPRO_DRYRUN_JSON", "dryrun.json")
    if os.path.exists(dry):
        roofline.report(dry, out_path="roofline.json")
    else:
        print(f"# roofline: {dry} not found (run repro.launch.dryrun first)")

    # headline claim checks (printed, asserted loosely in tests)
    f4 = results.get("fig4_throughput", {})
    print(f"# CLAIM tardis~=msi: {f4.get('tardis_vs_msi'):.3f} (paper 1.00)")
    print(f"# CLAIM spec-off slower: {f4.get('nospec_vs_msi'):.3f} (paper 0.93)")
    print(f"# CLAIM traffic: {f4.get('traffic_vs_msi'):.3f} (paper 1.19-1.21)")
    f5 = results.get("fig5_renew", {})
    print(f"# CLAIM misspec<1%: {f5.get('avg_misspec'):.5f}")


if __name__ == "__main__":
    main()
