"""Shared benchmark plumbing: cached simulator runs + CSV reporting.

Output convention (required by run.py): one CSV row per measurement,
``name,us_per_call,derived`` where us_per_call is the wall-clock of the
simulator invocation and ``derived`` carries the paper-comparable figure
(normalized throughput / traffic / rate...).
"""
from __future__ import annotations

import os
import time
from typing import Dict, Tuple

from repro.core import SimConfig, make_trace, simulate

QUICK = os.environ.get("REPRO_BENCH_QUICK", "0") == "1"

# paper benchmark order (Fig. 4)
BENCHES = ["fmm", "barnes", "cholesky", "volrend", "ocean_c", "ocean_nc",
           "fft", "radix", "lu_c", "lu_nc", "water_nsq", "water_sp"]
SUBSET = ["fmm", "cholesky", "volrend", "fft", "lu_c", "water_nsq"]

N_CORES = 16 if QUICK else 64
SCALE = 0.2 if QUICK else 0.25     # trace length multiplier (1 CPU budget)
MAX_STEPS = 4_000_000

_cache: Dict[Tuple, Tuple] = {}


def run(bench: str, proto: str, n_cores: int = None, scale: float = None,
        **cfg_kw):
    """Memoized simulate() -> (SimResult, wall_seconds)."""
    n_cores = n_cores or N_CORES
    scale = scale if scale is not None else SCALE
    key = (bench, proto, n_cores, scale, tuple(sorted(cfg_kw.items())))
    if key not in _cache:
        tr = make_trace(bench, n_cores, scale=scale)
        cfg = SimConfig(max_steps=MAX_STEPS, **cfg_kw)
        t0 = time.time()
        res = simulate(tr, proto, cfg)
        _cache[key] = (res, time.time() - t0)
    return _cache[key]


def row(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def header(title: str):
    print(f"# --- {title} ---", flush=True)
