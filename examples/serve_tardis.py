"""End-to-end serving driver: continuous batching through paged pool KV.

Serves a tinyllama-family model on N replicas with a stream of requests
sharing a common system-prompt prefix; every KV byte decode touches lives
in LeaseEngine pool pages (decode budgets are randomized per request, so
streams finish independently and the scheduler admits new requests into
running batches as pages free up).  Hot-swaps the weights mid-stream (no
invalidation broadcast) and prints the coherence ledger: renewals,
data-less renewal savings, prefix-KV block reuse through the LeaseEngine
(Pallas ``tardis_lease`` kernels), pool occupancy / page churn, and what a
full-map directory would have done on the same stream.

Run:  PYTHONPATH=src python examples/serve_tardis.py [--replicas 3]
      (--check makes it a CI smoke: asserts the prefix-reuse path fired
       and a request was admitted mid-batch)
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced
from repro.core import CONSISTENCY_MODELS, CoherencePolicy
from repro.models import init_params
from repro.runtime import Request, ServingCluster


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--replicas", type=int, default=3)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--prefix-len", type=int, default=32,
                    help="shared system-prompt tokens per request")
    ap.add_argument("--prefix-block", type=int, default=8,
                    help="tokens per leased prefix-KV block")
    ap.add_argument("--no-prefix-reuse", action="store_true")
    ap.add_argument("--consistency", choices=CONSISTENCY_MODELS,
                    default="sc",
                    help="prefix-KV memory model (tso/rc skip renewals of "
                         "expired read-only leases)")
    ap.add_argument("--predictor", action="store_true",
                    help="adaptive (Tardis 2.0) per-block lease prediction")
    ap.add_argument("--check", action="store_true",
                    help="assert the LeaseEngine prefix path fired (CI)")
    args = ap.parse_args()

    cfg = reduced(get_arch("tinyllama-1.1b"), n_layers=args.layers,
                  d_model=args.d_model, d_ff=args.d_model * 4, vocab=512)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key, jnp.float32)
    print(f"model: {cfg.name}-reduced {args.layers}L d={args.d_model} "
          f"({sum(p.size for p in jax.tree.leaves(params))/1e6:.1f}M params)")

    policy = CoherencePolicy(consistency=args.consistency, lease=16,
                             predictor=args.predictor)
    cluster = ServingCluster(cfg, lambda: params,
                             n_replicas=args.replicas, lease=8,
                             prefix_block_tokens=args.prefix_block,
                             policy=policy,
                             prefix_reuse=not args.no_prefix_reuse,
                             cache_len=96, selfinc_period=4,
                             max_batch=3)
    rng = np.random.default_rng(0)
    system_prompt = rng.integers(1, cfg.vocab,
                                 args.prefix_len).astype(np.int32)
    # randomized decode budgets: streams finish independently, so the
    # continuous-batching scheduler admits later requests mid-batch
    reqs = [Request(i, np.concatenate(
                [system_prompt,
                 rng.integers(1, cfg.vocab, rng.integers(4, 24))
                 .astype(np.int32)]),
                max_new=int(rng.integers(1, args.max_new + 1)))
            for i in range(args.requests)]

    t0 = time.time()
    half = len(reqs) // 2
    done1, _ = cluster.run(reqs[:half])
    # live weight hot-swap between waves: Tardis jumps ahead, nobody blocks
    new_params = jax.tree.map(lambda p: p * 0.999, params)
    wts = cluster.publish_weights(new_params)
    print(f"published new weight version at logical time {wts} "
          "(zero invalidation messages)")
    done2, report = cluster.run(reqs[half:])
    dt = time.time() - t0

    n_tok = sum(len(r.output) for r in reqs)
    print(f"\nserved {len(reqs)} requests / {n_tok} tokens "
          f"on {args.replicas} replicas in {dt:.1f}s "
          f"({n_tok/dt:.1f} tok/s on CPU)")
    print("\ncoherence ledger (Tardis):")
    for k, v in report.items():
        print(f"  {k:28s} {v}")
    saved = report["bytes_saved_by_renewals"]
    print(f"\n=> data-less renewals avoided re-sending "
          f"{saved/1e6:.1f} MB of weights/KV;")
    print(f"=> prefix-KV reuse: {report['prefix_block_hits']} block hits "
          f"({report['prefix_tokens_reused']} tokens), "
          f"{report['prefix_data_less_renewals']} data-less renewals via "
          "the LeaseEngine kernel;")
    print(f"=> paged-KV pool: prefill skipped "
          f"{report['prefix_prefill_tokens_skipped']} prompt tokens "
          f"({report['prefix_flops_saved']/1e9:.2f} GFLOPs saved) in "
          f"{report['prefix_read_dispatches']} read + "
          f"{report['prefix_write_dispatches']} write wave-batched engine "
          "dispatches;")
    print(f"=> paged decode: {report['kv_tokens_appended']} token rows "
          f"appended into pages, {report['decode_block_reads']} decode-time "
          f"block reads ({report['decode_local_hits']} local hits / "
          f"{report['decode_renewals']} renewals), "
          f"{report['paged_mid_batch_admissions']} mid-batch admissions, "
          f"peak {report['pool_page_peak']} pages in use;")
    print(f"=> a full-map directory would have tracked "
          f"{report['directory_peak_sharers']} sharers and sent "
          f"{report['directory_would_invalidate']} invalidations.")
    sample = reqs[0]
    print(f"\nsample completion (req 0): {sample.output.tolist()}")

    if args.check:
        assert all(r.done for r in reqs)
        assert report["prefix_block_hits"] > 0, "prefix reuse never hit"
        assert report["prefix_data_less_renewals"] > 0, \
            "no data-less renewals on the LeaseEngine path"
        assert report["data_less_renewals"] > 0
        assert report["prefix_flops_saved"] > 0, \
            "paged-KV pool never skipped prefill on a hit"
        assert report["prefix_kv_blocks_read"] > 0
        # wave batching: never more engine read dispatches than admission
        # groups + in-flight renewal rounds
        n_waves = -(-args.requests // args.replicas)
        assert report["prefix_read_dispatches"] <= n_waves
        # continuous batching: decode runs through pool pages, a request
        # joined a running batch, and everything was released
        assert report["kv_tokens_appended"] > 0
        assert report["paged_mid_batch_admissions"] > 0, \
            "scheduler never admitted a request mid-batch"
        assert report["pool_pages_free"] == cluster.n_decode_pages, \
            "page leak: not every page returned to the free list"
        print("check: serving smoke OK (prefix reuse + data-less renewals "
              "+ paged-KV prefill skip + mid-batch admission)")


if __name__ == "__main__":
    main()
