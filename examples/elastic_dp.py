"""Elastic data-parallel training with Tardis-leased parameters.

A learner publishes parameter versions into a TardisStore while the worker
pool grows and shrinks every few steps.  Workers read *leased* parameter
copies (bounded logical staleness -- the paper's deferred update propagation
put to work), renew on expiry (data-less when the learner hasn't published),
and need zero protocol action to leave.

Run:  PYTHONPATH=src python examples/elastic_dp.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced
from repro.models import init_params, loss_fn
from repro.runtime import ElasticTrainer


def main():
    cfg = reduced(get_arch("tinyllama-1.1b"), n_layers=2, d_model=128,
                  vocab=512)
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)

    def grad_fn(p, b):
        return jax.value_and_grad(lambda pp: loss_fn(cfg, pp, b))(p)

    def make_batch(step, worker):
        rng = np.random.default_rng(step * 1000 + worker)
        t = rng.integers(0, cfg.vocab, (4, 64)).astype(np.int32)
        return {"tokens": jnp.asarray(t), "labels": jnp.asarray(t)}

    # worker pool: 2 -> 4 -> 1 -> 3 (simulated preemptions / scale-ups)
    schedule = [2, 2, 3, 4, 4, 1, 1, 2, 3, 3, 3, 2, 2, 2, 2, 2]
    et = ElasticTrainer(params, grad_fn, make_batch, lease=2, lr=3e-3)
    rep = et.run(len(schedule), schedule=lambda s: schedule[s])

    print(f"steps: {rep.steps}, worker joins: {rep.joins}, "
          f"leaves: {rep.leaves}")
    print(f"loss: {rep.losses[0]:.3f} -> {np.mean(rep.losses[-4:]):.3f}")
    print(f"parameter renewals: {rep.renewals} "
          f"({rep.data_less} data-less)")
    print(f"max logical staleness observed: {rep.max_staleness} "
          f"(lease bound: workers can never be further behind than "
          f"lease+publish jump)")
    print("no sharer lists, no invalidation broadcasts, no barrier on "
          "scale-down: O(log N) metadata per object (the paper's claim, "
          "applied to the training control plane)")


if __name__ == "__main__":
    main()
