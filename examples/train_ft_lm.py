"""End-to-end training driver: a ~100M-param tinyllama-family LM trained for
a few hundred steps with the full production loop -- sharded checkpoints,
an injected node failure + restart, straggler accounting, int8 gradient
compression with error feedback, and microbatch accumulation.

Default size is CPU-friendly; ``--full`` trains the ~100M configuration for
200 steps (expect ~20-40 min on CPU).

Run:  PYTHONPATH=src python examples/train_ft_lm.py [--full]
"""
import argparse
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch, reduced
from repro.models import init_params
from repro.runtime import TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="~100M params, 200 steps")
    ap.add_argument("--steps", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()

    if args.full:
        cfg = reduced(get_arch("tinyllama-1.1b"), n_layers=8, d_model=768,
                      n_heads=12, n_kv_heads=4, d_ff=2048, d_head=64,
                      vocab=32000)
        steps = args.steps or 200
        batch, seq = 8, 256
    else:
        cfg = reduced(get_arch("tinyllama-1.1b"), n_layers=4, d_model=256,
                      n_heads=8, n_kv_heads=4, d_ff=512, d_head=32,
                      vocab=2048)
        steps = args.steps or 60
        batch, seq = 8, 128

    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"training {n_params/1e6:.1f}M params for {steps} steps "
          f"(batch={batch}, seq={seq})")

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="tardis_ckpt_")
    tc = TrainConfig(
        steps=steps, ckpt_dir=ckpt_dir, ckpt_every=max(10, steps // 8),
        batch=batch, seq=seq, grad_compression=True, n_micro=2,
        fail_at_step=steps // 2,         # inject a crash mid-run
        log_every=10)

    stragglers = []
    t0 = time.time()
    out = train(cfg, params, tc,
                on_straggler=lambda s, dt: stragglers.append((s, dt)),
                on_metrics=lambda s, m: print(
                    f"  step {s:4d} loss {m['loss']:.4f} "
                    f"gnorm {m['grad_norm']:.2f} {m['step_s']*1e3:.0f} ms"))
    dt = time.time() - t0

    print(f"\ndone in {dt/60:.1f} min: loss {out['losses'][0]:.3f} -> "
          f"{out['losses'][-1]:.3f}")
    print(f"recovered from {out['restarts']} injected failure(s) via "
          f"checkpoint restore; {out['stragglers']} straggler steps flagged")
    print(f"checkpoints in {ckpt_dir}")
    assert out["losses"][-1] < out["losses"][0], "did not learn!"


if __name__ == "__main__":
    main()
