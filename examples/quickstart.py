"""Quickstart: the Tardis protocol end-to-end in five minutes.

1. run the paper's Listing-1 litmus through the coherence simulator,
2. compare Tardis vs. full-map MSI on a SPLASH-2-like workload,
3. use the TardisStore to share versioned objects without invalidations,
4. train a tiny LM for a few steps with the fault-tolerant loop.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import SimConfig, make_trace, simulate
from repro.core.check import check_sc
from repro.core.store import Replica, TardisStore
from repro.core.traces import _Builder
from repro.configs import get_arch, reduced
from repro.models import init_params
from repro.runtime import TrainConfig, train


def litmus():
    print("== 1. Listing-1 litmus (store A; load B || store B; load A) ==")
    b = _Builder(2)
    b.store(0, 0); b.load(0, 1)
    b.store(1, 1); b.load(1, 0)
    tr = b.build(4, "litmus")
    res = simulate(tr, "tardis", SimConfig(), log=True)
    check_sc(res.log, 2)
    loads = {(int(c), int(a)): int(v) for c, a, v, k in zip(
        res.log["core"], res.log["addr"], res.log["ver"], res.log["kind"])
        if k == 0}
    print(f"   loads observed versions: {loads}  (A=B=0 impossible)")
    print("   sequential consistency: VERIFIED\n")


def protocol_comparison():
    print("== 2. Tardis vs MSI on a volrend-like workload (16 cores) ==")
    tr = make_trace("volrend", 16, scale=0.5)
    msi = simulate(tr, "directory", SimConfig())
    trd = simulate(tr, "tardis", SimConfig())
    print(f"   MSI   : {msi.cycles} cycles, traffic {msi.traffic:.0f}")
    print(f"   Tardis: {trd.cycles} cycles, traffic {trd.traffic:.0f} "
          f"({trd.stats['n_renew']:.0f} renewals, "
          f"{trd.stats['n_renew_ok']:.0f} data-less)")
    print(f"   relative throughput {msi.cycles / trd.cycles:.3f} "
          f"(paper: ~1.00), traffic x{trd.traffic / msi.traffic:.2f}\n")


def store_demo():
    print("== 3. TardisStore: invalidation-free version sharing ==")
    store = TardisStore(lease=4)
    writer = Replica(store, "trainer")
    readers = [Replica(store, f"r{i}", selfinc_period=1) for i in range(3)]
    writer.write("weights", "v1", nbytes=1 << 20)
    for r in readers:
        r.read("weights")
    writer.write("weights", "v2", nbytes=1 << 20)   # no broadcast!
    for _ in range(8):
        vals = [r.read("weights") for r in readers]
    print(f"   all readers converged to: {set(vals)}")
    s = store.stats
    print(f"   renewals={s.renews} data-less={s.renew_data_less} "
          f"payload transfers={s.payload_transfers} "
          f"(directory would have sent {s.dir_invalidations} invalidations)\n")


def tiny_training():
    print("== 4. fault-tolerant training (tiny LM, 20 steps) ==")
    cfg = reduced(get_arch("tinyllama-1.1b"), n_layers=2, d_model=64,
                  vocab=128)
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    out = train(cfg, params, TrainConfig(steps=20, batch=4, seq=32))
    print(f"   loss: {out['losses'][0]:.3f} -> {out['losses'][-1]:.3f}\n")


if __name__ == "__main__":
    litmus()
    protocol_comparison()
    store_demo()
    tiny_training()
    print("quickstart complete.")
