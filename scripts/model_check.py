#!/usr/bin/env python
"""Exhaustively model-check the Tardis protocol on a bounded config.

Enumerates every reachable state of the guarded-action model of Tables
I-III (repro.analysis), checks the proof's invariants on each state and
transition, and (by default) cross-validates every distinct rule
application against the shipped ``core.protocol`` scalars and the numpy
``LeaseEngine``.  Exits non-zero on any violation or if the state space
fails to close under the cap.

The CI fast lane runs the 2-core/1-block config (a few seconds)::

    PYTHONPATH=src python scripts/model_check.py --cores 2 --blocks 1

Bigger sweeps (3 cores, 2 blocks) are recorded in EXPERIMENTS.md.
"""
import argparse
import sys

from repro.analysis import Bridge, Config, TardisModel, explore


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--cores", type=int, default=2)
    ap.add_argument("--blocks", type=int, default=1)
    ap.add_argument("--lease", type=int, default=2)
    ap.add_argument("--ts-bits", type=int, default=2,
                    help="rebase threshold exponent (bounds the ts domain)")
    ap.add_argument("--consistency", choices=("sc", "tso", "rc"),
                    default="sc",
                    help="forbidden-outcome predicates to enforce over the "
                    "same state graph: sc = all load checks, tso waives the "
                    "beyond-lease-end check, rc also waives the stale-"
                    "inside-newer-interval check")
    ap.add_argument("--no-self-inc", action="store_true",
                    help="disable spontaneous pts advance")
    ap.add_argument("--no-pw-opt", action="store_true",
                    help="disable the private-write optimization (IV-C), "
                    "exercising the store_hit_exclusive rule instead")
    ap.add_argument("--no-symmetry", action="store_true",
                    help="disable the core/block permutation quotient")
    ap.add_argument("--no-bridge", action="store_true",
                    help="skip cross-validation against core.protocol and "
                    "the numpy LeaseEngine")
    ap.add_argument("--max-states", type=int, default=2_000_000)
    args = ap.parse_args(argv)

    cfg = Config(n_cores=args.cores, n_blocks=args.blocks,
                 lease=args.lease, ts_bits=args.ts_bits,
                 self_inc=not args.no_self_inc,
                 pw_opt=not args.no_pw_opt,
                 symmetry=not args.no_symmetry,
                 consistency=args.consistency)
    model = TardisModel(cfg)
    bridge = None if args.no_bridge else Bridge(cfg.lease)
    res = explore(model, bridge=bridge, max_states=args.max_states)

    print(f"config: {cfg}")
    print(f"states: {res.n_states}  transitions: {res.n_transitions}  "
          f"depth: {res.max_depth}  wall: {res.wall_time:.1f}s")
    print("rules fired: " + ", ".join(
        f"{k}={v}" for k, v in sorted(res.rule_counts.items())))
    if bridge is not None:
        print("bridge (distinct replays): " + ", ".join(
            f"{k}={v}" for k, v in sorted(res.bridge_counts.items())))
    if not res.closed:
        print(f"FAIL: state space did not close under "
              f"--max-states {args.max_states}", file=sys.stderr)
        return 2
    if res.violations:
        print(f"FAIL: {len(res.violations)} invariant violation(s):",
              file=sys.stderr)
        for v in res.violations:
            print(str(v), file=sys.stderr)
        return 1
    print("OK: state space closed, all invariants hold "
          "(wts<=rts, single owner, value-ts consistency, pts "
          "monotonicity, no deadlock)" +
          ("" if args.no_bridge else ", cross-validated against "
           "core.protocol and the numpy LeaseEngine"))
    return 0


if __name__ == "__main__":
    sys.exit(main())
