#!/usr/bin/env bash
# Tier-1 verification: full test suite + a 1-cell dry-run smoke.
#
#   bash scripts/check.sh           # everything
#   bash scripts/check.sh -k store  # pass extra args through to pytest
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# protocol-conformance fast lane: the SC litmus suite + lease-engine
# differentials run first so Tables I-III regressions surface in seconds,
# before the full tier-1 run (which collects them again as part of the
# whole suite).  CI runs this lane as its own named step and sets
# REPRO_SKIP_FAST_LANE=1 here so the *dedicated* lane isn't repeated.
if [ -z "${REPRO_SKIP_FAST_LANE:-}" ]; then
    # static protocol lints: table ownership + kernel ref mirrors (stdlib
    # AST lint, always available); ruff when installed (CI pins it)
    python scripts/lint_protocol.py
    if command -v ruff >/dev/null 2>&1; then
        ruff check src
    else
        echo "ruff not installed; skipping (CI runs the pinned version)"
    fi
    # bounded exhaustive model check: Tables I-III close under
    # 2 cores / 1 block with every transition cross-validated against
    # core.protocol and the LeaseEngine numpy mirror (seconds); the tso
    # lane re-closes the space with the store->load relaxation admitted
    # (stale-read windows the weaker model permits must stay bounded)
    python scripts/model_check.py --cores 2 --blocks 1 --lease 2 --ts-bits 2
    python scripts/model_check.py --cores 2 --blocks 1 --lease 2 --ts-bits 2 \
        --consistency tso
    python -m pytest -q tests/test_litmus.py tests/test_lease_engine.py \
        tests/test_model_check.py tests/test_coherence_policy.py
fi

python -m pytest -x -q "$@"

# 1-cell lower+compile+cost-analysis smoke on the production mesh shapes
# (decode_32k is the cheapest cell; --no-hlo skips HLO text parsing).
out="$(mktemp -t dryrun_check_XXXX.json)"
python -m repro.launch.dryrun --mesh single --archs tinyllama-1.1b \
    --shapes decode_32k --no-hlo --out "$out"
python - "$out" <<'EOF'
import json, sys
recs = json.load(open(sys.argv[1]))
ok = [r for r in recs if r["status"] == "ok"]
assert ok, f"no ok cells: {recs}"
assert any(r.get("cost_analysis", {}).get("flops", 0) > 0 for r in ok), \
    f"no nonzero flops: {recs}"
print(f"dryrun smoke: {len(ok)} ok cell(s), nonzero flops")
EOF

# serving smoke: tinyllama replicas with continuous-batching paged decode
# through the LeaseEngine pool (--check asserts prefix hits, data-less
# renewals, and a mid-batch admission).  TARDIS_SANITIZE=1 runs the whole
# smoke with the lease sanitizer asserting after every engine transition.
TARDIS_SANITIZE=1 python examples/serve_tardis.py --replicas 2 \
    --requests 16 --max-new 4 --layers 2 --d-model 64 --check

# moe serving smoke: kimi-k2 scaled-down pages BOTH cache stacks through
# the engine's named pools -- the per-stack occupancy counters must move
python -m repro.launch.serve --arch kimi-k2-1t-a32b --replicas 2 \
    --requests 6 --max-new 2 --max-batch 2 | tee /tmp/serve_moe_check.out
grep -Eq "pool_tokens_appended_dense +[1-9]" /tmp/serve_moe_check.out
grep -Eq "pool_tokens_appended_moe +[1-9]" /tmp/serve_moe_check.out

# multi-host serving smoke: 2 simulated hosts share one sharded lease
# directory; the system prompt prefilled on host 0 must serve host 1
# suffix-only (skipped prefill tokens + migrated pages) with ZERO
# multicast/invalidation traffic, under the migration sanitizer
TARDIS_SANITIZE=1 python -m repro.launch.serve --arch tinyllama-1.1b \
    --hosts 2 --replicas 1 --requests 6 --max-new 2 --prefix-len 16 \
    --prefix-block 4 --decode-pages 64 --max-pages 16 --max-batch 2 \
    | tee /tmp/serve_xhost_check.out
grep -Eq "host1_prefix_prefill_tokens_skipped +[1-9]" /tmp/serve_xhost_check.out
grep -Eq "host1_xhost_pages_fetched +[1-9]" /tmp/serve_xhost_check.out
grep -Eq "xhost_multicasts +0" /tmp/serve_xhost_check.out
grep -Eq "xhost_invalidation_msgs +0" /tmp/serve_xhost_check.out

# disaggregated serving smoke: 1 prefill pod + 1 decode pod over the same
# directory.  The decode pod must perform ZERO cold-prefix prefills (the
# router forwards cold work to the prefill pod; the publish-then-notify
# wake hands the stream back for suffix-only serving), still with zero
# multicast/invalidation traffic, under the sanitizers.
TARDIS_SANITIZE=1 python -m repro.launch.serve --arch tinyllama-1.1b \
    --roles prefill,decode --replicas 1 --requests 6 --max-new 2 \
    --prefix-len 16 --prefix-block 4 --decode-pages 64 --max-pages 16 \
    --max-batch 2 | tee /tmp/serve_disagg_check.out
grep -Eq "host1_role_cold_prefills +0" /tmp/serve_disagg_check.out
grep -Eq "host0_role_prefill_jobs +[1-9]" /tmp/serve_disagg_check.out
grep -Eq "host1_prefix_prefill_tokens_skipped +[1-9]" /tmp/serve_disagg_check.out
grep -Eq "xhost_notifies +[1-9]" /tmp/serve_disagg_check.out
grep -Eq "xhost_multicasts +0" /tmp/serve_disagg_check.out

# bench smoke: every lease_bench path (engine, wave, paged-vs-dense
# decode) runs end to end so the bench code cannot rot.
python benchmarks/lease_bench.py --smoke

echo "check.sh: all green"
