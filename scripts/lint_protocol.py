#!/usr/bin/env python
"""Protocol-boundary AST lint.

Two repo-specific rules, enforced in scripts/check.sh and CI:

1. **Table ownership** -- only ``src/repro/core/`` may mutate the
   ``(wts, rts)`` timestamp tables directly.  Outside ``core/`` any
   assignment (plain, augmented, annotated, or through a subscript) whose
   target is an attribute named ``wts`` / ``rts`` / ``_wts`` / ``_rts``
   is flagged: everything else must go through the ``LeaseEngine`` /
   ``protocol`` APIs (or the ``set_tables`` verification seam), or the
   invariants the model checker proves stop meaning anything.

2. **Kernel oracles** -- every public op in ``kernels/*/ops.py`` must
   have a ``<name>_ref`` mirror in the sibling ``ref.py`` whose
   parameters are a same-order prefix of the op's, with any op-only
   extras (``interpret``, block sizes, ...) defaulted -- so the
   differential tests can always call both sides with the same
   arguments.

Pure stdlib; no third-party imports.  Exits non-zero with one line per
finding.
"""
import ast
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
SRC = ROOT / "src" / "repro"
TS_NAMES = {"wts", "rts", "_wts", "_rts"}


def _attr_target(node):
    """The Attribute node a store target writes through, if any."""
    if isinstance(node, ast.Attribute):
        return node
    if isinstance(node, ast.Subscript):
        return _attr_target(node.value)
    if isinstance(node, ast.Starred):
        return _attr_target(node.value)
    return None


def check_table_mutation(path: Path, tree) -> list:
    findings = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign):
            targets = []
            for t in node.targets:
                targets += t.elts if isinstance(t, ast.Tuple) else [t]
        elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
            targets = [node.target]
        else:
            continue
        for t in targets:
            attr = _attr_target(t)
            if attr is not None and attr.attr in TS_NAMES:
                findings.append(
                    f"{path.relative_to(ROOT)}:{node.lineno}: mutates "
                    f"timestamp table attribute '.{attr.attr}' outside "
                    f"core/ (use the LeaseEngine/protocol API)")
    return findings


def _params(fn):
    """Ordered (name, has_default) for positional + kw-only params."""
    a = fn.args
    pos = list(a.posonlyargs) + list(a.args)
    out = []
    n_def = len(a.defaults)
    for k, arg in enumerate(pos):
        out.append((arg.arg, k >= len(pos) - n_def))
    for arg, d in zip(a.kwonlyargs, a.kw_defaults):
        out.append((arg.arg, d is not None))
    return out


def check_kernel_mirrors(kdir: Path) -> list:
    findings = []
    ops_path = kdir / "ops.py"
    ref_path = kdir / "ref.py"
    ops_tree = ast.parse(ops_path.read_text())
    if not ref_path.exists():
        return [f"{ops_path.relative_to(ROOT)}: kernel has no ref.py "
                f"oracle module"]
    ref_tree = ast.parse(ref_path.read_text())
    refs = {n.name: n for n in ref_tree.body
            if isinstance(n, ast.FunctionDef)}
    for node in ops_tree.body:
        if not isinstance(node, ast.FunctionDef) \
                or node.name.startswith("_"):
            continue
        where = f"{ops_path.relative_to(ROOT)}:{node.lineno}"
        mirror = refs.get(node.name + "_ref")
        if mirror is None:
            findings.append(
                f"{where}: public op '{node.name}' has no "
                f"'{node.name}_ref' mirror in ref.py")
            continue
        op_params = _params(node)
        ref_params = _params(mirror)
        op_names = [n for n, _ in op_params]
        ref_names = [n for n, _ in ref_params]
        if op_names[:len(ref_names)] != ref_names:
            findings.append(
                f"{where}: '{node.name}' params {op_names} do not start "
                f"with its ref mirror's params {ref_names}")
            continue
        extras = [n for n, d in op_params[len(ref_params):] if not d]
        if extras:
            findings.append(
                f"{where}: '{node.name}' op-only params {extras} need "
                f"defaults so the differential tests can call both sides "
                f"with the same arguments")
    return findings


def main() -> int:
    findings = []
    core = SRC / "core"
    for path in sorted(SRC.rglob("*.py")):
        if core in path.parents:
            continue
        findings += check_table_mutation(path, ast.parse(path.read_text()))
    for kdir in sorted((SRC / "kernels").iterdir()):
        if kdir.is_dir() and (kdir / "ops.py").exists():
            findings += check_kernel_mirrors(kdir)
    for f in findings:
        print(f, file=sys.stderr)
    if findings:
        print(f"lint_protocol: {len(findings)} finding(s)", file=sys.stderr)
        return 1
    print("lint_protocol: OK (table ownership + kernel ref mirrors)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
