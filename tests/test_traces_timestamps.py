"""Trace-generator well-formedness + base-delta compression properties."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import protocol as P
from repro.core import timestamps as T
from repro.core.traces import (BARRIER, END, SPIN, STORE, TRACE_GENERATORS,
                               make_trace)


@pytest.mark.parametrize("name", sorted(TRACE_GENERATORS))
def test_trace_wellformed(name):
    tr = make_trace(name, 8, scale=0.3)
    assert tr.op_type.shape == tr.op_addr.shape == tr.op_aux.shape
    assert (tr.op_addr >= 0).all() and (tr.op_addr < tr.n_addr).all()
    # every core's trace ends with the END sentinel
    for c in range(8):
        ops = tr.op_type[c]
        ends = np.where(ops == END)[0]
        assert len(ends) > 0
    # barriers appear for every core with matching ids
    bar_ids = [set(tr.op_aux[c][tr.op_type[c] == BARRIER]) for c in range(8)]
    assert all(b == bar_ids[0] for b in bar_ids)


@pytest.mark.parametrize("name", sorted(TRACE_GENERATORS))
def test_spin_targets_satisfiable(name):
    """Every spin_until(addr, k) must have >= k prior stores to addr
    somewhere in the trace (otherwise the simulation livelocks)."""
    tr = make_trace(name, 8, scale=0.3)
    n_stores = {}
    for c in range(tr.n_cores):
        for t, a in zip(tr.op_type[c], tr.op_addr[c]):
            if t == STORE:
                n_stores[int(a)] = n_stores.get(int(a), 0) + 1
    for c in range(tr.n_cores):
        for t, a, x in zip(tr.op_type[c], tr.op_addr[c], tr.op_aux[c]):
            if t == SPIN:
                # target version k requires at least k stores released after
                assert n_stores.get(int(a), 0) >= int(x), \
                    f"{name}: spin on {a} for v{x} but only " \
                    f"{n_stores.get(int(a), 0)} stores exist"


def test_trace_deterministic():
    a = make_trace("barnes", 8, seed=3, scale=0.3)
    b = make_trace("barnes", 8, seed=3, scale=0.3)
    np.testing.assert_array_equal(a.op_addr, b.op_addr)


ts_small = st.integers(min_value=0, max_value=1 << 22)


class TestCompression:
    @given(st.lists(st.tuples(ts_small, ts_small), min_size=1, max_size=32),
           st.integers(0, 1 << 22), st.sampled_from([8, 14, 20]))
    @settings(max_examples=100, deadline=None)
    def test_rebase_preserves_order_and_only_increases(self, pairs, base,
                                                       bits):
        wts = jnp.array([min(a, b) + base for a, b in pairs])
        rts = jnp.array([max(a, b) + base for a, b in pairs])
        state = jnp.full(len(pairs), P.SHARED)
        bts = jnp.int32(base)
        nb, nw, nr, ns, killed = T.apply_rebase(
            bts, wts, rts, state, is_private=False, bits=bits)
        assert nb == base + T.rebase_amount(bits)
        # LLC rebase: timestamps never decrease, no lines die
        assert (np.asarray(nw) >= np.asarray(wts)).all()
        assert (np.asarray(nr) >= np.asarray(rts)).all()
        assert int(killed) == 0

    @given(st.integers(0, 1 << 20), st.sampled_from([8, 14, 20]))
    @settings(max_examples=50, deadline=None)
    def test_private_rebase_kills_stale_shared_lines(self, base, bits):
        bts = jnp.int32(base)
        # one line far in the past (expired long ago), one current
        wts = jnp.array([base - 0, base + (1 << bits) - 1])
        rts = jnp.array([base + 1, base + (1 << bits) - 1])
        state = jnp.array([P.SHARED, P.SHARED])
        nb, nw, nr, ns, killed = T.apply_rebase(
            bts, wts, rts, state, is_private=True, bits=bits)
        if base + 1 < int(nb):
            assert int(ns[0]) == P.INVALID      # stale lease invalidated
            assert int(killed) >= 1
        assert int(ns[1]) == P.SHARED           # live line survives

    def test_storage_bits_table7(self):
        assert T.storage_bits_per_line(64, "full-map") == 64
        assert T.storage_bits_per_line(64, "ackwise", ackwise_ptrs=4) == 24
        assert T.storage_bits_per_line(64, "tardis") == 40
        assert T.storage_bits_per_line(256, "tardis") == 40   # O(log N) flat


class TestAnalyticRoofline:
    def test_model_flops_sane(self):
        from benchmarks.analytic import model_flops
        from repro.configs import SHAPE_BY_NAME, get_arch
        cfg = get_arch("llama3-405b")
        f = model_flops(cfg, SHAPE_BY_NAME["train_4k"])
        # 6 * 405e9 * 1.048e6 tokens = 2.55e18 (+ attention)
        assert 2.0e18 < f["model_flops"] < 4.0e18
        fd = model_flops(cfg, SHAPE_BY_NAME["decode_32k"])
        # 2 * 405e9 * 128 + attention over the 32k cache ~ 1.4e14
        assert 1.0e14 < fd["model_flops"] < 1e16

    def test_roofline_terms_positive(self):
        from benchmarks.analytic import roofline_terms
        from repro.configs import SHAPE_BY_NAME, get_arch
        t = roofline_terms(get_arch("glm4-9b"), SHAPE_BY_NAME["train_4k"],
                           256, collective_bytes_per_dev=1e9)
        assert t["compute_s"] > 0 and t["memory_s"] > 0
        assert t["dominant"] in ("compute", "memory", "collective")
