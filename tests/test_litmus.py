"""Sequential-consistency litmus conformance suite for the lease protocol.

"A Proof of Correctness for the Tardis Cache Coherence Protocol" (Yu et
al.) shows the protocol's SC argument reduces to small checkable
invariants; this suite drives the classic litmus shapes -- store buffering
(SB), message passing (MP), load buffering (LB), and IRIW -- as op streams
through THREE implementations of the timestamp-manager rules:

  * the Pallas ``tardis_lease`` kernel behind ``LeaseEngine("pallas")``,
  * the numpy mirror behind ``LeaseEngine("numpy")``,
  * the scalar Table I-III rules from ``repro.core.protocol``,

each paired with paper-faithful private caches (stale local hits included:
a core with an unexpired lease reads its cached -- possibly old -- value).
Every interleaving of each litmus program is executed on every backend and
checked two ways:

  * the *forbidden outcome* (the one SC rules out) is never observed, and
  * the timestamp invariant holds per load: no store to the same address
    carries a timestamp inside ``(version_wts, load_pts]`` -- the "no
    cycle the timestamps forbid" witness (per-core pts is monotone by
    construction, so timestamp order embeds program order).

Backends must also agree bit-for-bit on every outcome, final table, and
program timestamp.

The litmus matrix is additionally parametrized over the **multi-pool
engine**: the engine backends carry a dual-stack paged payload (two named
KV pools interleaved in one token row, the moe serving layout) whose
content encodes the version timestamp -- every store publishes both
stacks' payloads through one ``write_kv`` and every manager read
(including the injected decode-time block re-reads) checks that both
stacks -- via the full-row gather AND the per-stack windowed gather --
serve exactly the version the lease protocol names.  Pool payloads carry
no timestamps, so the protocol-state comparison across all three backends
is unchanged.

Plus the per-wave batching contracts: randomized differential tests that
``read_many`` / ``write_many`` are bit-identical in ``wts/rts/pts`` to the
per-request path issued at the wave's shared pts, and that the multi-row
mask kernel matches its scalar-composed oracle for per-group timestamps.

**Relaxed-consistency outcome tables (Tardis 2.0).**  A weaker memory
model is exactly a set of legal program-order transformations -- TSO may
order a load before a program-earlier store to a different address, RC may
reorder any two adjacent accesses to different addresses -- so each
model's outcome set is enumerated by running every reachable per-core
reordering through the SAME SC interleaving machinery on every backend
(the backends never change; consistency is a property of what the core is
allowed to issue).  The table: SB's relaxed outcome is forbidden under SC
but allowed-and-observed under TSO and RC; MP/LB/IRIW stay forbidden
under TSO and become observable only under RC; CoRR (same address, so no
model reorders it) is forbidden everywhere.
"""
import itertools

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import LeaseEngine, ShardedLeaseDirectory, protocol as P
from repro.kernels.tardis_lease import ops as lease_ops, ref as lease_ref

X, Y = 0, 1
N_ADDR = 2


# ---------------------------------------------------------------------------
# The three timestamp-manager backends behind one interface
# ---------------------------------------------------------------------------

# dual-stack paged payload layout for the multi-pool litmus lane: two
# named pools (the moe serving shape, reduced), chunk-1 token rows
KV_POOLS = {"s0": (1, 2), "s1": (1, 3)}


class EngineManager:
    """LeaseEngine-backed manager (pallas kernel or numpy mirror).

    With ``pools=True`` the engine also carries the dual-stack paged
    payload: a store publishes both stacks' content (the version timestamp
    broadcast) through ONE ``write_kv`` on the block id, and a manager
    read asserts both stacks serve exactly the version the protocol names
    -- through the one-dispatch full-row gather and through each stack's
    windowed gather (the kernels' pool-offset index-map dimension).
    """

    def __init__(self, backend: str, lease: int, pools: bool = False,
                 sanitize: bool = False):
        # sanitize=False defers to the engine's TARDIS_SANITIZE env check,
        # so the whole litmus matrix runs sanitized under TARDIS_SANITIZE=1
        self.eng = LeaseEngine(N_ADDR, lease=lease, backend=backend,
                               kv_pools=KV_POOLS if pools else None,
                               kv_dtype=np.float32,
                               sanitize=sanitize or None)

    def read(self, addr, pts, req):
        r = self.eng.read([addr], pts, req_wts=[req])
        w = int(r.wts[0])
        if self.eng.has_kv and self.eng.kv_ok(addr):
            got = self.eng.read_kv([addr])
            for name, arr in got.items():
                assert np.all(np.asarray(arr, np.float32) == w), \
                    (addr, name, w, np.asarray(arr))
                np.testing.assert_array_equal(
                    np.asarray(self.eng.read_kv([addr], pool=name)),
                    np.asarray(arr), err_msg=f"windowed gather {name}")
        return w, int(r.rts[0]), int(r.new_pts)

    def write(self, addr, pts):
        ts = self.eng.write([addr], pts)
        if self.eng.has_kv:
            self.eng.write_kv([addr], {n: np.full((1,) + s, ts, np.float32)
                                       for n, s in KV_POOLS.items()})
        return ts

    def state(self):
        return self.eng.wts.tolist(), self.eng.rts.tolist()


class ScalarManager:
    """Tables I-III applied one address at a time with protocol scalars."""

    def __init__(self, lease: int):
        self.wts = [0] * N_ADDR
        self.rts = [0] * N_ADDR
        self.lease = lease

    def read(self, addr, pts, req):
        del req                       # renewability doesn't change the state
        w, r = self.wts[addr], self.rts[addr]
        new_pts = pts if P.shared_expired(pts, r) \
            else int(P.load_no_cache(pts, w, r)[0])
        self.rts[addr] = int(P.lease_extend(w, r, pts, self.lease))
        return w, self.rts[addr], new_pts

    def write(self, addr, pts):
        ts = int(P.store_no_cache(pts, self.wts[addr], self.rts[addr])[0])
        self.wts[addr] = self.rts[addr] = ts
        return ts

    def state(self):
        return list(self.wts), list(self.rts)


class ShardedManager:
    """Sharded-directory manager: the SAME litmus programs resolved through
    :class:`ShardedLeaseDirectory` with the two litmus addresses living on
    **different owner shards** (``owner(addr) = addr % 2``) and every core
    its own host.  Each protocol op is one directory wave, so the cross-host
    invariant -- at most one request + one response per contacted owner
    shard per wave -- is asserted on every single operation.

    With ``pools=True`` the lane exercises timestamp-ordered page
    migration: a store publishes its dual-stack payload write-behind
    (``defer_publish`` + ``flush_deferred``) and every directory read also
    fetches the home page, asserting the migrated content is exactly the
    version the returned lease names.
    """

    def __init__(self, lease: int, n_cores: int, pools: bool = False,
                 sanitize: bool = False, backend: str = "numpy"):
        self.dirx = ShardedLeaseDirectory(
            N_ADDR, 2, n_hosts=n_cores, lease=lease, backend=backend,
            kv_pools=KV_POOLS if pools else None, kv_dtype=np.float32,
            sanitize=sanitize or None)
        self.pools = pools

    def port(self, ci: int) -> "_ShardPort":
        return _ShardPort(self, ci)

    def state(self):
        return self.dirx.wts.tolist(), self.dirx.rts.tolist()


class _ShardPort:
    """One core's view of the sharded directory (core index = host id)."""

    def __init__(self, mgr: ShardedManager, host: int):
        self.mgr = mgr
        self.host = host

    def read(self, addr, pts, req):
        d = self.mgr.dirx
        fetch = [addr] if (self.mgr.pools and d.home_ok(addr)) else []
        res = d.wave(self.host, pts, read_groups=[[addr]],
                     req_wts={addr: req}, fetch_bids=fetch)
        assert res.shards_contacted <= 1 and res.msgs <= 2, res
        w, r = res.leases[addr]
        if addr in res.fetched:    # migrated page serves the named version
            page = res.fetched[addr]
            assert (page.wts, page.rts) == (w, r)
            for name, arr in page.blocks.items():
                assert np.all(np.asarray(arr, np.float32) == w), \
                    (addr, name, w, np.asarray(arr))
        return w, r, int(res.new_pts)

    def write(self, addr, pts):
        d = self.mgr.dirx
        res = d.wave(self.host, pts, write_bids=[addr],
                     tag_writes_with_ts=True)
        assert res.shards_contacted <= 1 and res.msgs <= 2, res
        ts = res.write_ts[addr]
        if self.mgr.pools:         # write-behind: payload rides a flush
            d.defer_publish(self.host, addr,
                            {n: np.full((1,) + s, ts, np.float32)
                             for n, s in KV_POOLS.items()}, tag=ts)
            d.flush_deferred(self.host)
        return ts


class Core:
    """Paper-faithful private cache: local hits while the lease covers pts
    (returning the cached, possibly stale, value), renewal on expiry."""

    def __init__(self, mgr, versions):
        self.mgr = mgr
        self.versions = versions      # addr -> {wts: value}; wts 0 = initial
        self.pts = 0
        self.cache = {}               # addr -> (value, wts, rts)

    def store(self, addr, val):
        ts = self.mgr.write(addr, self.pts)
        self.pts = ts
        self.versions[addr][ts] = val
        self.cache[addr] = (val, ts, ts)
        return ts

    def load(self, addr):
        ent = self.cache.get(addr)
        if ent is not None and self.pts <= ent[2]:
            val, w, _ = ent           # unexpired lease: stale-but-SC-legal
            self.pts = max(self.pts, w)
            return val, w
        req = ent[1] if ent is not None else -1
        w, r, new_pts = self.mgr.read(addr, self.pts, req)
        val = self.versions[addr][w]
        self.pts = new_pts
        self.cache[addr] = (val, w, r)
        return val, w


# ---------------------------------------------------------------------------
# Litmus programs and the interleaving driver
# ---------------------------------------------------------------------------

LITMUS = {
    # name: (per-core programs, forbidden-outcome predicate)
    "SB": ([[("st", X, 1), ("ld", Y, "r1")],
            [("st", Y, 1), ("ld", X, "r2")]],
           lambda r: r["r1"] == 0 and r["r2"] == 0),
    "MP": ([[("st", X, 1), ("st", Y, 1)],
            [("ld", Y, "r1"), ("ld", X, "r2")]],
           lambda r: r["r1"] == 1 and r["r2"] == 0),
    "LB": ([[("ld", X, "r1"), ("st", Y, 1)],
            [("ld", Y, "r2"), ("st", X, 1)]],
           lambda r: r["r1"] == 1 and r["r2"] == 1),
    "IRIW": ([[("st", X, 1)], [("st", Y, 1)],
              [("ld", X, "r1"), ("ld", Y, "r2")],
              [("ld", Y, "r3"), ("ld", X, "r4")]],
             lambda r: (r["r1"] == 1 and r["r2"] == 0
                        and r["r3"] == 1 and r["r4"] == 0)),
    # read-read coherence: exercises the stale-but-SC-legal local hit (a
    # leased reader may re-read the OLD value after a concurrent store,
    # but values must never go backwards)
    "CoRR": ([[("st", X, 1)],
              [("ld", X, "r1"), ("ld", X, "r2")]],
             lambda r: r["r1"] == 1 and r["r2"] == 0),
}


def interleavings(progs):
    """Every merge of the per-core programs that respects program order."""
    counts = tuple(len(p) for p in progs)

    def rec(remaining, acc):
        if not any(remaining):
            yield tuple(acc)
            return
        for i, r in enumerate(remaining):
            if r:
                nxt = remaining[:i] + (r - 1,) + remaining[i + 1:]
                yield from rec(nxt, acc + [i])
    yield from rec(counts, [])


def run_litmus(progs, schedule, make_mgr, decode_reads=0):
    """One execution; returns (regs, loads, stores, final_state, pts).

    ``decode_reads > 0`` injects the serving engine's decode-time access
    pattern into the history: after each program op, the core re-reads
    every address it holds that many times (local hits while the lease
    covers pts, renewals after), exactly like a continuous-batch decode
    tick re-reading its leased prefix blocks.  The re-read loads join the
    per-load timestamp-invariant check.
    """
    mgr = make_mgr()
    versions = {a: {0: 0} for a in range(N_ADDR)}
    cores = [Core(mgr.port(ci) if hasattr(mgr, "port") else mgr, versions)
             for ci in range(len(progs))]
    cursors = [0] * len(progs)
    regs, loads, stores = {}, [], []
    for ci in schedule:
        op = progs[ci][cursors[ci]]
        cursors[ci] += 1
        core = cores[ci]
        pts_before = core.pts
        if op[0] == "st":
            ts = core.store(op[1], op[2])
            stores.append((op[1], ts))
        else:
            val, version = core.load(op[1])
            regs[op[2]] = val
            loads.append((op[1], version, core.pts))
        for addr in sorted(core.cache):        # decode-tick block re-reads
            for _ in range(decode_reads):
                core.pts += 1                  # each tick is a logical step
                _, version = core.load(addr)
                loads.append((addr, version, core.pts))
        assert core.pts >= pts_before          # timestamp order embeds
        #                                        program order per core
    return regs, loads, stores, mgr.state(), [c.pts for c in cores]


@pytest.mark.parametrize("shape", sorted(LITMUS))
@pytest.mark.parametrize("lease,decode_reads,pools,sanitize",
                         [(1, 0, False, False), (4, 0, False, False),
                          (4, 2, False, False), (4, 2, True, False),
                          (4, 2, True, True)])
def test_litmus_forbidden_outcomes_never_observed(shape, lease,
                                                  decode_reads, pools,
                                                  sanitize):
    progs, forbidden = LITMUS[shape]
    backends = {
        # the multi-pool lane runs the same litmus matrix with dual-stack
        # paged payloads riding the engine backends (decode-time re-reads
        # then exercise dual-stack blocks); the scalar oracle has no pool
        # -- payloads never touch protocol state, so all three backends
        # must still agree bit-for-bit on every outcome and table.  The
        # ``sanitize`` lane re-runs the pool matrix with the runtime lease
        # sanitizer asserting after every engine transition.
        "kernel": lambda: EngineManager("pallas", lease, pools, sanitize),
        "mirror": lambda: EngineManager("numpy", lease, pools, sanitize),
        "scalar": lambda: ScalarManager(lease),
    }
    for schedule in interleavings(progs):
        results = {name: run_litmus(progs, schedule, mk, decode_reads)
                   for name, mk in backends.items()}
        regs, loads, stores, state, pts = results["kernel"]
        # the three implementations of Tables I-III agree bit-for-bit
        for name in ("mirror", "scalar"):
            assert results[name] == results["kernel"], (shape, schedule, name)
        # SC: the forbidden outcome is never produced
        assert not forbidden(regs), (shape, schedule, regs)
        # timestamp witness: a load of version v at (post-load) pts t never
        # has a same-address store inside (v, t] -- the order by timestamps
        # is a legal SC total order, so no forbidden cycle can exist
        for addr, v, t in loads:
            for addr2, ts in stores:
                assert not (addr2 == addr and v < ts <= t), \
                    (shape, schedule, loads, stores)


# ---------------------------------------------------------------------------
# Relaxed-consistency outcome tables: SC/TSO/RC as program-order relaxations
# ---------------------------------------------------------------------------

def _swappable(a, b, model):
    """May op ``b`` be issued before the program-earlier adjacent op ``a``
    under ``model``?  Same-address pairs keep program order in every model
    (per-location coherence is never relaxed)."""
    if model == "sc" or a[1] == b[1]:
        return False
    if model == "tso":
        return a[0] == "st" and b[0] == "ld"   # the store->load relaxation
    return True                                # rc: any different-address pair


def relaxed_programs(prog, model):
    """All per-core issue orders reachable by the model's legal adjacent
    swaps (the closure, not just one swap: TSO may sink a store below any
    number of later different-address loads)."""
    seen = {tuple(prog)}
    frontier = [tuple(prog)]
    while frontier:
        cur = frontier.pop()
        for i in range(len(cur) - 1):
            if _swappable(cur[i], cur[i + 1], model):
                nxt = cur[:i] + (cur[i + 1], cur[i]) + cur[i + 2:]
                if nxt not in seen:
                    seen.add(nxt)
                    frontier.append(nxt)
    return sorted(seen)


def relaxed_variants(progs, model):
    """Every combination of per-core reorderings the model allows."""
    yield from itertools.product(*(relaxed_programs(p, model)
                                   for p in progs))


# shape -> model -> is the litmus shape's relaxed outcome allowed?  When
# allowed it must also be OBSERVED (the lane is not vacuous); when
# forbidden it must never appear across all variants x interleavings.
RELAXED_OUTCOMES = {
    "SB":   {"sc": False, "tso": True,  "rc": True},
    "MP":   {"sc": False, "tso": False, "rc": True},
    "LB":   {"sc": False, "tso": False, "rc": True},
    "IRIW": {"sc": False, "tso": False, "rc": True},
    "CoRR": {"sc": False, "tso": False, "rc": False},
}


@pytest.mark.parametrize("model", ["sc", "tso", "rc"])
@pytest.mark.parametrize("shape", sorted(LITMUS))
def test_relaxed_consistency_outcome_tables(shape, model):
    """The per-model outcome tables, enumerated as program-order
    relaxations over the unchanged SC machinery, agree on all FOUR
    backend lanes (kernel, numpy mirror, scalar rules, sharded
    directory): a forbidden outcome never appears in any variant, an
    allowed one is actually witnessed."""
    progs, forbidden = LITMUS[shape]
    allowed = RELAXED_OUTCOMES[shape][model]
    lease, n_cores = 4, len(progs)
    backends = {
        "kernel": lambda: EngineManager("pallas", lease),
        "mirror": lambda: EngineManager("numpy", lease),
        "scalar": lambda: ScalarManager(lease),
        "sharded": lambda: ShardedManager(lease, n_cores),
    }
    observed = False
    for variant in relaxed_variants(progs, model):
        variant = [list(p) for p in variant]
        for schedule in interleavings(variant):
            results = {name: run_litmus(variant, schedule, mk)
                       for name, mk in backends.items()}
            regs = results["kernel"][0]
            for name in ("mirror", "scalar", "sharded"):
                assert results[name] == results["kernel"], \
                    (shape, model, variant, schedule, name)
            if forbidden(regs):
                assert allowed, (shape, model, variant, schedule, regs)
                observed = True
                break                  # witnessed; no need to keep scanning
        if observed:
            break
    assert observed == allowed, \
        f"{shape} under {model}: relaxed outcome " \
        f"{'never witnessed' if allowed else 'observed'}"


# ---------------------------------------------------------------------------
# Sharded-directory lane: same programs, cores on different owner shards
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", sorted(LITMUS))
@pytest.mark.parametrize("backend,lease,decode_reads,pools",
                         [("numpy", 4, 0, False),
                          ("pallas", 4, 0, False),
                          ("numpy", 4, 1, True)])
def test_litmus_sharded_directory_matches_single_host_oracle(
        shape, backend, lease, decode_reads, pools):
    """X and Y live on DIFFERENT owner shards of a ShardedLeaseDirectory
    (every core its own host) and must produce bit-for-bit the outcomes,
    tables, and timestamps of the single-host engine oracle -- with at
    most one request/response per owner shard per op and zero multicast
    or invalidation messages.  The ``pools`` lane adds timestamp-ordered
    page migration (write-behind publish + fetch-on-read) on top."""
    progs, forbidden = LITMUS[shape]
    n_cores = len(progs)
    for schedule in interleavings(progs):
        mgr = ShardedManager(lease, n_cores, pools=pools,
                             sanitize=pools, backend=backend)
        res = run_litmus(progs, schedule, lambda: mgr, decode_reads)
        oracle = run_litmus(
            progs, schedule,
            lambda: EngineManager("numpy", lease), decode_reads)
        assert res == oracle, (shape, schedule)
        regs = res[0]
        assert not forbidden(regs), (shape, schedule, regs)
        d = mgr.dirx
        assert d.stats.multicasts == 0
        assert d.stats.invalidation_msgs == 0
        assert d.max_msgs_per_wave() <= 2    # one shard touched per op
        if pools:
            assert d.stats.publishes > 0
            assert d.stats.migrations > 0
            assert d.sanitize_checks > 0


# ---------------------------------------------------------------------------
# Per-wave batching: bit-identical to the per-request path
# ---------------------------------------------------------------------------

N_BLOCKS = 24
LEASE = 5

wave_stream = st.lists(
    st.tuples(st.booleans(),                           # write prelude op?
              st.lists(st.integers(0, N_BLOCKS - 1), min_size=1, max_size=5)),
    min_size=0, max_size=6)
wave_groups = st.lists(
    st.lists(st.integers(0, N_BLOCKS - 1), min_size=1, max_size=6),
    min_size=1, max_size=4)


def _prelude(engines, stream):
    pts = 0
    for is_write, idx in stream:
        idx = sorted(set(idx))
        if is_write:
            for e in engines:
                pts = e.write(idx, pts)
        else:
            for e in engines:
                r = e.read(idx, pts)
            pts = r.new_pts
    return pts


@given(wave_stream, wave_groups, st.integers(0, 10))
@settings(max_examples=25, deadline=None)
def test_read_many_bit_identical_to_per_request_path(stream, groups, dpts):
    """One read_many dispatch == the per-request reads at the wave's shared
    pts: same wts/rts tables, same resulting program timestamp, on both
    engine backends (the wave semantics the serving cluster relies on)."""
    ek = LeaseEngine(N_BLOCKS, lease=LEASE, backend="pallas")
    en = LeaseEngine(N_BLOCKS, lease=LEASE, backend="numpy")
    es = LeaseEngine(N_BLOCKS, lease=LEASE, backend="numpy")
    pts = _prelude([ek, en, es], stream) + dpts
    groups = [sorted(set(g)) for g in groups]
    req = {b: int(ek.wts[b]) - (b % 2) for g in groups for b in g}
    rk = ek.read_many(groups, pts, req_wts=req)
    rn = en.read_many(groups, pts, req_wts=req)
    seq_pts = [es.read(g, pts, req_wts=[req[b] for b in g]).new_pts
               for g in groups]
    np.testing.assert_array_equal(ek.wts, en.wts)
    np.testing.assert_array_equal(ek.rts, en.rts)
    np.testing.assert_array_equal(ek.wts, es.wts)
    np.testing.assert_array_equal(ek.rts, es.rts)
    assert int(rk.new_pts.max()) == int(rn.new_pts.max()) == max(seq_pts)
    np.testing.assert_array_equal(rk.union_idx, rn.union_idx)
    np.testing.assert_array_equal(rk.expired, rn.expired)
    np.testing.assert_array_equal(rk.renew_ok, rn.renew_ok)
    assert ek.stats == en.stats


@given(wave_stream, wave_groups, st.integers(0, 10))
@settings(max_examples=25, deadline=None)
def test_write_many_bit_identical_to_union_write(stream, groups, dpts):
    """A wave's writes fold into ONE jump-ahead over the union of its
    blocks (one logical tick), bit-identical across backends."""
    ek = LeaseEngine(N_BLOCKS, lease=LEASE, backend="pallas")
    en = LeaseEngine(N_BLOCKS, lease=LEASE, backend="numpy")
    es = LeaseEngine(N_BLOCKS, lease=LEASE, backend="numpy")
    pts = _prelude([ek, en, es], stream) + dpts
    ops_before = ek.stats.write_ops
    tk = ek.write_many(groups, pts)
    tn = en.write_many(groups, pts)
    union = sorted({b for g in groups for b in g})
    ts = es.write(union, pts)
    assert tk == tn == ts
    np.testing.assert_array_equal(ek.wts, es.wts)
    np.testing.assert_array_equal(ek.rts, es.rts)
    np.testing.assert_array_equal(en.wts, es.wts)
    assert ek.stats.write_ops == ops_before + 1   # whole wave: one dispatch


@given(st.integers(1, 4), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=15, deadline=None)
def test_multi_row_kernel_matches_scalar_oracle(n_groups, seed):
    """The multi-row mask kernel with per-group timestamps is bit-identical
    to the scalar-composed oracle (kernels/tardis_lease/ref.py)."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 300))
    wts = rng.integers(0, 50, n).astype(np.int32)
    rts = np.maximum(wts, rng.integers(0, 60, n)).astype(np.int32)
    req = rng.integers(-1, 50, n).astype(np.int32)
    masks = rng.integers(0, 2, (n_groups, n)).astype(np.int32)
    pts_vec = rng.integers(0, 70, n_groups).astype(np.int32)
    out = lease_ops.masked_lease_check_many(
        jnp.asarray(wts), jnp.asarray(rts), jnp.asarray(req),
        jnp.asarray(masks), jnp.asarray(pts_vec), LEASE, interpret=True)
    exp = lease_ref.masked_lease_check_many_ref(
        jnp.asarray(wts), jnp.asarray(rts), jnp.asarray(req),
        jnp.asarray(masks), jnp.asarray(pts_vec), LEASE)
    for key in out:
        np.testing.assert_array_equal(np.asarray(out[key]),
                                      np.asarray(exp[key]), err_msg=key)
