"""LeaseEngine: kernel == numpy mirror == protocol scalar oracle.

The randomized differential test drives identical op streams through the
three implementations of Tables I-III and asserts bit-identical int32
``wts/rts/pts`` after every op:

  * the Pallas ``tardis_lease`` kernel (interpret mode) behind
    ``LeaseEngine(backend="pallas")``,
  * the numpy mirror behind ``backend="numpy"``,
  * the scalar rules from ``repro.core.protocol`` applied block-by-block.

Plus: int32 wraparound/rebase behaviour, flit-charged traffic accounting,
and the serving prefix-KV reuse path end to end.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import LeaseEngine, protocol as P
from repro.core.store import BlockTable, Replica, TardisStore

N_BLOCKS = 24
LEASE = 5


class ScalarOracle:
    """Tables I-III applied one block at a time with the protocol scalars."""

    def __init__(self, n_blocks: int, lease: int):
        self.wts = [0] * n_blocks
        self.rts = [0] * n_blocks
        self.lease = lease

    def read(self, idx, pts, req):
        expired, renew_ok = [], []
        consumed = pts
        for b, rq in zip(idx, req):
            expired.append(bool(P.shared_expired(pts, self.rts[b])))
            renew_ok.append(bool(P.renewable(rq, self.wts[b])))
            if pts <= self.rts[b]:               # readable under the lease
                consumed = max(consumed, self.wts[b])
        # extensions all use the requester's original pts (one batched op)
        for b in idx:
            self.rts[b] = int(P.lease_extend(self.wts[b], self.rts[b],
                                             pts, self.lease))
        return expired, renew_ok, consumed

    def write(self, idx, pts):
        ts = pts
        for b in idx:                            # fold the Table I store rule
            ts = int(P.store_no_cache(ts, self.wts[b], self.rts[b])[0])
        for b in idx:                            # one atomic multi-block store
            self.wts[b] = self.rts[b] = ts
        return ts


op_stream = st.lists(
    st.tuples(st.booleans(),                          # write?
              st.lists(st.integers(0, N_BLOCKS - 1), min_size=1, max_size=6),
              st.integers(0, 2)),                     # req mode
    min_size=1, max_size=10)


@given(op_stream)
@settings(max_examples=25, deadline=None)
def test_differential_kernel_numpy_oracle(stream):
    ek = LeaseEngine(N_BLOCKS, lease=LEASE, backend="pallas")
    en = LeaseEngine(N_BLOCKS, lease=LEASE, backend="numpy")
    orc = ScalarOracle(N_BLOCKS, LEASE)
    pts = {"k": 0, "n": 0, "o": 0}
    for is_write, idx, req_mode in stream:
        idx = sorted(set(idx))
        if is_write:
            tk = ek.write(idx, pts["k"])
            tn = en.write(idx, pts["n"])
            to = orc.write(idx, pts["o"])
            assert tk == tn == to
            pts = dict.fromkeys(pts, tk)
        else:
            # req mode: 0 = no cached copy, 1 = current version (data-less
            # renewal), 2 = stale version (payload refetch)
            req = [-1 if req_mode == 0 else
                   orc.wts[b] - (1 if req_mode == 2 else 0) for b in idx]
            rk = ek.read(idx, pts["k"], req_wts=req)
            rn = en.read(idx, pts["n"], req_wts=req)
            exp_o, ren_o, pts_o = orc.read(idx, pts["o"], req)
            np.testing.assert_array_equal(rk.expired, rn.expired)
            np.testing.assert_array_equal(rk.expired, np.asarray(exp_o))
            np.testing.assert_array_equal(rk.renew_ok, rn.renew_ok)
            np.testing.assert_array_equal(rk.renew_ok, np.asarray(ren_o))
            assert rk.new_pts == rn.new_pts == pts_o
            pts = dict.fromkeys(pts, rk.new_pts)
        np.testing.assert_array_equal(ek.wts, en.wts)
        np.testing.assert_array_equal(ek.rts, en.rts)
        np.testing.assert_array_equal(ek.wts, np.asarray(orc.wts, np.int32))
        np.testing.assert_array_equal(ek.rts, np.asarray(orc.rts, np.int32))
    assert ek.stats == en.stats                  # same flits, same renewals


@pytest.mark.parametrize("backend", ["pallas", "numpy"])
def test_int32_and_rebase(backend):
    """Timestamps are int32 end to end; the ts_bits guard rebases the table
    before the width overflows, preserving every ordering relation."""
    eng = LeaseEngine(8, lease=4, backend=backend, ts_bits=8)
    assert eng.wts.dtype == np.int32 and eng.rts.dtype == np.int32
    pts = 0
    for _ in range(60):                          # drive ts past 2**8
        pts = eng.write([0, 1], pts)
        pts = eng.read([0, 1, 2], pts).new_pts
        if int(eng.rts.max()) >= (1 << 8):
            break
    assert int(eng.rts.max()) >= (1 << 8)
    before_w, before_r = eng.wts.copy(), eng.rts.copy()
    shift = eng.maybe_rebase()
    assert shift == (1 << 7) and eng.stats.rebases == 1
    # shifted where above the new base, clamped at zero below it
    np.testing.assert_array_equal(eng.wts, np.maximum(before_w - shift, 0))
    np.testing.assert_array_equal(eng.rts, np.maximum(before_r - shift, 0))
    order = np.argsort(before_w, kind="stable")
    assert (np.diff(eng.wts[order]) >= 0).all()  # ordering preserved
    pts = LeaseEngine.rebase_pts(pts, shift)
    assert pts >= 0
    # the protocol still behaves after the rebase: write jumps every lease
    rts2_before = int(eng.rts[2])
    ts = eng.write([2], pts)
    assert ts > rts2_before
    assert int(eng.rts.max()) < (1 << 8)         # back under the width
    # per-op guard keeps the table in-width indefinitely
    for _ in range(200):
        pts = eng.write([3, 4], pts)
        pts = LeaseEngine.rebase_pts(pts, eng.maybe_rebase())
        assert int(eng.rts.max()) < (1 << 8)
    assert eng.stats.rebases > 1


def test_rebase_parity_between_backends():
    ek = LeaseEngine(8, lease=4, backend="pallas", ts_bits=8)
    en = LeaseEngine(8, lease=4, backend="numpy", ts_bits=8)
    pk = pn = 0
    for _ in range(300):
        pk, pn = ek.write([0, 3], pk), en.write([0, 3], pn)
        sk, sn = ek.maybe_rebase(), en.maybe_rebase()
        assert sk == sn
        pk = LeaseEngine.rebase_pts(pk, sk)
        pn = LeaseEngine.rebase_pts(pn, sn)
        np.testing.assert_array_equal(ek.wts, en.wts)
        np.testing.assert_array_equal(ek.rts, en.rts)
    assert ek.stats.rebases > 0


def test_block_table_is_engine_adapter():
    bt = BlockTable(16, lease=8, backend="numpy", kv_block_shape=(2, 3))
    assert bt.wts.dtype == np.int32
    expired, pts = bt.read_blocks(np.array([0, 3]), 0)
    assert (bt.rts[[0, 3]] >= 8).all()
    ts = bt.write_blocks(np.array([3]), pts)
    assert ts == int(bt.wts[3]) == int(bt.rts[3])
    assert bt.engine.stats.reads == 2 and bt.engine.stats.writes == 1
    # per-wave batched forms: overlapping groups, one engine op each
    expired2, pts2 = bt.read_blocks_many([[0, 3], [3, 7]], ts)
    assert expired2.shape == (2, 3) and pts2 >= ts     # union = {0, 3, 7}
    assert bt.engine.stats.read_ops == 2
    ts2 = bt.write_blocks_many([[1, 5], [5, 9]], pts2)
    assert ts2 >= pts2 and bt.engine.stats.write_ops == 2
    assert (bt.wts[[1, 5, 9]] == ts2).all()
    # the paged-KV payload pool rides the same adapter
    blk = np.arange(6, dtype=np.float32).reshape(1, 2, 3)
    bt.engine.write_kv([5], blk)
    np.testing.assert_array_equal(np.asarray(bt.engine.read_kv([5]))[0],
                                  blk[0])


def test_store_charges_message_flits():
    """bytes-on-wire include metadata headers, like the simulator's ledger."""
    store = TardisStore(lease=4)
    pub = Replica(store, "w")
    pub.write("obj", b"x" * 1600, nbytes=1600)
    flits_after_pub = store.stats.flits
    assert flits_after_pub == P.MESSAGE_FLITS["EX_REQ"] + P.data_flits(1600)
    r = Replica(store, "r", selfinc_period=1)
    r.read("obj")                                # first fetch: payload
    payload_cost = store.stats.flits - flits_after_pub
    assert payload_cost == (P.MESSAGE_FLITS["SH_REQ"]
                            + P.MESSAGE_FLITS["RENEW_REP"]
                            + P.data_flits(1600))
    for _ in range(20):                          # expiries renew data-less
        r.read("obj")
    renew_cost = (P.MESSAGE_FLITS["SH_REQ"] + P.MESSAGE_FLITS["RENEW_REP"])
    assert store.stats.renew_data_less > 0
    assert store.stats.flits < flits_after_pub + payload_cost \
        + 20 * renew_cost + 1                    # renewals never carried data
    assert store.stats.wire_bytes == store.stats.flits * P.FLIT_BYTES


def _tiny_cluster(**kw):
    import jax
    from repro.configs import get_arch, reduced
    from repro.models import init_params
    from repro.runtime import ServingCluster

    cfg = reduced(get_arch("tinyllama-1.1b"), n_layers=2, d_model=64,
                  vocab=128)
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    return ServingCluster(cfg, lambda: params, **kw)


def test_wave_of_identical_prompts_advances_pts_once():
    """Regression: the wave is ONE protocol interaction -- a wave of N
    identical prompts charges a single logical tick, and pure local hits
    dispatch nothing to the engine (the old code ticked per request)."""
    cluster = _tiny_cluster(n_replicas=2, prefix_block_tokens=4, kv_lease=64)
    rep = cluster.replicas[0]
    p = np.arange(1, 13, dtype=np.int32)            # 3 prefix blocks
    cluster._lease_prefix_wave(rep, [p])            # writes the blocks
    cluster._lease_prefix_wave(rep, [p] * 8)        # one renewal dispatch
    before = rep.kv_pts
    reads_before = cluster.prefix_engine.stats.read_ops
    writes_before = cluster.prefix_engine.stats.write_ops
    hits_before = cluster.prefix_stats["prefix_local_hits"]
    cluster._lease_prefix_wave(rep, [p] * 8)        # pure local hits
    assert rep.kv_pts == before + 1                 # one tick per WAVE
    assert cluster.prefix_engine.stats.read_ops == reads_before
    assert cluster.prefix_engine.stats.write_ops == writes_before
    assert cluster.prefix_stats["prefix_local_hits"] == hits_before + 24


def test_wave_sharing_prefix_is_one_dispatch_and_skips_prefill():
    """Acceptance: a wave of B requests sharing a system prompt resolves
    with exactly 1 read_many dispatch and <=1 write dispatch, and a later
    wave serves the prefix from the paged KV pool -- prefill skips it
    (prefix_flops_saved > 0)."""
    from repro.runtime import Request

    cluster = _tiny_cluster(n_replicas=2, prefix_block_tokens=8,
                            kv_lease=16, cache_len=64, selfinc_period=4)
    rng = np.random.default_rng(0)
    prefix = rng.integers(1, 128, 32).astype(np.int32)
    reqs = [Request(i, np.concatenate(
                [prefix, rng.integers(1, 128, 4).astype(np.int32)]),
                max_new=1) for i in range(4)]
    done, rep = cluster.run(reqs)                   # 2 waves of B=2
    assert all(r.done for r in done)
    e = cluster.prefix_engine.stats
    # wave 1 (replica0): req0 misses the 4 prefix blocks (1 write), req1
    # fetches them (1 read); wave 2 (replica1): both renew (1 read).
    assert e.read_ops == 2
    assert e.write_ops == 1
    assert e.writes == 4                            # 4 blocks, one union op
    # wave 2 ran suffix-only prefill on pool-materialized prefix KV
    assert rep["prefix_prefill_tokens_skipped"] == 32 * 2
    assert rep["prefix_flops_saved"] > 0
    assert rep["prefix_kv_blocks_written"] == 4
    assert rep["prefix_kv_blocks_read"] == 4


def test_weight_publish_frees_pool_and_waves_repair_it():
    """A weight hot-swap must not let prefill skip on KV computed under the
    old weights: the publish frees every pool slot (zero messages), and the
    next wave repairs them from its own prefill so later waves skip again."""
    import jax
    from repro.runtime import Request

    cluster = _tiny_cluster(n_replicas=2, prefix_block_tokens=8,
                            kv_lease=16, cache_len=64, selfinc_period=4)
    rng = np.random.default_rng(0)
    prefix = rng.integers(1, 128, 32).astype(np.int32)

    def mk(i):
        return Request(i, np.concatenate(
            [prefix, rng.integers(1, 128, 4).astype(np.int32)]), max_new=1)

    cluster.run([mk(i) for i in range(4)])
    assert cluster.prefix_engine.kv_valid_count() >= 4   # prefix in pool
    skipped = cluster.prefix_stats["prefix_prefill_tokens_skipped"]
    assert skipped > 0
    old = cluster.store._val["params"]
    cluster.publish_weights(jax.tree.map(lambda p: p * 0.5, old))
    assert cluster.prefix_engine.kv_valid_count() == 0   # pool freed
    cluster.run([mk(i) for i in range(4, 8)])
    rep = cluster.coherence_report()
    # wave 3 repaired the slots (no skip on stale KV), wave 4 skipped again
    assert cluster.prefix_engine.kv_valid_count() >= 4
    assert rep["prefix_prefill_tokens_skipped"] == skipped + 32 * 2


def test_cross_version_pool_kv_never_mixes_into_prefill():
    """Pool KV may only skip prefill for a wave serving the SAME weight
    version it was computed under: same-version staleness is SC-legal (a
    lagging replica reuses its lagging KV), but a renewed replica must
    refuse, free, and repair the slots at its own version."""
    import jax
    from repro.runtime import Request

    cluster = _tiny_cluster(n_replicas=1, prefix_block_tokens=8,
                            kv_lease=64, cache_len=64, lease=1000,
                            selfinc_period=1000)
    rep = cluster.replicas[0]
    rng = np.random.default_rng(0)
    prefix = rng.integers(1, 128, 32).astype(np.int32)

    def serve_one(i):
        cluster.run([Request(i, np.concatenate(
            [prefix, rng.integers(1, 128, 4).astype(np.int32)]), max_new=1)])
        return cluster.prefix_stats["prefix_prefill_tokens_skipped"]

    serve_one(0)                       # writes pool under weight version v0
    v0 = rep.reader.cached_version("params")
    assert serve_one(1) == 32          # skips at v0
    cluster.publish_weights(jax.tree.map(
        lambda p: p * 0.5, cluster.store._val["params"]))
    # replica's weight lease is unexpired: it still serves v0, repairs the
    # freed slots with v0 KV...
    assert serve_one(2) == 32
    assert rep.reader.cached_version("params") == v0
    # ...and same-version staleness remains legal: it skips on v0 KV
    assert serve_one(3) == 64
    assert (cluster._pool_wver[cluster._pool_wver >= 0] == v0).all()
    # force the weight renewal: now the replica serves v1
    rep.reader.pts = 10 ** 6
    assert serve_one(4) == 64          # refuses v0 KV, repairs at v1
    assert rep.reader.cached_version("params") != v0
    assert serve_one(5) == 96          # skips again, on v1 KV


@pytest.mark.parametrize("backend", ["pallas", "numpy"])
def test_rebase_mid_flight_preserves_kv_pool(backend):
    """A ts_bits rebase racing a stream of waves shifts metadata only: the
    paged KV pool's payloads and validity survive bit-for-bit (timestamps
    never touch the pool)."""
    eng = LeaseEngine(8, lease=4, backend=backend, ts_bits=7,
                      kv_block_shape=(4, 2, 2, 4), kv_dtype=np.float32)
    rng = np.random.default_rng(0)
    blocks = rng.standard_normal((3, 4, 2, 2, 4)).astype(np.float32)
    eng.write_kv([1, 4, 6], blocks)
    before = np.asarray(eng.read_kv([1, 4, 6])).copy()
    pts = 0
    while eng.stats.rebases == 0:                   # wave stream vs rebase
        pts = eng.write_many([[0, 1], [4, 5]], pts)
        pts = int(eng.read_many([[0, 1, 4, 6]], pts).new_pts.max())
        pts = LeaseEngine.rebase_pts(pts, eng.maybe_rebase())
    assert int(eng.rts.max()) < (1 << 7)
    np.testing.assert_array_equal(np.asarray(eng.read_kv([1, 4, 6])), before)
    assert eng.kv_ok(1) and eng.kv_ok(4) and eng.kv_ok(6)
    assert eng.kv_valid_count() == 3


def test_serving_survives_rebase_with_pool_hits():
    """Cluster-level: rebases fire mid-stream and prefill keeps skipping
    the pooled prefix afterwards."""
    from repro.runtime import Request

    cluster = _tiny_cluster(n_replicas=2, prefix_block_tokens=8,
                            kv_lease=24, ts_bits=5, cache_len=64,
                            selfinc_period=4)
    rng = np.random.default_rng(0)
    prefix = rng.integers(1, 128, 32).astype(np.int32)
    reqs = [Request(i, np.concatenate(
                [prefix, rng.integers(1, 128, 8).astype(np.int32)]),
                max_new=1) for i in range(24)]
    done, rep = cluster.run(reqs)
    assert all(r.done for r in done)
    assert rep["prefix_rebases"] >= 1
    assert rep["prefix_flops_saved"] > 0
    # waves after the first keep hitting the pool across rebases
    assert rep["prefix_prefill_tokens_skipped"] >= 32 * 2 * 5


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=2, deadline=None)
def test_eviction_frees_pool_slot_no_leak(seed):
    """Property: over 10k random requests on a tiny colliding table, a
    valid pool slot always holds content written for its CURRENT tag, and
    validity never outgrows the live tags -- collision evictions free their
    slot, so the pool cannot leak."""
    cluster = _tiny_cluster(n_replicas=1, n_prefix_blocks=8,
                            prefix_block_tokens=4, prefix_backend="numpy")
    rep = cluster.replicas[0]
    eng = cluster.prefix_engine
    rng = np.random.default_rng(seed)
    written_tag = {}                 # bid -> tag its pool content was for
    served = 0
    while served < 10_000:
        wave = [rng.integers(1, 64, 4 * int(rng.integers(1, 4)))
                .astype(np.int32) for _ in range(int(rng.integers(1, 5)))]
        plan = cluster._lease_prefix_wave(rep, wave)
        served += len(wave)
        if plan.miss_writers:        # stand-in for the prefill write-back
            bids = list(plan.miss_writers)
            eng.write_kv(bids, np.zeros((len(bids),) + eng.kv_block_shape,
                                        np.float32))
            for b in bids:
                written_tag[b] = int(cluster._tags[b])
        live = int((cluster._tags != -1).sum())
        assert eng.kv_valid_count() <= live <= eng.n_blocks
        for b in np.nonzero(eng._kv_valid)[0]:
            assert written_tag[int(b)] == int(cluster._tags[b])
    assert cluster.prefix_stats["prefix_evictions"] > 0
    assert eng.stats.kv_evictions > 0


def test_prefix_collision_eviction_never_serves_stale_content():
    """A collision eviction re-tags a block without invalidating anybody;
    a replica holding an unexpired lease on the OLD content must not local-
    hit the NEW tag (content check), only re-fetch with a payload."""
    import jax
    from repro.configs import get_arch, reduced
    from repro.models import init_params
    from repro.runtime import ServingCluster

    cfg = reduced(get_arch("tinyllama-1.1b"), n_layers=2, d_model=64,
                  vocab=128)
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    cluster = ServingCluster(cfg, lambda: params, n_replicas=2,
                             n_prefix_blocks=1,    # everything collides
                             prefix_block_tokens=4, kv_lease=64)
    rep_a, rep_b = cluster.replicas
    p1 = np.arange(1, 5, dtype=np.int32)
    p2 = np.arange(5, 9, dtype=np.int32)
    cluster._lease_prefix(rep_a, p1)              # A writes prefix P1
    cluster._lease_prefix(rep_a, p1)              # A renews: long lease
    assert rep_a.kv_pts <= rep_a.kv_leases[0][1]  # lease now unexpired
    tag1 = rep_a.kv_leases[0][2]
    cluster._lease_prefix(rep_b, p2)              # B's P2 evicts/re-tags
    assert cluster.prefix_stats["prefix_evictions"] == 1
    hits_before = cluster.prefix_stats["prefix_local_hits"]
    payload_before = cluster.prefix_engine.stats.payload_transfers
    cluster._lease_prefix(rep_a, p2)              # A asks for P2
    assert cluster.prefix_stats["prefix_local_hits"] == hits_before
    assert cluster.prefix_engine.stats.payload_transfers == payload_before + 1
    assert rep_a.kv_leases[0][2] != tag1          # cache re-tagged to P2


def test_serving_prefix_reuse_reports_hits_and_renewals():
    """Acceptance: a shared-prefix stream drives nonzero prefix_block_hits
    and data-less renewals through the LeaseEngine path."""
    import jax
    from repro.configs import get_arch, reduced
    from repro.models import init_params
    from repro.runtime import Request, ServingCluster

    cfg = reduced(get_arch("tinyllama-1.1b"), n_layers=2, d_model=64,
                  vocab=128)
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    cluster = ServingCluster(cfg, lambda: params, n_replicas=2, lease=6,
                             prefix_block_tokens=8, kv_lease=4,
                             cache_len=64, selfinc_period=2)
    rng = np.random.default_rng(0)
    prefix = rng.integers(1, cfg.vocab, 16).astype(np.int32)
    reqs = [Request(i, np.concatenate(
                [prefix, rng.integers(1, cfg.vocab, 8).astype(np.int32)]),
                max_new=2) for i in range(10)]
    done, rep = cluster.run(reqs)
    assert all(r.done and len(r.output) == 2 for r in done)
    assert rep["prefix_block_hits"] > 0
    assert rep["prefix_local_hits"] > 0
    assert rep["prefix_data_less_renewals"] > 0
    assert rep["data_less_renewals"] > 0
    assert rep["prefix_tokens_reused"] > 0
    assert rep["wire_flits"] > 0
    # reuse must beat a cold run: hits outnumber unique prefix writes
    assert rep["prefix_block_hits"] > rep["prefix_blocks_written"]


# ---------------------------------------------------------------------------
# Multi-pool paged KV: named per-stack pools interleaved in one token row
# ---------------------------------------------------------------------------

MOE_POOLS = {"dense": (4, 2, 2, 4), "moe": (4, 2, 6, 4)}   # chunk 4


@pytest.mark.parametrize("backend", ["pallas", "numpy"])
def test_multi_pool_layout_and_roundtrip(backend):
    """Named pools share one block table / free list: each stack's segment
    sits at a static LANES-aligned offset, write_kv publishes every stack
    in one dispatch, read_kv round-trips both full-row and per-stack
    windowed gathers, and a per-stack append touches only its window."""
    eng = LeaseEngine(16, lease=8, backend=backend, kv_pools=MOE_POOLS,
                      kv_dtype=np.float32)
    assert eng.pool_names == ["dense", "moe"]
    assert eng.pool_offset("dense") == 0
    assert eng.pool_offset("moe") == 128           # 16 elems -> 128 lanes
    assert eng.kv_token_row == 256                 # 48 elems -> 128 lanes
    assert eng.kv_block_shape is None              # no single-pool alias
    rng = np.random.default_rng(0)
    bd = rng.standard_normal((3, 4, 2, 2, 4)).astype(np.float32)
    bm = rng.standard_normal((3, 4, 2, 6, 4)).astype(np.float32)
    writes0 = eng.stats.kv_blocks_written
    eng.write_kv([2, 5, 9], {"dense": bd, "moe": bm})
    assert eng.stats.kv_blocks_written == writes0 + 3   # one transition/blk
    out = eng.read_kv([2, 5, 9])
    np.testing.assert_array_equal(np.asarray(out["dense"]), bd)
    np.testing.assert_array_equal(np.asarray(out["moe"]), bm)
    # per-stack windowed gather (the kernel's pool-offset index map)
    np.testing.assert_array_equal(np.asarray(eng.read_kv([5], pool="moe")),
                                  bm[1:2])
    np.testing.assert_array_equal(
        np.asarray(eng.read_kv([9], pool="dense")), bd[2:3])
    # per-stack token append: neighbors' bits and validity stay put
    tok = rng.standard_normal((2, 16)).astype(np.float32)
    eng.append_kv([2 * 4 + 1, 5 * 4 + 0], tok, pool="dense")
    out2 = eng.read_kv([2, 5])
    np.testing.assert_array_equal(np.asarray(out2["moe"]), bm[:2])
    np.testing.assert_array_equal(
        np.asarray(out2["dense"])[0, 1].ravel(), tok[0])
    np.testing.assert_array_equal(
        np.asarray(out2["dense"])[1, 0].ravel(), tok[1])
    assert eng.stats.kv_pool_tokens == {"dense": 2, "moe": 0}
    # full-row append feeds both stacks and marks content
    eng.append_kv([3 * 4 + 2], rng.standard_normal(
        (1, eng.kv_token_row)).astype(np.float32))
    assert eng.kv_ok(3)
    assert eng.stats.kv_pool_tokens == {"dense": 3, "moe": 1}
    # invalidation frees BOTH stacks (one bitmap bit per block)
    eng.invalidate_kv([2])
    assert not eng.kv_ok(2) and eng.kv_ok(5)
    with pytest.raises(ValueError):
        eng.write_kv([1], {"dense": bd[:1]})       # must name every pool


def test_multi_pool_backends_bit_identical():
    """kernel and mirror agree bit-for-bit on the whole interleaved pool
    buffer after a mixed stream of writes and per-stack/full appends."""
    engs = [LeaseEngine(16, lease=8, backend=b, kv_pools=MOE_POOLS,
                        kv_dtype=np.float32) for b in ("pallas", "numpy")]
    rng = np.random.default_rng(1)
    bd = rng.standard_normal((3, 4, 2, 2, 4)).astype(np.float32)
    bm = rng.standard_normal((3, 4, 2, 6, 4)).astype(np.float32)
    tok = rng.standard_normal((2, 16)).astype(np.float32)
    full = rng.standard_normal((1, 256)).astype(np.float32)
    for eng in engs:
        eng.write_kv([2, 5, 9], {"dense": bd, "moe": bm})
        eng.append_kv([2 * 4 + 1, 5 * 4 + 0], tok, pool="dense")
        eng.append_kv([3 * 4 + 2], full)
        # a per-stack append over a row whose lane PADDING holds nonzero
        # bits (the full random row above) must clear the whole padded
        # window on both backends, like the kernel's LANES-block DMA
        eng.append_kv([3 * 4 + 2], tok[:1], pool="dense")
        eng.append_kv([3 * 4 + 2], tok[1:].repeat(3, axis=1), pool="moe")
    np.testing.assert_array_equal(np.asarray(engs[0]._kv_pool),
                                  np.asarray(engs[1]._kv_pool))


@pytest.mark.parametrize("backend", ["pallas", "numpy"])
def test_multi_pool_rebase_and_page_free_cover_all_stacks(backend):
    """A ts_bits rebase leaves every stack's payload bits intact, and
    freeing a page invalidates both stacks at once."""
    eng = LeaseEngine(8, lease=4, backend=backend, ts_bits=7,
                      kv_pools=MOE_POOLS, kv_dtype=np.float32,
                      alloc_reserve=4)
    rng = np.random.default_rng(2)
    bd = rng.standard_normal((2, 4, 2, 2, 4)).astype(np.float32)
    bm = rng.standard_normal((2, 4, 2, 6, 4)).astype(np.float32)
    eng.write_kv([1, 3], {"dense": bd, "moe": bm})
    pts = 0
    while eng.stats.rebases == 0:
        pts = eng.write_many([[0, 1], [2, 3]], pts)
        pts = LeaseEngine.rebase_pts(pts, eng.maybe_rebase())
    out = eng.read_kv([1, 3])
    np.testing.assert_array_equal(np.asarray(out["dense"]), bd)
    np.testing.assert_array_equal(np.asarray(out["moe"]), bm)
    pages = eng.alloc_pages(2)
    eng.write_kv(pages, {"dense": bd, "moe": bm})
    assert eng.kv_ok(pages[0]) and eng.kv_ok(pages[1])
    eng.free_pages(pages)
    assert not eng.kv_ok(pages[0]) and not eng.kv_ok(pages[1])


@pytest.mark.parametrize("backend", ["pallas", "numpy"])
def test_free_pages_rejects_double_free_and_foreign_pages(backend):
    """The allocator raises -- before touching any state -- on double
    frees, frees of never-allocated pages, out-of-region ids, and
    duplicate ids inside one call."""
    eng = LeaseEngine(8, lease=4, backend=backend, alloc_reserve=4)
    pages = eng.alloc_pages(2)
    eng.free_pages(pages)
    with pytest.raises(ValueError, match="already free"):
        eng.free_pages([pages[0]])            # double free
    with pytest.raises(ValueError, match="already free"):
        eng.free_pages([7])                   # in-region, never allocated
    with pytest.raises(ValueError, match="outside the allocatable region"):
        eng.free_pages([0])                   # content-addressed region
    with pytest.raises(ValueError, match="outside the allocatable region"):
        eng.free_pages([eng.n_blocks])        # past the table
    p = eng.alloc_pages(1)
    with pytest.raises(ValueError, match="duplicate"):
        eng.free_pages([int(p[0])] * 2)
    # validate-all-first: a rejected batch must not free its valid ids
    with pytest.raises(ValueError):
        eng.free_pages([int(p[0]), 0])
    assert int(p[0]) not in eng._free_pages
    eng.free_pages(p)                         # still outstanding -> frees
    assert eng.free_page_count() == eng.n_blocks - eng.alloc_reserve


def test_free_pages_double_free_raises_with_sanitizer_attached():
    """The raising allocator and the sanitizer shadow agree: a legal
    alloc/free cycle passes every after-op check, the illegal free still
    raises first."""
    eng = LeaseEngine(8, lease=4, backend="numpy", alloc_reserve=4,
                      sanitize=True)
    pages = eng.alloc_pages(3)
    eng.free_pages(pages[:2])
    with pytest.raises(ValueError, match="already free"):
        eng.free_pages(pages)                 # 2 of 3 already free
    eng.free_pages(pages[2:])
    assert eng.sanitize_checks >= 3
