"""Sequential-consistency and protocol-equivalence tests on the simulator.

These mirror the functional checks Graphite ran for the paper: every
completed run is validated against SC Rules 1-2 in physiological order, and
the classic Listing-1 litmus outcome (A=B=0) is proven impossible.
"""
import numpy as np
import pytest

from repro.core import Geometry, SimConfig, make_trace, simulate
from repro.core.check import check_sc
from repro.core.traces import _Builder

N = 16
CFG = dict(max_steps=1_200_000)


def _litmus_trace():
    b = _Builder(2)
    b.store(0, 0)
    b.load(0, 1)
    b.store(1, 1)
    b.load(1, 0)
    return b.build(4, "litmus")


@pytest.mark.parametrize("proto", ["tardis", "directory"])
def test_litmus_no_a0_b0(proto):
    """Paper Listing 1: printing A=B=0 violates SC and must never happen."""
    tr = _litmus_trace()
    res = simulate(tr, proto, SimConfig(**CFG), log=True)
    assert not res.aborted and res.ops == 4
    check_sc(res.log, 2)
    loads = {(int(c), int(a)): int(v) for c, a, v, k in zip(
        res.log["core"], res.log["addr"], res.log["ver"], res.log["kind"])
        if k == 0}
    assert not (loads[(0, 1)] == 0 and loads[(1, 0)] == 0)


@pytest.mark.parametrize("name", ["fft", "volrend", "water_nsq", "barnes",
                                  "lu_c", "ocean_c"])
@pytest.mark.parametrize("proto", ["tardis", "directory"])
def test_sc_on_workloads(name, proto):
    tr = make_trace(name, N, scale=0.3)
    res = simulate(tr, proto, SimConfig(**CFG), log=True)
    assert not res.aborted, f"{name}/{proto} did not complete"
    assert res.ops == tr.total_ops() - np.sum(tr.op_type == 3)  # barriers
    check_sc(res.log, N)


def test_sc_under_tiny_caches():
    """Small caches force evictions + DRAM mts path; SC must still hold."""
    tr = make_trace("barnes", 8, scale=0.3)
    geom = Geometry(n_cores=8, l1_sets=4, l1_ways=2, llc_sets=4, llc_ways=2)
    res = simulate(tr, "tardis", SimConfig(**CFG), geom=geom, log=True)
    assert not res.aborted
    assert res.stats["n_dram"] > 0              # evictions actually happened
    check_sc(res.log, 8)


def test_sc_with_compression_rebase():
    """4-bit deltas roll over constantly; rebase must preserve SC."""
    tr = make_trace("volrend", 8, scale=0.4)
    res = simulate(tr, "tardis",
                   SimConfig(ts_bits=4, **CFG), log=True)
    assert not res.aborted
    assert res.stats["n_rebase_l1"] > 0
    check_sc(res.log, 8)


def test_sc_without_private_write_opt():
    tr = make_trace("water_sp", 8, scale=0.3)
    res = simulate(tr, "tardis",
                   SimConfig(private_write_opt=False, **CFG), log=True)
    assert not res.aborted
    check_sc(res.log, 8)


def test_spin_consumer_observes_update():
    """Livelock avoidance: a spinning reader eventually sees the write."""
    b = _Builder(2)
    b.store(0, 5)                  # producer writes flag (version 1)
    b.lock_acquire(1, 5)           # consumer spins for >= 1 store... but
    # lock_acquire pre-schedules version 0; use an explicit spin instead:
    b.ops[1][-1] = (2, 5, 1, 0)    # spin until version >= 1
    tr = b.build(8, "spin")
    res = simulate(tr, "tardis",
                   SimConfig(selfinc_period=10, **CFG), log=True)
    assert not res.aborted
    assert res.stats["n_selfinc"] >= 0
    check_sc(res.log, 2)


def test_protocols_agree_on_final_memory():
    """Both protocols must observe identical per-address final versions
    (same deterministic trace, same global store ordering per address)."""
    tr = make_trace("lu_c", 8, scale=0.3)
    r1 = simulate(tr, "tardis", SimConfig(**CFG), log=True)
    r2 = simulate(tr, "directory", SimConfig(**CFG), log=True)
    for log in (r1.log, r2.log):
        stores = log["kind"] == 1
        last = {}
        for a, v in zip(log["addr"][stores], log["ver"][stores]):
            last[int(a)] = max(last.get(int(a), 0), int(v))
    # store counts per address are trace-determined; both protocols must
    # have executed every store exactly once
    s1 = np.sum(r1.log["kind"] == 1)
    s2 = np.sum(r2.log["kind"] == 1)
    assert s1 == s2 == np.sum(tr.op_type == 1)


def test_ackwise_limited_directory():
    tr = make_trace("lu_c", N, scale=0.3)
    full = simulate(tr, "directory", SimConfig(**CFG))
    ack = simulate(tr, "directory", SimConfig(ackwise_k=4, **CFG))
    assert not ack.aborted
    # broadcast mode costs at least as much invalidation traffic
    assert ack.stats["n_inv_msgs"] >= full.stats["n_inv_msgs"]
