"""Property-based sequential-consistency fuzzing of the Tardis simulator.

Hypothesis generates arbitrary small multi-core programs (loads/stores over
a tiny address space, padded to a fixed rectangular trace so the jitted
simulator compiles exactly once); every interleaving the simulator produces
must satisfy SC Rules 1-2 in physiological order.  This is the
machine-checked analogue of the paper's Graphite functional checks.
"""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import SimConfig, simulate
from repro.core.check import check_sc
from repro.core.traces import END, LOAD, STORE, Trace

N_CORES = 4
LEN = 24          # fixed trace length -> one compile for the whole suite
N_ADDR = 8

op = st.tuples(st.integers(0, 1),                 # load/store
               st.integers(0, N_ADDR - 1),        # address
               st.integers(0, 3))                 # think cycles

program = st.lists(st.lists(op, min_size=1, max_size=LEN - 1),
                   min_size=N_CORES, max_size=N_CORES)


def _build(prog) -> Trace:
    t = np.full((N_CORES, LEN), END, np.int32)
    a = np.zeros((N_CORES, LEN), np.int32)
    x = np.zeros((N_CORES, LEN), np.int32)
    k = np.zeros((N_CORES, LEN), np.int32)
    for c, ops in enumerate(prog):
        for j, (kind, addr, think) in enumerate(ops):
            t[c, j] = STORE if kind else LOAD
            a[c, j] = addr
            k[c, j] = think
    return Trace(t, a, x, k, N_ADDR, "fuzz")


@given(program, st.sampled_from([1, 3, 10, 100]),
       st.sampled_from([2, 10, 50]))
@settings(max_examples=60, deadline=None)
def test_random_programs_are_sequentially_consistent(prog, lease, period):
    tr = _build(prog)
    res = simulate(tr, "tardis",
                   SimConfig(lease=lease, selfinc_period=period,
                             max_steps=50_000), log=True)
    assert not res.aborted
    check_sc(res.log, N_CORES)


@given(program)
@settings(max_examples=20, deadline=None)
def test_random_programs_directory_consistent(prog):
    tr = _build(prog)
    res = simulate(tr, "directory", SimConfig(max_steps=50_000), log=True)
    assert not res.aborted
    check_sc(res.log, N_CORES)
