"""Continuous-batching paged decode vs the dense-cache decode: bit-exact.

The serving cluster decodes every token through LeaseEngine pool pages
(``models.decode_step_paged``); the acceptance bar is that this is
*bit-exact* with the dense-cache decode path (``models.decode_step``) for
every attention-cache family -- dense/vlm AND the moe family, whose dual
cache stacks (leading dense layers + moe layers) page through named pools
interleaved in one token row -- over randomized request streams with
mid-stream joins and finishes, page-bounded admission, collision evictions
relocating pinned blocks under an active decode, and ts_bits rebases
firing between ticks.

The differential works off the cluster's trace hook: every admission
records the request's page table and the pool rows backing its prompt,
every decode tick records the batch composition and raw logits.  A dense
*shadow* then replays the exact same schedule -- same batch sizes, same
per-request positions (vector ``cur_idx``), caches seeded from the same
pool bits, each cache stack sliced out of its pool segment
(``models.pool_layout``) -- through ``decode_step`` and asserts the logits
match bit for bit.  Anything the paged path gets wrong (a token row
landing in the wrong page slot, a stack segment at the wrong pool offset,
a gather off by one, an eviction clobbering a pinned page, a rebase
touching payloads) shows up as a bit difference.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.models import decode_step, init_params, pool_layout
from repro.runtime import Request, ServingCluster

# dense single stack; kimi = dual stacks (1 leading dense + 1 moe layer
# after reduction); arctic = single moe stack (no leading dense layers)
ARCH_BASES = {
    "dense": "tinyllama-1.1b",
    "moe": "kimi-k2-1t-a32b",
    "moe-flat": "arctic-480b",
}


@functools.lru_cache(maxsize=None)
def _arch(name):
    cfg = reduced(get_arch(ARCH_BASES[name]), n_layers=2, d_model=64,
                  vocab=128)
    return cfg, init_params(cfg, jax.random.PRNGKey(0), jnp.float32)


def _cluster(arch="dense", **kw):
    cfg, params = _arch(arch)
    kw.setdefault("prefix_block_tokens", 4)
    kw.setdefault("kv_lease", 16)
    kw.setdefault("n_prefix_blocks", 64)
    kw.setdefault("n_decode_pages", 64)
    kw.setdefault("max_pages", 16)
    c = ServingCluster(cfg, lambda: params, **kw)
    c.trace = []
    return c


def _reqs(rng, cfg, n, n_prefixes=2, max_new_hi=4):
    """Random prompts drawn over a few shared system prompts + random
    suffixes and per-request decode budgets (staggered finishes)."""
    prefixes = [rng.integers(1, cfg.vocab, 4 * int(rng.integers(1, 4)))
                .astype(np.int32) for _ in range(n_prefixes)]
    out = []
    for i in range(n):
        p = prefixes[int(rng.integers(0, n_prefixes))]
        suffix = rng.integers(1, cfg.vocab,
                              int(rng.integers(1, 9))).astype(np.int32)
        out.append(Request(i, np.concatenate([p, suffix]),
                           max_new=int(rng.integers(1, max_new_hi + 1))))
    return out


def _replay_dense_shadow(arch, cluster, trace):
    """Re-run the recorded schedule on dense per-request caches seeded from
    the same pool bits and assert bitwise-equal logits every tick.  Each
    cache stack (moe: dk/dv and k/v) is sliced out of its own pool segment
    at the ``pool_layout`` offset."""
    cfg, params = _arch(arch)
    stacks = pool_layout(cfg)
    names = [k for s in stacks for k in s.cache_keys]
    bt = cluster.prefix_block_tokens
    hk, dh = cfg.n_kv_heads, cfg.head_dim()
    t_cap = cluster.max_pages * bt
    dec = jax.jit(lambda p, c, t, i: decode_step(cfg, p, c, t, i))
    caches = {}                       # rid -> {cache_key: (L_s, T, hk, dh)}
    ticks = 0
    for ev in trace:
        if ev["ev"] == "admit":
            plen = ev["prompt_len"]
            pos = np.arange(plen)
            flat = (ev["page_row"][pos // bt].astype(np.int64) * bt
                    + pos % bt)
            rows = ev["rows"][flat]                      # (plen, token_row)
            c = {}
            for s in stacks:
                kv = rows[:, s.offset:s.offset + s.token_elems].reshape(
                    plen, 2, s.n_layers, hk, dh)
                k = np.zeros((s.n_layers, t_cap, hk, dh), rows.dtype)
                v = np.zeros_like(k)
                k[:, :plen] = kv[:, 0].transpose(1, 0, 2, 3)
                v[:, :plen] = kv[:, 1].transpose(1, 0, 2, 3)
                c[s.cache_keys[0]] = k
                c[s.cache_keys[1]] = v
            caches[ev["rid"]] = c
        else:
            cache = {n: jnp.asarray(np.stack(
                [caches[r][n] for r in ev["rids"]], axis=1))
                for n in names}
            cache2, logits = dec(params, cache, jnp.asarray(ev["tokens"]),
                                 jnp.asarray(ev["lengths"], jnp.int32))
            np.testing.assert_array_equal(
                np.asarray(logits), ev["logits"],
                err_msg=f"paged decode diverged at tick {ev['tick']} "
                        f"(arch {arch}, rids {ev['rids']})")
            for i, r in enumerate(ev["rids"]):
                caches[r] = {n: np.asarray(cache2[n][:, i]) for n in names}
            ticks += 1
    return ticks


def _check_pool_drained(cluster):
    """Every page released, every pin dropped: no leaks across a run."""
    eng = cluster.prefix_engine
    assert eng.free_page_count() == cluster.n_decode_pages
    assert not cluster._pins and not cluster._reloc_refs
    assert all(not act for act in cluster._active)


@pytest.mark.parametrize("arch", sorted(ARCH_BASES))
@pytest.mark.parametrize("seed", [0, 1])
@pytest.mark.parametrize("n_replicas", [1, 2])
def test_paged_decode_bit_exact_random_streams(arch, seed, n_replicas):
    """Acceptance: randomized streams with mid-stream joins/finishes are
    bit-exact vs the dense shadow on every paged family (moe's dual cache
    stacks included), and the stream order/outputs line up."""
    cfg, _ = _arch(arch)
    rng = np.random.default_rng(seed)
    cluster = _cluster(arch, n_replicas=n_replicas)
    reqs = _reqs(rng, cfg, 10)
    done, rep = cluster.run(reqs)
    assert all(r.done and len(r.output) == r.max_new for r in done)
    ticks = _replay_dense_shadow(arch, cluster, cluster.trace)
    assert ticks > 0
    _check_pool_drained(cluster)
    assert rep["prefix_block_hits"] > 0          # prefixes really shared
    assert rep["kv_tokens_appended"] > 0         # decode wrote pool pages
    # per-stack occupancy ledger CONSISTENCY: serving appends full
    # interleaved rows, so every stack must see exactly the same token
    # traffic (whether the bits landed at the right offsets is what the
    # dense-shadow differential above proves)
    for s in pool_layout(cfg):
        assert rep[f"pool_tokens_appended_{s.pool}"] \
            == rep["kv_tokens_appended"]


@pytest.mark.parametrize("arch", ["dense", "moe"])
def test_admission_bounded_by_free_pages_joins_mid_batch(arch):
    """A tiny page budget forces the scheduler to defer admission until a
    running request frees its pages -- the joiner lands mid-batch and the
    whole stream is still bit-exact."""
    cfg, _ = _arch(arch)
    rng = np.random.default_rng(3)
    # each request needs ceil((8+4)/4) = 3 pages; budget fits two at once
    cluster = _cluster(arch, n_replicas=1, n_decode_pages=6,
                       n_prefix_blocks=64)
    reqs = [Request(i, rng.integers(1, cfg.vocab, 8).astype(np.int32),
                    max_new=2 + 2 * (i % 2)) for i in range(4)]
    done, rep = cluster.run(reqs)
    assert all(r.done and len(r.output) == r.max_new for r in done)
    assert rep["paged_admission_deferrals"] > 0
    assert rep["paged_mid_batch_admissions"] > 0
    assert rep["pool_page_peak"] <= 6
    _replay_dense_shadow(arch, cluster, cluster.trace)
    _check_pool_drained(cluster)


@pytest.mark.parametrize("arch", ["dense", "moe"])
def test_collision_eviction_relocates_pinned_blocks_mid_decode(arch):
    """A colliding admission re-tags a block an active decode still reads:
    the payload (every cache stack's segment) must relocate to a fresh page
    (zero messages), the active page table remap, and the decode stay
    bit-exact."""
    cfg, _ = _arch(arch)
    rng = np.random.default_rng(4)
    cluster = _cluster(arch, n_replicas=1, n_prefix_blocks=1, max_batch=2)
    pa = rng.integers(1, cfg.vocab, 6).astype(np.int32)   # 1 block + tail
    pb = rng.integers(1, cfg.vocab, 6).astype(np.int32)   # same bid, new tag
    # warm the pool so request A's prefix block is covered (pinned)
    cluster.run([Request(0, pa, max_new=1)])
    a = Request(1, pa, max_new=6)              # long decode, pins block 0
    # block-less filler (prompt < one chunk) holds the second batch slot so
    # the evictor can only join after it finishes -- mid-decode for A
    filler = Request(2, rng.integers(1, cfg.vocab, 3).astype(np.int32),
                     max_new=2)
    b = Request(3, pb, max_new=2)              # evicts block 0 mid-decode
    done, rep = cluster.run([a, filler, b])
    assert all(r.done for r in done)
    assert rep["pinned_relocations"] >= 1
    assert rep["prefix_evictions"] >= 1
    assert rep["paged_mid_batch_admissions"] >= 1
    _replay_dense_shadow(arch, cluster, cluster.trace)
    _check_pool_drained(cluster)


@pytest.mark.parametrize("arch", ["dense", "moe"])
def test_rebase_mid_decode_shifts_metadata_only(arch):
    """Satellite: ``maybe_rebase()`` firing between decode ticks must leave
    page payloads intact and shift only lease metadata -- live page tables
    keep decoding bit-exactly across the rebase."""
    cfg, _ = _arch(arch)
    rng = np.random.default_rng(5)
    cluster = _cluster(arch, n_replicas=2, ts_bits=5, kv_lease=4)
    reqs = _reqs(rng, cfg, 16, max_new_hi=6)
    done, rep = cluster.run(reqs)
    assert all(r.done for r in done)
    assert rep["prefix_rebases"] >= 1            # rebases really fired
    assert rep["decode_renewals"] > 0            # short leases renew in-flight
    _replay_dense_shadow(arch, cluster, cluster.trace)
    _check_pool_drained(cluster)
    # every surviving lease is under the rebased width
    for rep_ in cluster.replicas:
        assert all(r < (1 << 5) for _, r, _t in rep_.kv_leases.values())


def test_decode_holds_leases_and_ledgers_renewals():
    """Shared prefix blocks stay leased for the whole decode: ticks past
    the lease renew data-less (ONE dispatch), unexpired ticks are local
    hits, and the ledger separates the decode-time traffic."""
    cfg, _ = _arch("dense")
    rng = np.random.default_rng(6)
    cluster = _cluster(n_replicas=1, kv_lease=3)
    prefix = rng.integers(1, cfg.vocab, 8).astype(np.int32)
    cluster.run([Request(0, np.concatenate(
        [prefix, rng.integers(1, cfg.vocab, 3).astype(np.int32)]),
        max_new=1)])
    reads0 = cluster.prefix_engine.stats.read_ops
    cluster.run([Request(1, np.concatenate(
        [prefix, rng.integers(1, cfg.vocab, 3).astype(np.int32)]),
        max_new=10)])
    rep = cluster.coherence_report()
    assert rep["decode_renewals"] > 0
    assert rep["decode_local_hits"] > 0
    assert rep["decode_block_reads"] > 0
    # renewals batch: strictly fewer dispatches than (ticks x blocks)
    assert (cluster.prefix_engine.stats.read_ops - reads0
            <= 1 + rep["decode_renewals"])
    _replay_dense_shadow("dense", cluster, cluster.trace)


def test_moe_dual_stack_pool_layout_matches_engine():
    """The models' static stack offsets (pool_layout) and the engine's
    interleaved token row agree, and both stacks share the block table,
    the free list, and the validity bitmap -- one id, one transition."""
    cfg, _ = _arch("moe")
    cluster = _cluster("moe", n_replicas=1)
    eng = cluster.prefix_engine
    stacks = pool_layout(cfg)
    assert [s.pool for s in stacks] == ["dense", "moe"] == eng.pool_names
    assert cfg.first_dense_layers >= 1           # really dual stacks
    for s in stacks:
        assert eng.pool_offset(s.pool) == s.offset
        assert eng.pool_token_elems(s.pool) == s.token_elems
    assert eng.kv_token_row == sum(eng.pool_token_row(s.pool)
                                   for s in stacks)
    # one write publishes BOTH stacks; one invalidate frees both
    hk, dh = cfg.n_kv_heads, cfg.head_dim()
    bt = cluster.prefix_block_tokens
    rng = np.random.default_rng(0)
    blocks = {s.pool: rng.normal(size=(1, bt, 2, s.n_layers * hk, dh))
              .astype(np.float32) for s in stacks}
    writes0 = eng.stats.kv_blocks_written
    eng.write_kv([3], blocks)
    assert eng.stats.kv_blocks_written == writes0 + 1 and eng.kv_ok(3)
    out = eng.read_kv([3])
    for s in stacks:
        np.testing.assert_allclose(np.asarray(out[s.pool], np.float32),
                                   blocks[s.pool], rtol=0.02, atol=0.02)
        # the windowed per-stack gather sees the same bits
        np.testing.assert_array_equal(
            np.asarray(eng.read_kv([3], pool=s.pool)),
            np.asarray(out[s.pool]))
    eng.invalidate_kv([3])
    assert not eng.kv_ok(3)


def test_dense_wave_fallback_families_still_serve():
    """Only ssm/hybrid keep the fixed-wave dense-cache path (their
    recurrent states are not block-addressable); the lease metadata
    protocol still runs."""
    for base in ("mamba2-130m", "zamba2-2.7b"):
        cfg = reduced(get_arch(base))
        params = init_params(cfg, jax.random.PRNGKey(1), jnp.float32)
        cluster = ServingCluster(cfg, lambda: params, n_replicas=1,
                                 prefix_block_tokens=4, cache_len=32)
        assert not cluster.paged
        rng = np.random.default_rng(7)
        reqs = [Request(i, rng.integers(1, cfg.vocab, 8).astype(np.int32),
                        max_new=2) for i in range(2)]
        done, rep = cluster.run(reqs)
        assert all(r.done and len(r.output) == 2 for r in done)
        assert rep["prefix_block_hits"] + rep["prefix_block_misses"] > 0


def test_paged_decode_with_sanitizer_enabled():
    """The full continuous-batching run under TARDIS_SANITIZE semantics:
    every engine transition is shadow-checked, the stream stays bit-exact
    against the dense shadow, and the report ledgers the check count."""
    cfg, _ = _arch("dense")
    rng = np.random.default_rng(0)
    cluster = _cluster("dense", n_replicas=1, sanitize=True)
    reqs = _reqs(rng, cfg, 8)
    done, rep = cluster.run(reqs)
    assert all(r.done and len(r.output) == r.max_new for r in done)
    assert _replay_dense_shadow("dense", cluster, cluster.trace) > 0
    _check_pool_drained(cluster)
    assert rep["sanitize_checks"] > 0
