"""Continuous-batching paged decode vs the dense-cache decode: bit-exact.

The serving cluster decodes every token through LeaseEngine pool pages
(``models.decode_step_paged``); the acceptance bar is that this is
*bit-exact* with the dense-cache decode path (``models.decode_step``) for
the dense/vlm families -- over randomized request streams with mid-stream
joins and finishes, page-bounded admission, collision evictions relocating
pinned blocks under an active decode, and ts_bits rebases firing between
ticks.

The differential works off the cluster's trace hook: every admission
records the request's page table and the pool rows backing its prompt,
every decode tick records the batch composition and raw logits.  A dense
*shadow* then replays the exact same schedule -- same batch sizes, same
per-request positions (vector ``cur_idx``), caches seeded from the same
pool bits -- through ``decode_step`` and asserts the logits match bit for
bit.  Anything the paged path gets wrong (a token row landing in the wrong
page slot, a gather off by one, an eviction clobbering a pinned page, a
rebase touching payloads) shows up as a bit difference.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.models import decode_step, init_params
from repro.runtime import Request, ServingCluster

CFG = reduced(get_arch("tinyllama-1.1b"), n_layers=2, d_model=64, vocab=128)
PARAMS = init_params(CFG, jax.random.PRNGKey(0), jnp.float32)


def _cluster(**kw):
    kw.setdefault("prefix_block_tokens", 4)
    kw.setdefault("kv_lease", 16)
    kw.setdefault("n_prefix_blocks", 64)
    kw.setdefault("n_decode_pages", 64)
    kw.setdefault("max_pages", 16)
    c = ServingCluster(CFG, lambda: PARAMS, **kw)
    c.trace = []
    return c


def _reqs(rng, n, n_prefixes=2, max_new_hi=4):
    """Random prompts drawn over a few shared system prompts + random
    suffixes and per-request decode budgets (staggered finishes)."""
    prefixes = [rng.integers(1, CFG.vocab, 4 * int(rng.integers(1, 4)))
                .astype(np.int32) for _ in range(n_prefixes)]
    out = []
    for i in range(n):
        p = prefixes[int(rng.integers(0, n_prefixes))]
        suffix = rng.integers(1, CFG.vocab,
                              int(rng.integers(1, 9))).astype(np.int32)
        out.append(Request(i, np.concatenate([p, suffix]),
                           max_new=int(rng.integers(1, max_new_hi + 1))))
    return out


def _replay_dense_shadow(cluster, trace):
    """Re-run the recorded schedule on dense per-request caches seeded from
    the same pool bits and assert bitwise-equal logits every tick."""
    bt = cluster.prefix_block_tokens
    layers, hk = CFG.n_layers, CFG.n_kv_heads
    dh = CFG.head_dim()
    te = 2 * layers * hk * dh
    t_cap = cluster.max_pages * bt
    dec = jax.jit(lambda p, c, t, i: decode_step(CFG, p, c, t, i))
    caches = {}                       # rid -> {"k": (L,T,hk,dh), "v": ...}
    ticks = 0
    for ev in trace:
        if ev["ev"] == "admit":
            plen = ev["prompt_len"]
            pos = np.arange(plen)
            flat = (ev["page_row"][pos // bt].astype(np.int64) * bt
                    + pos % bt)
            rows = ev["rows"][flat][:, :te]              # (plen, te)
            kv = rows.reshape(plen, 2, layers, hk, dh)
            k = np.zeros((layers, t_cap, hk, dh), ev["rows"].dtype)
            v = np.zeros_like(k)
            k[:, :plen] = kv[:, 0].transpose(1, 0, 2, 3)
            v[:, :plen] = kv[:, 1].transpose(1, 0, 2, 3)
            caches[ev["rid"]] = {"k": k, "v": v}
        else:
            cache = {n: jnp.asarray(np.stack(
                [caches[r][n] for r in ev["rids"]], axis=1))
                for n in ("k", "v")}
            cache2, logits = dec(PARAMS, cache, jnp.asarray(ev["tokens"]),
                                 jnp.asarray(ev["lengths"], jnp.int32))
            np.testing.assert_array_equal(
                np.asarray(logits), ev["logits"],
                err_msg=f"paged decode diverged at tick {ev['tick']} "
                        f"(rids {ev['rids']})")
            for i, r in enumerate(ev["rids"]):
                caches[r] = {n: np.asarray(cache2[n][:, i])
                             for n in ("k", "v")}
            ticks += 1
    return ticks


def _check_pool_drained(cluster):
    """Every page released, every pin dropped: no leaks across a run."""
    eng = cluster.prefix_engine
    assert eng.free_page_count() == cluster.n_decode_pages
    assert not cluster._pins and not cluster._reloc_refs
    assert all(not act for act in cluster._active)


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("n_replicas", [1, 2])
def test_paged_decode_bit_exact_random_streams(seed, n_replicas):
    """Acceptance: randomized streams with mid-stream joins/finishes are
    bit-exact vs the dense shadow, and the stream order/outputs line up."""
    rng = np.random.default_rng(seed)
    cluster = _cluster(n_replicas=n_replicas)
    reqs = _reqs(rng, 10)
    done, rep = cluster.run(reqs)
    assert all(r.done and len(r.output) == r.max_new for r in done)
    ticks = _replay_dense_shadow(cluster, cluster.trace)
    assert ticks > 0
    _check_pool_drained(cluster)
    assert rep["prefix_block_hits"] > 0          # prefixes really shared
    assert rep["kv_tokens_appended"] > 0         # decode wrote pool pages


def test_admission_bounded_by_free_pages_joins_mid_batch():
    """A tiny page budget forces the scheduler to defer admission until a
    running request frees its pages -- the joiner lands mid-batch and the
    whole stream is still bit-exact."""
    rng = np.random.default_rng(3)
    # each request needs ceil((8+4)/4) = 3 pages; budget fits two at once
    cluster = _cluster(n_replicas=1, n_decode_pages=6, n_prefix_blocks=64)
    reqs = [Request(i, rng.integers(1, CFG.vocab, 8).astype(np.int32),
                    max_new=2 + 2 * (i % 2)) for i in range(4)]
    done, rep = cluster.run(reqs)
    assert all(r.done and len(r.output) == r.max_new for r in done)
    assert rep["paged_admission_deferrals"] > 0
    assert rep["paged_mid_batch_admissions"] > 0
    assert rep["pool_page_peak"] <= 6
    _replay_dense_shadow(cluster, cluster.trace)
    _check_pool_drained(cluster)


def test_collision_eviction_relocates_pinned_blocks_mid_decode():
    """A colliding admission re-tags a block an active decode still reads:
    the payload must relocate to a fresh page (zero messages), the active
    page table remap, and the decode stay bit-exact."""
    rng = np.random.default_rng(4)
    cluster = _cluster(n_replicas=1, n_prefix_blocks=1, max_batch=2)
    pa = rng.integers(1, CFG.vocab, 6).astype(np.int32)   # 1 block + tail
    pb = rng.integers(1, CFG.vocab, 6).astype(np.int32)   # same bid, new tag
    # warm the pool so request A's prefix block is covered (pinned)
    cluster.run([Request(0, pa, max_new=1)])
    a = Request(1, pa, max_new=6)              # long decode, pins block 0
    # block-less filler (prompt < one chunk) holds the second batch slot so
    # the evictor can only join after it finishes -- mid-decode for A
    filler = Request(2, rng.integers(1, CFG.vocab, 3).astype(np.int32),
                     max_new=2)
    b = Request(3, pb, max_new=2)              # evicts block 0 mid-decode
    done, rep = cluster.run([a, filler, b])
    assert all(r.done for r in done)
    assert rep["pinned_relocations"] >= 1
    assert rep["prefix_evictions"] >= 1
    assert rep["paged_mid_batch_admissions"] >= 1
    _replay_dense_shadow(cluster, cluster.trace)
    _check_pool_drained(cluster)


def test_rebase_mid_decode_shifts_metadata_only():
    """Satellite: ``maybe_rebase()`` firing between decode ticks must leave
    page payloads intact and shift only lease metadata -- live page tables
    keep decoding bit-exactly across the rebase."""
    rng = np.random.default_rng(5)
    cluster = _cluster(n_replicas=2, ts_bits=5, kv_lease=4)
    reqs = _reqs(rng, 16, max_new_hi=6)
    done, rep = cluster.run(reqs)
    assert all(r.done for r in done)
    assert rep["prefix_rebases"] >= 1            # rebases really fired
    assert rep["decode_renewals"] > 0            # short leases renew in-flight
    _replay_dense_shadow(cluster, cluster.trace)
    _check_pool_drained(cluster)
    # every surviving lease is under the rebased width
    for rep_ in cluster.replicas:
        assert all(r < (1 << 5) for _, r, _t in rep_.kv_leases.values())


def test_decode_holds_leases_and_ledgers_renewals():
    """Shared prefix blocks stay leased for the whole decode: ticks past
    the lease renew data-less (ONE dispatch), unexpired ticks are local
    hits, and the ledger separates the decode-time traffic."""
    rng = np.random.default_rng(6)
    cluster = _cluster(n_replicas=1, kv_lease=3)
    prefix = rng.integers(1, CFG.vocab, 8).astype(np.int32)
    cluster.run([Request(0, np.concatenate(
        [prefix, rng.integers(1, CFG.vocab, 3).astype(np.int32)]),
        max_new=1)])
    reads0 = cluster.prefix_engine.stats.read_ops
    cluster.run([Request(1, np.concatenate(
        [prefix, rng.integers(1, CFG.vocab, 3).astype(np.int32)]),
        max_new=10)])
    rep = cluster.coherence_report()
    assert rep["decode_renewals"] > 0
    assert rep["decode_local_hits"] > 0
    assert rep["decode_block_reads"] > 0
    # renewals batch: strictly fewer dispatches than (ticks x blocks)
    assert (cluster.prefix_engine.stats.read_ops - reads0
            <= 1 + rep["decode_renewals"])
    _replay_dense_shadow(cluster, cluster.trace)


def test_dense_wave_fallback_families_still_serve():
    """moe/ssm/hybrid keep the fixed-wave dense-cache path (their caches
    are not block-addressable); the lease metadata protocol still runs."""
    cfg = reduced(get_arch("mamba2-130m"))
    params = init_params(cfg, jax.random.PRNGKey(1), jnp.float32)
    cluster = ServingCluster(cfg, lambda: params, n_replicas=1,
                             prefix_block_tokens=4, cache_len=32)
    assert not cluster.paged
    rng = np.random.default_rng(7)
    reqs = [Request(i, rng.integers(1, cfg.vocab, 8).astype(np.int32),
                    max_new=2) for i in range(2)]
    done, rep = cluster.run(reqs)
    assert all(r.done and len(r.output) == 2 for r in done)
    assert rep["prefix_block_hits"] + rep["prefix_block_misses"] > 0
