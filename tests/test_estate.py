"""E-state extension (paper section IV-D): SC-preserving renewal elimination."""
from repro.core import SimConfig, make_trace, simulate
from repro.core.check import check_sc

CFG = dict(max_steps=900_000)


def test_estate_preserves_sc_and_cuts_renewals():
    tr = make_trace("water_sp", 8, scale=0.3)
    base = simulate(tr, "tardis", SimConfig(**CFG), log=True)
    est = simulate(tr, "tardis", SimConfig(estate=True, **CFG), log=True)
    check_sc(base.log, 8)
    check_sc(est.log, 8)
    assert est.stats["n_egrant"] > 0
    assert est.stats["n_renew"] < base.stats["n_renew"]
    assert est.stats["traffic"] < base.stats["traffic"]


def test_estate_sc_under_write_sharing():
    """E-granted lines must flush correctly when another core writes."""
    tr = make_trace("water_nsq", 8, scale=0.3)
    est = simulate(tr, "tardis", SimConfig(estate=True, **CFG), log=True)
    assert not est.aborted
    check_sc(est.log, 8)


def test_estate_sc_spin_workload():
    tr = make_trace("volrend", 8, scale=0.3)
    est = simulate(tr, "tardis", SimConfig(estate=True, **CFG), log=True)
    assert not est.aborted
    check_sc(est.log, 8)
