"""Test-suite bootstrap: make the suite collect in hermetic environments.

* Puts ``src/`` on sys.path so ``PYTHONPATH=src`` is not required.
* Installs the deterministic ``hypothesis`` shim when the real package is
  unavailable (no package index in CI containers).
"""
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_SRC = os.path.join(_ROOT, "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)          # for ``import benchmarks.analytic``

try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    import _hypothesis_compat
    _hypothesis_compat.install()
