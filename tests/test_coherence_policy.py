"""CoherencePolicy: the one-object coherence configuration (Tardis 2.0).

Covers the policy dataclass itself (validation, predictor bounds, the
grow/shrink step rules every engine shares), the serving-cluster
deprecation shim for the legacy ``kv_lease=``/``ts_bits=`` kwargs, the
typed ``CoherenceReport`` accessor groups, and the adaptive-lease state
machine end to end: predictions survive ``ts_bits`` rebases unshifted
(they are timestamp *deltas*), travel with pages across shard-directory
migration, evolve bit-identically to a single-engine oracle under sharded
waves, and match between the Pallas kernels and the numpy mirror.
"""
import warnings

import numpy as np
import pytest

from repro.core import (CoherencePolicy, CONSISTENCY_MODELS, LeaseEngine,
                        ShardedLeaseDirectory)
from repro.core.policy import resolve_policy

POOLS = {"k": (1, 2), "v": (1, 2)}


def _page(val):
    return {n: np.full((1,) + s, val, np.float32) for n, s in POOLS.items()}


# ---------------------------------------------------------------------------
# The dataclass: defaults, bounds, step rules, validation
# ---------------------------------------------------------------------------

def test_policy_static_bounds_collapse_to_lease():
    p = CoherencePolicy(lease=16)
    assert (p.lease_min, p.lease_max) == (16, 16)
    assert not p.predictor and p.consistency == "sc"
    assert not p.skip_expired_renewal()
    # the degenerate predictor: grow/shrink are identities at tight bounds
    assert p.grow(16) == 16 and p.shrink(16) == 16


def test_policy_predictor_default_and_explicit_bounds():
    p = CoherencePolicy(lease=16, predictor=True)
    assert (p.lease_min, p.lease_max) == (4, 128)       # [lease//4, lease*8]
    q = CoherencePolicy(lease=16, predictor=True, lease_min=2, lease_max=32)
    assert (q.lease_min, q.lease_max) == (2, 32)
    assert q.grow(32) == 32 and q.grow(20) == 32        # clamped doubling
    assert q.shrink(2) == 2 and q.shrink(5) == 2        # clamped halving
    r = q.with_(consistency="tso")
    assert r.skip_expired_renewal() and q.consistency == "sc"
    assert CoherencePolicy.from_legacy(lease=8, ts_bits=12).ts_bits == 12


def test_policy_validation_rejects_bad_configs():
    with pytest.raises(ValueError, match="consistency"):
        CoherencePolicy(consistency="weak")
    with pytest.raises(ValueError, match="lease must be"):
        CoherencePolicy(lease=0)
    with pytest.raises(ValueError, match="lease_min <= lease"):
        CoherencePolicy(lease=4, lease_min=8, predictor=True)
    with pytest.raises(ValueError, match="lease_min <= lease"):
        CoherencePolicy(lease=4, lease_max=2, predictor=True)
    with pytest.raises(ValueError, match="ts_bits"):
        CoherencePolicy(ts_bits=1)
    assert set(CONSISTENCY_MODELS) == {"sc", "tso", "rc"}


def test_resolve_policy_precedence():
    given = CoherencePolicy(lease=5)
    assert resolve_policy(given, lease=99, ts_bits=4) is given
    folded = resolve_policy(None, lease=7, ts_bits=9)
    assert (folded.lease, folded.ts_bits) == (7, 9)
    defaulted = resolve_policy(None, lease=None, ts_bits=None,
                               default_lease=21, default_ts_bits=11)
    assert (defaulted.lease, defaulted.ts_bits) == (21, 11)


# ---------------------------------------------------------------------------
# Serving-cluster API: policy= is first class, legacy kwargs one release out
# ---------------------------------------------------------------------------

def _tiny_cluster(**kw):
    import jax
    import jax.numpy as jnp
    from repro.configs import get_arch, reduced
    from repro.models import init_params
    from repro.runtime import ServingCluster

    cfg = reduced(get_arch("tinyllama-1.1b"), n_layers=2, d_model=64,
                  vocab=128)
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    return ServingCluster(cfg, lambda: params, **kw)


def test_legacy_kv_lease_kwarg_deprecated_but_working():
    with pytest.warns(DeprecationWarning, match="kv_lease=/ts_bits="):
        cluster = _tiny_cluster(n_replicas=1, prefix_block_tokens=4,
                                kv_lease=32)
    assert cluster.policy.lease == 32
    assert cluster.prefix_engine.lease == 32
    with pytest.warns(DeprecationWarning):
        cluster = _tiny_cluster(n_replicas=1, prefix_block_tokens=4,
                                ts_bits=12)
    assert cluster.policy.ts_bits == 12


def test_policy_kwarg_is_silent_and_exclusive():
    pol = CoherencePolicy(consistency="tso", lease=32, predictor=True)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        cluster = _tiny_cluster(n_replicas=1, prefix_block_tokens=4,
                                policy=pol)
    assert cluster.policy is pol
    assert cluster.prefix_engine.policy is pol
    with pytest.raises(ValueError, match="not both"):
        _tiny_cluster(n_replicas=1, prefix_block_tokens=4,
                      policy=pol, kv_lease=16)


def test_coherence_report_typed_accessors_keep_flat_keys():
    pol = CoherencePolicy(consistency="tso", lease=16, predictor=True)
    cluster = _tiny_cluster(n_replicas=1, prefix_block_tokens=4, policy=pol)
    rep = cluster.coherence_report()
    assert isinstance(rep, dict)                       # flat view intact
    assert rep["consistency"] == "tso"
    assert rep["kv_lease"] == 16
    lease = rep.lease
    assert lease["consistency"] == "tso"
    assert {"renewals", "decode_renewals_skipped", "pred_grows",
            "pred_shrinks"} <= set(lease)
    assert all(k in rep for k in lease)                # group == flat subset
    for group in (rep.xhost, rep.role, rep.router):
        assert isinstance(group, dict)
        for k in group:                                # prefixes stripped
            assert not k.startswith(("xhost_", "role_", "router_"))


# ---------------------------------------------------------------------------
# Predictor state machine on one engine
# ---------------------------------------------------------------------------

def _pol(**kw):
    kw.setdefault("lease", 4)
    kw.setdefault("predictor", True)
    kw.setdefault("lease_min", 1)
    kw.setdefault("lease_max", 64)
    return CoherencePolicy(**kw)


def test_predictor_grows_on_wasted_renewal_shrinks_on_write():
    eng = LeaseEngine(4, policy=_pol(), backend="numpy")
    pts = eng.write([0], 0)                            # every write shrinks
    assert int(eng.pred_lease[0]) == _pol().shrink(4) == 2
    r = eng.read([0], pts, req_wts=[-1])               # fetch: no copy yet
    assert int(eng.pred_lease[0]) == 2                 # fetch never grows
    wts = int(r.wts[0])
    expect = 2
    for _ in range(3):                                 # wasted renewals:
        pts = int(r.rts[0]) + 1                        # expired ...
        r = eng.read([0], pts, req_wts=[wts])          # ... and unchanged
        expect = _pol().grow(expect)                   # 2 -> 4 -> 8 -> 16
        assert int(eng.pred_lease[0]) == expect
    assert eng.stats.pred_grows == 3
    pts = eng.write([0], int(r.new_pts))               # writer was blocked
    assert int(eng.pred_lease[0]) == _pol().shrink(expect)
    assert eng.stats.pred_shrinks == 2                 # seed write + this one
    # stale-version renewal (copy outdated): payload refresh, no growth
    r = eng.read([0], pts, req_wts=[wts])
    assert not bool(r.renew_ok[0])
    assert int(eng.pred_lease[0]) == _pol().shrink(expect)
    rep = eng.report()
    assert rep["pred_grows"] == 3 and rep["pred_shrinks"] == 2
    assert rep["pred_lease_lo"] <= rep["pred_lease_hi"]


def test_predictor_off_is_bit_identical_to_static():
    """A predictor-off policy is the legacy protocol exactly: same tables
    as a legacy-kwarg engine on the same stream, zero predictor motion."""
    a = LeaseEngine(4, lease=4, backend="numpy")
    b = LeaseEngine(4, policy=CoherencePolicy(lease=4), backend="numpy")
    pa = pb = 0
    for step in range(12):
        if step % 3 == 0:
            pa = a.write([step % 4], pa)
            pb = b.write([step % 4], pb)
        else:
            ra = a.read([step % 4], pa, req_wts=[-1])
            rb = b.read([step % 4], pb, req_wts=[-1])
            pa, pb = int(ra.new_pts), int(rb.new_pts)
    np.testing.assert_array_equal(a.wts, b.wts)
    np.testing.assert_array_equal(a.rts, b.rts)
    assert b.stats.pred_grows == 0 and b.stats.pred_shrinks == 0


def test_predictor_survives_ts_bits_rebase():
    """Predicted leases are timestamp DELTAS: a table rebase shifts wts/rts
    down uniformly but must leave every per-block prediction untouched."""
    eng = LeaseEngine(4, policy=_pol(ts_bits=8), backend="numpy")
    pts = eng.write([0], 300)                          # past the 8-bit guard
    r = eng.read([0], pts, req_wts=[-1])
    wts = int(r.wts[0])
    for _ in range(2):                                 # grow 2 -> 4 -> 8
        r = eng.read([0], int(r.rts[0]) + 1, req_wts=[wts])
    pred_before = eng.pred_lease.copy()
    assert int(pred_before[0]) == 8
    wts_before, rts_before = eng.wts.copy(), eng.rts.copy()
    shift = eng.maybe_rebase()
    assert shift > 0
    np.testing.assert_array_equal(eng.pred_lease, pred_before)
    np.testing.assert_array_equal(
        eng.wts, np.maximum(0, wts_before.astype(np.int64) - shift))
    np.testing.assert_array_equal(
        eng.rts, np.maximum(0, rts_before.astype(np.int64) - shift))
    # and the next wasted renewal keeps tuning from where it left off
    r = eng.read([0], int(eng.rts[0]) + 1,
                 req_wts=[int(eng.wts[0])])
    assert int(eng.pred_lease[0]) == 16


# ---------------------------------------------------------------------------
# Predictor across the sharded directory
# ---------------------------------------------------------------------------

def test_sharded_predictor_matches_single_engine_oracle():
    """Random wave streams under the predictor: the reassembled per-block
    predicted-lease table tracks ONE LeaseEngine driven with the same
    per-owner-shard batches, wave by wave (sharding changes the wire,
    never the learned leases)."""
    rng = np.random.default_rng(11)
    pol = _pol(lease=4, lease_max=32)
    d = ShardedLeaseDirectory(16, 4, n_hosts=2, policy=pol, backend="numpy")
    oracle = LeaseEngine(16, policy=pol, backend="numpy")
    pts = 0
    for step in range(50):
        host = step % 2
        if rng.random() < 0.3:
            bids = sorted(rng.choice(16, rng.integers(1, 4),
                                     replace=False).tolist())
            res = d.wave(host, pts, write_bids=bids, tag_writes_with_ts=True)
            for s in sorted({d.owner(b) for b in bids}):
                oracle.write([b for b in bids if d.owner(b) == s], pts)
            pts = res.new_pts
        else:
            bids = sorted(rng.choice(16, rng.integers(1, 5),
                                     replace=False).tolist())
            # renew with the current wts so a post-expiry renewal is
            # exactly the "wasted traffic" signal the predictor feeds on
            req = {b: int(oracle.wts[b]) for b in bids}
            res = d.wave(host, pts, read_groups=[bids], req_wts=req)
            for s in sorted({d.owner(b) for b in bids}):
                part = [b for b in bids if d.owner(b) == s]
                oracle.read(part, pts, req_wts=[req[b] for b in part])
            pts = res.new_pts
        pts += int(rng.integers(0, 10))                # age leases out
        np.testing.assert_array_equal(d.pred_lease, oracle.pred_lease)
        np.testing.assert_array_equal(d.wts, oracle.wts)
        np.testing.assert_array_equal(d.rts, oracle.rts)
    grows = sum(e.stats.pred_grows for e in d.shards)
    shrinks = sum(e.stats.pred_shrinks for e in d.shards)
    assert grows == oracle.stats.pred_grows > 0
    assert shrinks == oracle.stats.pred_shrinks > 0
    assert d.report()["xhost_pred_grows"] == grows


def test_pred_lease_travels_with_page_migration():
    """A migrated page carries the owner's learned lease: the FetchedPage
    snapshot equals the owner-shard prediction at fetch time, and
    ``set_pred_lease`` installs it (clipped to the local bounds)."""
    pol = _pol(lease=4, lease_max=64)
    d = ShardedLeaseDirectory(8, 2, n_hosts=2, policy=pol, backend="numpy",
                              kv_pools=POOLS, kv_dtype=np.float32,
                              block_bytes=16, sanitize=True)
    res = d.wave(0, 0, write_bids=[1], write_tags=[7])
    ts = res.write_ts[1]
    d.defer_publish(0, 1, _page(float(ts)))
    d.flush_deferred(0)
    # grow block 1's prediction with wasted renewals from the writer host
    pts = ts
    for _ in range(3):
        r = d.wave(0, pts, read_groups=[[1]], req_wts={1: ts})
        pts = r.leases[1][1] + 1                       # past the new rts
    assert int(d.pred_lease[1]) > pol.shrink(4)        # it did grow
    res = d.wave(1, pts, fetch_bids=[1])               # host 1 borrows it
    page = res.fetched[1]
    assert page.pred_lease == int(d.pred_lease[1])
    assert (page.wts, page.rts) == res.leases[1]
    # install on a destination engine with tighter bounds: clipped
    dest = LeaseEngine(8, policy=_pol(lease=4, lease_max=8),
                       backend="numpy")
    dest.set_pred_lease([1], page.pred_lease)
    assert int(dest.pred_lease[1]) == min(8, page.pred_lease)


# ---------------------------------------------------------------------------
# Kernel vs mirror: the predictor is backend-invariant
# ---------------------------------------------------------------------------

def test_predictor_bit_identical_pallas_vs_numpy():
    pol = _pol(lease=4, lease_max=32)
    engines = {b: LeaseEngine(8, policy=pol, backend=b)
               for b in ("pallas", "numpy")}
    rng = np.random.default_rng(3)
    script = []
    pts = 0
    for step in range(16):
        idx = sorted(rng.choice(8, 2, replace=False).tolist())
        if step % 4 == 0:
            script.append(("write", idx, pts))
            pts += 5
        else:
            script.append(("read", idx, pts))
            pts += int(rng.integers(0, 9))
    for name, eng in engines.items():
        wts_seen = np.full(8, -1, np.int64)
        for op, idx, p in script:
            if op == "write":
                eng.write(idx, p)
                wts_seen[idx] = -1                     # copies invalidated
            else:
                r = eng.read(idx, p, req_wts=wts_seen[idx].tolist())
                wts_seen[idx] = np.asarray(r.wts, np.int64)
    a, b = engines["pallas"], engines["numpy"]
    np.testing.assert_array_equal(np.asarray(a.wts), np.asarray(b.wts))
    np.testing.assert_array_equal(np.asarray(a.rts), np.asarray(b.rts))
    np.testing.assert_array_equal(a.pred_lease, b.pred_lease)
    assert a.stats.pred_grows == b.stats.pred_grows > 0
    assert a.stats.pred_shrinks == b.stats.pred_shrinks > 0
