"""Unit tests for the repro.dist layer beyond what the substrate suite pins:
batch/cache placement rules, sharding tree structure, and the ambient-mesh
behaviour of the activation annotations."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_arch, reduced
from repro.dist import annotate
from repro.dist import sharding as shd
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import cache_specs
from repro.models import abstract_params
from repro.optim import adamw


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


MULTI = FakeMesh({"pod": 2, "data": 16, "model": 16})


class TestBatchSpec:
    def test_batch_dim_over_dp(self):
        assert shd.batch_spec(MULTI, (256, 4096)) == P(("pod", "data"), None)

    def test_indivisible_batch_replicates(self):
        assert shd.batch_spec(MULTI, (1, 64)) == P(None, None)

    def test_uneven_batch_drops_pod(self):
        assert shd.batch_spec(MULTI, (16, 64)) == P("data", None)

    def test_scalar_leaf_replicates(self):
        assert shd.batch_spec(MULTI, ()) == P()

    def test_activation_spec_sequence_parallel(self):
        # residual stream (B, S, D): batch over DP, sequence over the
        # otherwise-idle model axis -- the long-context activation fix
        assert shd.activation_spec(MULTI, (16, 500000, 1024)) == \
            P("data", "model", None)
        assert shd.activation_spec(MULTI, (256, 4096, 1024)) == \
            P(("pod", "data"), "model", None)

    def test_activation_spec_guards(self):
        # 2-D activations never sequence-shard; indivisible seq replicates
        assert shd.activation_spec(MULTI, (256, 4096)) == \
            P(("pod", "data"), None)
        assert shd.activation_spec(MULTI, (16, 4097, 1024)) == \
            P("data", None, None)
        assert shd.activation_spec(MULTI, ()) == P()

    def test_shardings_tree_structure(self):
        mesh = make_host_mesh(data=1, model=1)
        batch = {"tokens": jax.ShapeDtypeStruct((4, 32), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((4, 32), jnp.int32)}
        out = shd.batch_shardings(mesh, batch)
        assert set(out) == {"tokens", "labels"}
        for ns in out.values():
            assert isinstance(ns, NamedSharding)
            assert len(ns.spec) == 2


class TestCacheSpec:
    def test_kv_cache_rule(self):
        cfg = get_arch("tinyllama-1.1b")
        cache = cache_specs(cfg, 128, 32768)
        sk = shd.cache_spec(MULTI, "k", cache["k"].shape)
        # (L, B, T, H, Dh): batch over DP, kv heads over model if divisible
        assert sk[1] == ("pod", "data")
        hk = cache["k"].shape[3]
        assert sk[3] == ("model" if hk % 16 == 0 else None)
        assert sk[0] is None and sk[2] is None

    def test_ssm_cache_rule(self):
        cfg = get_arch("mamba2-130m")
        cache = cache_specs(cfg, 128, 32768)
        st = shd.cache_spec(MULTI, "state", cache["state"].shape)
        assert st[1] == ("pod", "data")          # (L, B, H, P, N)
        h = cache["state"].shape[2]
        assert st[2] == ("model" if h % 16 == 0 else None)

    def test_shardings_tree_matches_for_every_family(self):
        mesh = make_host_mesh(data=1, model=1)
        for arch in ("tinyllama-1.1b", "kimi-k2-1t-a32b", "zamba2-2.7b",
                     "whisper-large-v3"):
            cfg = get_arch(arch)
            cache = cache_specs(cfg, 128, 1024,
                                1024 if cfg.family == "encdec" else 0)
            out = shd.cache_shardings(mesh, cache)
            assert jax.tree.structure(out) == jax.tree.structure(cache)
            for ns in jax.tree.leaves(out):
                assert isinstance(ns, NamedSharding)


class TestOptShardings:
    def test_moments_mirror_params_step_replicates(self):
        mesh = make_host_mesh(data=1, model=1)
        cfg = reduced(get_arch("tinyllama-1.1b"))
        params = abstract_params(cfg, jnp.float32)
        pshard = shd.param_shardings(mesh, params)
        opt = jax.eval_shape(adamw.init, params)
        out = shd.opt_shardings(mesh, opt, pshard)
        assert jax.tree.structure(out) == jax.tree.structure(opt)
        assert out["m"] is pshard and out["v"] is pshard
        assert out["step"].spec == P()


class TestAnnotate:
    def test_noop_without_mesh(self):
        assert annotate.ambient_mesh() is None
        x = jnp.ones((4, 8, 16))
        assert annotate.batch_activations(x) is x
        assert annotate.replicate(x) is x

    def test_noop_under_jit_without_mesh(self):
        x = jnp.ones((4, 8))
        y = jax.jit(annotate.batch_activations)(x)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(x))

    def test_constrains_under_ambient_mesh(self):
        mesh = make_host_mesh(data=1, model=1)
        x = jnp.ones((4, 8, 16))
        with mesh:
            assert annotate.ambient_mesh() is not None
            y = jax.jit(annotate.batch_activations)(x)
            z = jax.jit(annotate.replicate)(x)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(x))
        np.testing.assert_array_equal(np.asarray(z), np.asarray(x))

    def test_value_preserved_through_grad(self):
        mesh = make_host_mesh(data=1, model=1)

        def f(x):
            return jnp.sum(annotate.batch_activations(x) ** 2)
        x = jnp.arange(8.0).reshape(2, 4)
        with mesh:
            g = jax.grad(f)(x)
        np.testing.assert_allclose(np.asarray(g), 2 * np.asarray(x))


class TestParamShardingsEndToEnd:
    def test_every_leaf_gets_named_sharding(self):
        cfg = get_arch("kimi-k2-1t-a32b")
        params = abstract_params(cfg)
        mesh = make_host_mesh(data=1, model=1)
        pshard = shd.param_shardings(mesh, params)
        assert jax.tree.structure(pshard) == jax.tree.structure(params)
        for leaf, ns in zip(jax.tree.leaves(params), jax.tree.leaves(pshard)):
            assert isinstance(ns, NamedSharding)
            assert len(ns.spec) == len(leaf.shape)

    def test_moe_expert_placement_spec(self):
        # full kimi config on the multi-pod mesh: experts -> model (EP),
        # d_model -> ('pod','data') FSDP, layer axis replicated.
        cfg = get_arch("kimi-k2-1t-a32b")
        e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff_expert
        n_moe = cfg.n_layers - cfg.first_dense_layers
        up = shd.param_spec(MULTI, ("layers", "moe", "w_up"), (n_moe, e, d, f))
        assert up[0] is None and up[1] == "model"
        down = shd.param_spec(MULTI, ("layers", "moe", "w_down"),
                              (n_moe, e, f, d))
        assert down[0] is None and down[1] == "model"
        # d_model dim carries the FSDP axes on both layouts
        assert up[2] == shd._dp_axes(MULTI, d)
        assert down[3] == shd._dp_axes(MULTI, d)
