"""End-to-end behaviour tests: fault-tolerant training, Tardis-coherent
serving, elastic DP, and a small-mesh dry-run of the launch machinery."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPE_BY_NAME, get_arch, reduced
from repro.dist import sharding as shd
from repro.models import abstract_params, init_params, loss_fn
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import input_specs, make_serve_step, make_train_step
from repro.optim import adamw
from repro.runtime import (ElasticTrainer, Request, ServingCluster,
                           TrainConfig, train)

CFG = reduced(get_arch("tinyllama-1.1b"), n_layers=2, d_model=64, vocab=128)


def test_train_with_crash_and_restart():
    params = init_params(CFG, jax.random.PRNGKey(0), jnp.float32)
    with tempfile.TemporaryDirectory() as d:
        tc = TrainConfig(steps=30, ckpt_dir=d, ckpt_every=10, batch=4,
                         seq=32, fail_at_step=17, grad_compression=True,
                         n_micro=2)
        out = train(CFG, params, tc)
    assert out["restarts"] == 1
    assert out["final_step"] == 30
    assert out["losses"][-1] < out["losses"][0]      # actually learned


def test_serving_cluster_coherence():
    params = init_params(CFG, jax.random.PRNGKey(0), jnp.float32)
    cluster = ServingCluster(CFG, lambda: params, n_replicas=2, lease=6,
                             cache_len=64, selfinc_period=2)
    reqs = [Request(i, np.arange(1, 9, dtype=np.int32) % CFG.vocab,
                    max_new=4) for i in range(6)]
    done, rep = cluster.run(reqs)
    assert all(r.done and len(r.output) == 4 for r in done)
    assert rep["replica_local_hits"] > 0             # leases actually used
    # weight hot-swap: no invalidations ever recorded by Tardis itself
    cluster.publish_weights(params)
    _, rep = cluster.run([Request(99, np.arange(1, 5, dtype=np.int32),
                                  max_new=2)])
    assert rep["data_less_renewals"] + rep["payload_transfers"] >= 1


def test_elastic_dp_bounded_staleness():
    params = init_params(CFG, jax.random.PRNGKey(0), jnp.float32)

    def grad_fn(p, b):
        return jax.value_and_grad(lambda pp: loss_fn(CFG, pp, b))(p)

    def make_batch(s, i):
        rng = np.random.default_rng(s * 100 + i)
        t = rng.integers(0, CFG.vocab, (2, 16)).astype(np.int32)
        return {"tokens": jnp.asarray(t), "labels": jnp.asarray(t)}

    et = ElasticTrainer(params, grad_fn, make_batch, lease=2)
    rep = et.run(8, schedule=lambda s: [1, 2, 3, 2, 4, 2, 1, 2][s])
    assert rep.joins >= 4 and rep.leaves >= 2        # elasticity exercised
    assert rep.renewals > 0                          # leases expired + renewed
    assert rep.max_staleness <= 3 * (2 + 1)          # bounded logical staleness


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "mamba2-130m"])
def test_dryrun_machinery_small_mesh(arch):
    """The launch/dryrun cell logic on a 1x1 host mesh with reduced configs:
    lower + compile + cost analysis must succeed for train and serve."""
    cfg = reduced(get_arch(arch))
    mesh = make_host_mesh(data=1, model=1)
    params = abstract_params(cfg, jnp.float32)
    pshard = shd.param_shardings(mesh, params)
    params = jax.tree.map(
        lambda s, ns: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=ns),
        params, pshard)
    opt = jax.eval_shape(adamw.init, params)
    opt = jax.tree.map(
        lambda s, ns: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=ns),
        opt, shd.opt_shardings(mesh, opt, pshard))
    batch = {
        "tokens": jax.ShapeDtypeStruct((4, 32), jnp.int32),
        "labels": jax.ShapeDtypeStruct((4, 32), jnp.int32),
    }
    step = make_train_step(cfg)
    compiled = jax.jit(step, donate_argnums=(0, 1)).lower(
        params, opt, batch).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    assert ca.get("flops", 0) > 0
