"""Deterministic fallback for ``hypothesis`` in no-network environments.

The tier-1 suite property-tests the Tardis protocol rules with hypothesis,
but this container has no package index, so ``conftest.py`` installs this
module under ``sys.modules["hypothesis"]`` when the real library is missing.
It implements just the surface the suite uses -- ``given``, ``settings``,
``assume``, and the ``strategies`` constructors ``integers``, ``lists``,
``tuples``, ``sampled_from``, ``booleans``, ``floats`` -- backed by a
``random.Random`` seeded from the test's qualified name, so every run draws
the same examples (no shrinking, no example database).
"""
from __future__ import annotations

import functools
import inspect
import random
import sys
import types
import zlib

DEFAULT_MAX_EXAMPLES = 100


class _Unsatisfied(Exception):
    """Raised by assume() to skip the current example."""


def assume(condition):
    if not condition:
        raise _Unsatisfied()
    return True


class Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)

    def map(self, fn):
        return Strategy(lambda rng: fn(self._draw(rng)))

    def filter(self, pred):
        def draw(rng):
            for _ in range(1000):
                v = self._draw(rng)
                if pred(v):
                    return v
            raise _Unsatisfied()
        return Strategy(draw)


def integers(min_value=0, max_value=None):
    if max_value is None:
        max_value = min_value + (1 << 30)
    return Strategy(lambda rng: rng.randint(min_value, max_value))


def booleans():
    return Strategy(lambda rng: bool(rng.getrandbits(1)))


def floats(min_value=0.0, max_value=1.0, **_kw):
    return Strategy(lambda rng: rng.uniform(min_value, max_value))


def lists(elements, min_size=0, max_size=None):
    if max_size is None:
        max_size = min_size + 10

    def draw(rng):
        n = rng.randint(min_size, max_size)
        return [elements.example(rng) for _ in range(n)]
    return Strategy(draw)


def tuples(*strats):
    return Strategy(lambda rng: tuple(s.example(rng) for s in strats))


def sampled_from(seq):
    seq = list(seq)
    return Strategy(lambda rng: seq[rng.randrange(len(seq))])


def just(value):
    return Strategy(lambda rng: value)


def one_of(*strats):
    return Strategy(lambda rng: strats[rng.randrange(len(strats))].example(rng))


class settings:
    """Both the decorator form (@settings(...)) and a no-op profile API."""

    def __init__(self, max_examples: int = DEFAULT_MAX_EXAMPLES,
                 deadline=None, **_kw):
        self.max_examples = max_examples
        self.deadline = deadline

    def __call__(self, fn):
        fn._compat_settings = self
        return fn

    @staticmethod
    def register_profile(*_a, **_kw):
        pass

    @staticmethod
    def load_profile(*_a, **_kw):
        pass


def given(*strat_args, **strat_kwargs):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            cfg = (getattr(wrapper, "_compat_settings", None)
                   or getattr(fn, "_compat_settings", None))
            n = cfg.max_examples if cfg else DEFAULT_MAX_EXAMPLES
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            executed = 0
            for _ in range(n):
                try:
                    pos = tuple(s.example(rng) for s in strat_args)
                    kw = {k: s.example(rng) for k, s in strat_kwargs.items()}
                    fn(*args, *pos, **kw, **kwargs)
                except _Unsatisfied:
                    continue
                executed += 1
            if n > 0 and executed == 0:
                raise AssertionError(
                    f"{fn.__qualname__}: assume()/filter() rejected all "
                    f"{n} examples (vacuous property test)")
        wrapper.hypothesis = types.SimpleNamespace(inner_test=fn)
        # Hide the strategy-bound parameters from pytest's fixture resolver:
        # keep only 'self' (and any params not drawn from strategies).
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        keep, to_drop = [], len(strat_args)
        for p in params:
            if p.name == "self":
                keep.append(p)
            elif to_drop > 0:
                to_drop -= 1
            elif p.name not in strat_kwargs:
                keep.append(p)
        wrapper.__signature__ = sig.replace(parameters=keep)
        return wrapper
    return deco


class HealthCheck:
    too_slow = "too_slow"
    data_too_large = "data_too_large"
    filter_too_much = "filter_too_much"

    @staticmethod
    def all():
        return []


def install() -> None:
    """Register this shim as the ``hypothesis`` package in sys.modules."""
    st = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "booleans", "floats", "lists", "tuples",
                 "sampled_from", "just", "one_of"):
        setattr(st, name, globals()[name])
    st.SearchStrategy = Strategy

    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.assume = assume
    hyp.HealthCheck = HealthCheck
    hyp.strategies = st
    hyp.__version__ = "0.0-compat"
    hyp.__is_repro_compat_shim__ = True

    sys.modules.setdefault("hypothesis", hyp)
    sys.modules.setdefault("hypothesis.strategies", st)
