"""Unit + property tests for the Tardis protocol rules (paper Tables I-III)."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import protocol as P

ts = st.integers(min_value=0, max_value=2**28)
lease = st.integers(min_value=1, max_value=1000)


class TestTableI:
    def test_load_updates(self):
        pts, rts = P.load_no_cache(5, 10, 12)
        assert pts == 10 and rts == 12          # pts joins wts; rts keeps max

    def test_load_bumps_rts(self):
        pts, rts = P.load_no_cache(20, 10, 12)
        assert pts == 20 and rts == 20

    def test_store_jumps_past_lease(self):
        pts, wts, rts = P.store_no_cache(3, 10, 17)
        assert pts == wts == rts == 18          # rts + 1

    @given(pts=ts, wts=ts, rts=ts)
    @settings(max_examples=200, deadline=None)
    def test_load_monotone(self, pts, wts, rts):
        rts = max(rts, wts)
        new_pts, new_rts = P.load_no_cache(pts, wts, rts)
        assert new_pts >= pts                    # Rule 1: pts never decreases
        assert new_pts >= wts                    # Rule 2: after the write
        assert new_rts >= rts

    @given(pts=ts, wts=ts, rts=ts)
    @settings(max_examples=200, deadline=None)
    def test_store_after_all_reads(self, pts, wts, rts):
        rts = max(rts, wts)
        new_pts, new_wts, new_rts = P.store_no_cache(pts, wts, rts)
        assert new_pts > rts                     # write ordered after last read
        assert new_pts >= pts
        assert new_wts == new_rts == new_pts


class TestTableII:
    @given(pts=ts, wts=ts, rts=ts)
    @settings(max_examples=200, deadline=None)
    def test_exclusive_store_exceeds_reads(self, pts, wts, rts):
        p2, w2, r2 = P.store_hit_exclusive(pts, rts)
        assert p2 == w2 == r2 and p2 > rts and p2 >= pts

    @given(pts=ts, rts=ts)
    @settings(max_examples=200, deadline=None)
    def test_private_write_no_advance(self, pts, rts):
        p2, w2, r2 = P.store_hit_private(pts, rts)
        assert p2 == max(pts, rts)               # no +1: physical order implicit

    @given(wts=ts, rts=ts, pts=ts, l=lease)
    @settings(max_examples=200, deadline=None)
    def test_writeback_extends(self, wts, rts, pts, l):
        out = P.writeback_rts(wts, rts, pts, l)
        assert out >= rts and out >= wts + l and out >= pts + l


class TestTableIII:
    @given(wts=ts, rts=ts, pts=ts, l=lease)
    @settings(max_examples=200, deadline=None)
    def test_lease_extend_covers_reader(self, wts, rts, pts, l):
        out = P.lease_extend(wts, rts, pts, l)
        assert out >= pts + l                     # reader can read till pts+l
        assert out >= rts                         # never shrinks a lease

    def test_renewable_is_version_match(self):
        assert bool(P.renewable(7, 7)) and not bool(P.renewable(6, 7))

    @given(mts=ts, rts=ts)
    @settings(max_examples=100, deadline=None)
    def test_evict_mts_monotone(self, mts, rts):
        assert P.evict_mts(mts, rts) == max(mts, rts)


class TestBatched:
    @given(st.lists(st.tuples(ts, ts), min_size=1, max_size=50), ts)
    @settings(max_examples=100, deadline=None)
    def test_batched_read_check(self, pairs, pts):
        wts = jnp.array([min(a, b) for a, b in pairs])
        rts = jnp.array([max(a, b) for a, b in pairs])
        readable, new_pts = P.batched_read_check(pts, wts, rts)
        np.testing.assert_array_equal(np.asarray(readable), pts <= np.asarray(rts))
        assert new_pts >= pts

    @given(st.lists(ts, min_size=1, max_size=50), ts)
    @settings(max_examples=100, deadline=None)
    def test_batched_write_advance(self, rts_list, pts):
        rts = jnp.array(rts_list)
        mask = jnp.ones(len(rts_list), bool)
        new_pts, new_wts, new_rts = P.batched_write_advance(pts, rts, mask)
        assert new_pts > max(rts_list)            # jumps every lease
        assert new_pts >= pts
        np.testing.assert_array_equal(np.asarray(new_wts), new_pts)


def test_example_program_figure1():
    """Paper Fig. 1 walk-through (lease=10): the exact timestamps."""
    lease_ = 10
    pts0 = pts1 = 0
    # step 1: core0 stores A (rts=wts=0 at manager)
    pts0, a_wts, a_rts = P.store_no_cache(pts0, 0, 0)
    assert pts0 == 1 and a_wts == 1
    # step 2: core0 loads B -> lease to max(rts, wts+lease, pts+lease) = 11
    b_rts = int(P.lease_extend(0, 0, pts0, lease_))
    assert b_rts == 11
    # step 3: core1 stores B: jumps to rts+1 = 12 without invalidating core0
    pts1, b_wts2, b_rts2 = P.store_no_cache(pts1, 0, b_rts)
    assert pts1 == 12
    # core0 can still read its leased B=0 copy at pts0=1 <= 11: legal
    assert pts0 <= b_rts
