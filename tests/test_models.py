"""Model-zoo tests: per-arch smoke, attention equivalence, decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch, reduced
from repro.models import (decode_step, forward, init_params, logits_fn,
                          loss_fn, prefill)
from repro.models.attention import flash_attention, reference_attention
from repro.models.layers import chunked_xent
from repro.models.moe import moe_apply, moe_init

KEY = jax.random.PRNGKey(0)
B, S = 2, 32


def _batch(cfg, key=KEY, s=S):
    tokens = jax.random.randint(key, (B, s), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(key, (B, s, cfg.d_model))
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.random.normal(key, (B, 8, cfg.d_model))
    return batch


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_arch_smoke(name):
    """Reduced config: one train step (loss+grads finite) on CPU."""
    cfg = reduced(get_arch(name))
    p = init_params(cfg, KEY, jnp.float32)
    batch = _batch(cfg)
    loss, grads = jax.value_and_grad(lambda pp: loss_fn(cfg, pp, batch))(p)
    assert np.isfinite(float(loss))
    for g in jax.tree.leaves(grads):
        assert np.isfinite(np.asarray(g)).all()
    hidden = forward(cfg, p, batch)
    assert hidden.shape == (B, S, cfg.d_model)


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_arch_decode_smoke(name):
    cfg = reduced(get_arch(name))
    p = init_params(cfg, KEY, jnp.float32)
    batch = _batch(cfg)
    cache, logits = prefill(cfg, p, batch, cache_len=S + 4,
                            dtype=jnp.float32)
    assert logits.shape == (B, 1, cfg.vocab)
    cache, logits = decode_step(cfg, p, cache, batch["tokens"][:, :1],
                                jnp.int32(S))
    assert logits.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("shape", [(1, 128, 4, 2, 32), (2, 256, 8, 8, 64),
                                   (1, 192, 6, 2, 48)])
def test_flash_vs_reference(causal, shape):
    b, s, h, hk, d = shape
    q = jax.random.normal(KEY, (b, s, h, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, hk, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, hk, d))
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64)
    ref = reference_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_flash_grad_matches_reference():
    b, s, h, hk, d = 1, 128, 4, 2, 32
    q = jax.random.normal(KEY, (b, s, h, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, hk, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, hk, d))
    g1 = jax.grad(lambda q_: flash_attention(
        q_, k, v, causal=True, block_q=64, block_k=64).sum())(q)
    g2 = jax.grad(lambda q_: reference_attention(
        q_, k, v, causal=True).sum())(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               rtol=5e-4, atol=5e-4)


def test_chunked_xent_matches_direct():
    d, v = 16, 64
    hidden = jax.random.normal(KEY, (2, 64, d))
    w = jax.random.normal(jax.random.PRNGKey(1), (d, v))
    labels = jax.random.randint(jax.random.PRNGKey(2), (2, 64), 0, v)
    chunked = chunked_xent(hidden, w, labels, chunk=16)
    logits = jnp.einsum("bsd,dv->bsv", hidden, w)
    direct = -jnp.mean(jnp.take_along_axis(
        jax.nn.log_softmax(logits, -1), labels[..., None], -1))
    np.testing.assert_allclose(float(chunked), float(direct), rtol=1e-5)


def test_decode_matches_forward():
    """Token-by-token decode must reproduce full-forward logits."""
    cfg = reduced(get_arch("tinyllama-1.1b"))
    p = init_params(cfg, KEY, jnp.float32)
    toks = jax.random.randint(KEY, (1, 12), 0, cfg.vocab)
    hidden = forward(cfg, p, {"tokens": toks})
    full_logits = logits_fn(cfg, p, hidden)
    # prefill on the first 6, then decode the next 6 one at a time
    cache, lg = prefill(cfg, p, {"tokens": toks[:, :6]}, cache_len=16,
                        dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(lg[0, 0]),
                               np.asarray(full_logits[0, 5]),
                               rtol=1e-3, atol=1e-3)
    for i in range(6, 12):
        cache, lg = decode_step(cfg, p, cache, toks[:, i:i + 1],
                                jnp.int32(i))
        np.testing.assert_allclose(np.asarray(lg[0, 0]),
                                   np.asarray(full_logits[0, i]),
                                   rtol=1e-3, atol=1e-3)


def test_decode_matches_forward_ssm():
    cfg = reduced(get_arch("mamba2-130m"))
    p = init_params(cfg, KEY, jnp.float32)
    toks = jax.random.randint(KEY, (1, 12), 0, cfg.vocab)
    full_logits = logits_fn(cfg, p, forward(cfg, p, {"tokens": toks}))
    cache, lg = prefill(cfg, p, {"tokens": toks[:, :6]}, cache_len=16,
                        dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(lg[0, 0]),
                               np.asarray(full_logits[0, 5]),
                               rtol=1e-3, atol=1e-3)
    for i in range(6, 12):
        cache, lg = decode_step(cfg, p, cache, toks[:, i:i + 1], jnp.int32(i))
        np.testing.assert_allclose(np.asarray(lg[0, 0]),
                                   np.asarray(full_logits[0, i]),
                                   rtol=1e-3, atol=1e-3)


def test_moe_routes_to_topk_experts():
    cfg = reduced(get_arch("arctic-480b"))
    key = jax.random.PRNGKey(3)
    p = moe_init(key, cfg, jnp.float32)
    x = jax.random.normal(key, (2, 16, cfg.d_model))
    out = moe_apply(p, cfg, x)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    # capacity overflow must not corrupt: force tiny capacity via big batch
    x2 = jax.random.normal(key, (8, 64, cfg.d_model))
    out2 = moe_apply(p, cfg, x2)
    assert np.isfinite(np.asarray(out2)).all()


def test_param_counts_match_public_sizes():
    expect = {"llama3-405b": 405e9, "kimi-k2-1t-a32b": 1000e9,
              "arctic-480b": 480e9, "mistral-nemo-12b": 12e9,
              "tinyllama-1.1b": 1.1e9, "glm4-9b": 9.4e9,
              "mamba2-130m": 130e6, "qwen2-vl-72b": 72e9}
    for name, target in expect.items():
        got = get_arch(name).param_count()
        assert abs(got - target) / target < 0.15, (name, got, target)
