"""Pallas kernels vs. pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.ops import (decode_attention,
                                                paged_decode_attention)
from repro.kernels.decode_attention.ref import (decode_attention_ref,
                                                paged_decode_attention_ref)
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.rmsnorm.ops import rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_scan_ref
from repro.kernels.tardis_lease.ops import (append_rows, lease_check,
                                            masked_lease_check,
                                            write_advance)
from repro.kernels.tardis_lease.ref import (append_rows_ref, lease_check_ref,
                                            masked_lease_check_ref,
                                            write_advance_ref)

KEY = jax.random.PRNGKey(0)
TOL = {jnp.float32: dict(rtol=2e-4, atol=2e-4),
       jnp.bfloat16: dict(rtol=3e-2, atol=3e-2)}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(8, 128), (32, 512), (5, 2048), (16, 80)])
def test_rmsnorm_kernel(shape, dtype):
    x = jax.random.normal(KEY, shape, dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (shape[-1],), dtype)
    out = rmsnorm(x, w, interpret=True)
    ref = rmsnorm_ref(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("b,s,h,hk,d", [
    (1, 128, 4, 4, 128),         # MHA, aligned head dim
    (2, 256, 8, 2, 64),          # GQA, padded head dim
    (1, 256, 4, 1, 80),          # MQA, zamba-style 80-dim heads
])
def test_flash_attention_kernel(b, s, h, hk, d, causal, dtype):
    q = jax.random.normal(KEY, (b, s, h, d), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, hk, d), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, hk, d), dtype)
    out = flash_attention(q, k, v, causal=causal, interpret=True)
    ref = flash_attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                              v.astype(jnp.float32), causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOL[dtype])


@pytest.mark.parametrize("kv_len", [1, 100, 512, 1024])
@pytest.mark.parametrize("b,h,hk,d,t", [(2, 8, 2, 64, 1024),
                                        (1, 4, 4, 128, 512)])
def test_decode_attention_kernel(b, h, hk, d, t, kv_len):
    q = jax.random.normal(KEY, (b, 1, h, d))
    kc = jax.random.normal(jax.random.PRNGKey(1), (b, t, hk, d))
    vc = jax.random.normal(jax.random.PRNGKey(2), (b, t, hk, d))
    out = decode_attention(q, kc, vc, jnp.int32(kv_len), interpret=True)
    ref = decode_attention_ref(q, kc, vc, kv_len)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (2, 128, 4, 16, 32, 32),
    (1, 96, 2, 32, 16, 16),      # padded final chunk path
    (1, 64, 8, 64, 128, 64),     # mamba2-130m-like dims
])
def test_ssd_scan_kernel(b, s, h, p, n, chunk):
    x = jax.random.normal(KEY, (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(3), (b, s, h)))
    A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(4), (h,)) * 0.5)
    B = jax.random.normal(jax.random.PRNGKey(5), (b, s, n))
    C = jax.random.normal(jax.random.PRNGKey(6), (b, s, n))
    D = jnp.ones((h,))
    y1, s1 = ssd_scan(x, dt, A, B, C, D, chunk=chunk, interpret=True)
    y2, s2 = ssd_scan_ref(x, dt, A, B, C, D, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("n", [7, 128, 1000, 4096])
@pytest.mark.parametrize("pts,lease", [(0, 10), (55, 10), (1000, 64)])
def test_tardis_lease_kernel(n, pts, lease):
    rng = np.random.default_rng(n)
    wts = jnp.asarray(rng.integers(0, 100, n), jnp.int32)
    rts = jnp.maximum(wts, jnp.asarray(rng.integers(0, 120, n), jnp.int32))
    req = jnp.where(jnp.asarray(rng.random(n) < 0.5), wts, wts - 1)
    out = lease_check(wts, rts, req, pts, lease, interpret=True)
    ref = lease_check_ref(wts, rts, req, jnp.int32(pts), jnp.int32(lease))
    for k in ("new_rts", "renew_ok", "expired", "write_ts"):
        np.testing.assert_array_equal(np.asarray(out[k]), np.asarray(ref[k]),
                                      err_msg=k)


@pytest.mark.parametrize("n", [7, 128, 1000])
@pytest.mark.parametrize("pts", [0, 55])
def test_tardis_masked_ops(n, pts):
    """The engine's two transitions (masked lease pass + write jump-ahead)
    against the protocol-oracle refs, including pts advance."""
    rng = np.random.default_rng(n + pts)
    wts = jnp.asarray(rng.integers(0, 100, n), jnp.int32)
    rts = jnp.maximum(wts, jnp.asarray(rng.integers(0, 120, n), jnp.int32))
    req = jnp.where(jnp.asarray(rng.random(n) < 0.5), wts, wts - 1)
    mask = jnp.asarray(rng.integers(0, 2, n), jnp.int32)
    out = masked_lease_check(wts, rts, req, mask, pts, 10, interpret=True)
    ref = masked_lease_check_ref(wts, rts, req, mask, jnp.int32(pts),
                                 jnp.int32(10))
    for k in ("new_rts", "renew_ok", "expired", "write_ts", "new_pts"):
        np.testing.assert_array_equal(np.asarray(out[k]), np.asarray(ref[k]),
                                      err_msg=k)
    w1, r1, t1 = write_advance(wts, rts, mask, pts, interpret=True)
    w2, r2, t2 = write_advance_ref(wts, rts, mask, jnp.int32(pts))
    np.testing.assert_array_equal(np.asarray(w1), np.asarray(w2))
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))
    assert int(t1) == int(t2)


@pytest.mark.parametrize("b,h,hk,d,chunk,nb,p", [
    (3, 6, 2, 16, 4, 8, 3),
    (2, 4, 4, 32, 8, 16, 4),     # MHA-style, bigger pages
    (1, 8, 1, 16, 16, 4, 2),     # MQA
])
@pytest.mark.parametrize("layers,layer", [(2, 0), (2, 1), (1, 0)])
def test_paged_decode_attention_kernel(b, h, hk, d, chunk, nb, p, layers,
                                       layer):
    """Paged flash-decode (page tables drive the K/V DMA) vs the
    gather-then-reference oracle, across ragged per-request lengths."""
    rng = np.random.default_rng(b * 100 + h)
    te = 2 * layers * hk * d
    token_row = -(-te // 128) * 128
    pool = jnp.asarray(rng.standard_normal((nb * chunk, token_row)),
                       jnp.float32)
    page_rows = jnp.asarray(rng.integers(0, nb, (b, p)), jnp.int32)
    lengths = jnp.asarray(rng.integers(0, p * chunk, b), jnp.int32)
    q = jnp.asarray(rng.standard_normal((b, 1, h, d)), jnp.float32)
    ck = jnp.asarray(rng.standard_normal((b, 1, hk, d)), jnp.float32)
    cv = jnp.asarray(rng.standard_normal((b, 1, hk, d)), jnp.float32)
    k_off, v_off = layer * hk * d, (layers + layer) * hk * d
    out = paged_decode_attention(q, ck, cv, pool, page_rows, lengths,
                                 chunk=chunk, k_off=k_off, v_off=v_off,
                                 hkv=hk, interpret=True)
    ref = paged_decode_attention_ref(q, ck, cv, pool, page_rows, lengths,
                                     chunk=chunk, k_off=k_off, v_off=v_off,
                                     hkv=hk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("n,w,rows_w", [(8, 256, 256), (16, 512, 300)])
def test_append_rows_scatter_kernel(n, w, rows_w):
    """The token-append scatter: written rows land at their ids, every
    other row keeps its bits (in/out aliasing), last write wins."""
    rng = np.random.default_rng(n)
    pool = jnp.asarray(rng.standard_normal((n, w)), jnp.float32)
    idx = jnp.asarray([2, 0, n - 1, 2], jnp.int32)       # duplicate id
    rows = jnp.asarray(rng.standard_normal((4, rows_w)), jnp.float32)
    ref = np.asarray(append_rows_ref(pool, idx, rows))
    out = append_rows(pool, idx, rows, interpret=True)   # donates pool
    np.testing.assert_array_equal(np.asarray(out), ref)


def test_decode_attention_routing():
    """models.attention.decode_attention routes eligible GQA shapes through
    the Pallas flash-decode kernel (interpret fallback off-TPU) and keeps
    the dense einsum as the reference for everything else."""
    from repro.models import attention as A
    rng = np.random.default_rng(0)
    b, h, hk, d, t = 1, 4, 2, 64, 2048
    q = jnp.asarray(rng.standard_normal((b, 1, h, d)), jnp.float32)
    kc = jnp.asarray(rng.standard_normal((b, t, hk, d)), jnp.float32)
    vc = jnp.asarray(rng.standard_normal((b, t, hk, d)), jnp.float32)
    on_tpu = jax.default_backend() == "tpu"
    # the auto-route fires only where the kernel compiles (TPU)
    assert A._kernel_eligible(q, kc, jnp.int32(100),
                              A.DECODE_KERNEL_MIN_T) == on_tpu
    # small caches stay on the einsum; vector kv_len is the paged path's
    assert not A._kernel_eligible(q, kc[:, :512], jnp.int32(9), 2048)
    assert not A._kernel_eligible(q, kc, jnp.asarray([100]), 2048)
    # forcing the route off-TPU takes the interpret fallback
    routed = A.decode_attention(q, kc, vc, jnp.int32(100), use_kernel=True)
    ref = A.decode_attention(q, kc, vc, jnp.int32(100), use_kernel=False)
    np.testing.assert_allclose(np.asarray(routed), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_lease_kernel_matches_simulator_rules():
    """The kernel's rules ARE Table III: cross-check against protocol fns."""
    from repro.core import protocol as P
    wts = jnp.asarray([5, 5, 9], jnp.int32)
    rts = jnp.asarray([8, 20, 9], jnp.int32)
    req = jnp.asarray([5, 4, 9], jnp.int32)
    out = lease_check(wts, rts, req, 10, 10, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(out["new_rts"]),
        np.asarray(P.lease_extend(wts, rts, jnp.int32(10), jnp.int32(10))))
    assert out["write_ts"] == 21     # jump past the longest lease
