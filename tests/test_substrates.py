"""Substrate tests: store, checkpoint, data, optimizer, compression, sharding."""
import os
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import ckpt
from repro.core.store import BlockTable, Replica, TardisStore
from repro.data.pipeline import synthetic_batch
from repro.dist.collectives import (compress_grads, decompress_grads,
                                    init_residual, microbatch_grads)
from repro.optim import adamw


class TestTardisStore:
    def test_leases_and_dataless_renewals(self):
        store = TardisStore(lease=4)
        pub = Replica(store, "writer")
        pub.write("w", "v1", nbytes=100)
        r = Replica(store, "reader", selfinc_period=1)
        assert r.read("w") == "v1"
        # unchanged data: renewals must be data-less
        for _ in range(20):
            assert r.read("w") == "v1"
        assert store.stats.renew_data_less == store.stats.renews > 0
        assert store.stats.bytes_transferred == 100    # only first fetch

    def test_write_jumps_ahead_no_invalidation(self):
        store = TardisStore(lease=4)
        pub = Replica(store, "writer")
        r = Replica(store, "reader", selfinc_period=1)
        pub.write("w", "v1")
        assert r.read("w") == "v1"
        pub.write("w", "v2")
        # reader still inside its lease: continues on v1 (legal SC order)
        assert r.read("w") in ("v1", "v2")
        # after the lease expires it must observe v2 (bounded staleness)
        for _ in range(10):
            val = r.read("w")
        assert val == "v2"
        assert store.stats.dir_invalidations >= 1      # directory would have

    def test_block_table_rules(self):
        bt = BlockTable(16, lease=8)
        idx = np.array([0, 3, 5])
        expired, pts = bt.read_blocks(idx, 0)
        assert (bt.rts[idx] >= 8).all()
        ts = bt.write_blocks(np.array([3]), pts)
        assert ts == int(bt.rts[3]) == int(bt.wts[3])
        assert ts > 8                                   # jumped past lease

    @given(st.lists(st.integers(0, 15), min_size=1, max_size=8), st.integers(0, 50))
    @settings(max_examples=50, deadline=None)
    def test_block_table_write_exceeds_all_leases(self, idx, pts):
        bt = BlockTable(16, lease=5)
        idx = np.unique(np.array(idx))
        bt.read_blocks(idx, pts)
        ts = bt.write_blocks(idx, pts)
        assert ts > pts + 4                             # past every lease


class TestCheckpoint:
    def test_roundtrip_and_keep(self):
        tree = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 4))}}
        with tempfile.TemporaryDirectory() as d:
            for s in (5, 10, 15, 20):
                ckpt.save(d, s, tree, wts=s, keep=2)
            assert ckpt.latest_step(d) == 20
            kept = sorted(x for x in os.listdir(d) if x.startswith("step_"))
            assert kept == ["step_15", "step_20"]
            out, man = ckpt.restore(d, tree)
            assert man["step"] == 20 and man["wts"] == 20
            np.testing.assert_array_equal(np.asarray(out["a"]),
                                          np.asarray(tree["a"]))

    def test_restore_rejects_shape_mismatch(self):
        tree = {"a": jnp.ones((4,))}
        with tempfile.TemporaryDirectory() as d:
            ckpt.save(d, 1, tree)
            with pytest.raises(AssertionError):
                ckpt.restore(d, {"a": jnp.ones((5,))})

    def test_sharded_roundtrip_single_device(self):
        # save_sharded on unsharded leaves degrades to one piece per leaf
        tree = {"w": jnp.arange(24.0).reshape(4, 6),
                "b": {"s": jnp.float32(7.0)}}
        with tempfile.TemporaryDirectory() as d:
            ckpt.save_sharded(d, 3, tree, wts=9)
            out, man = ckpt.restore_sharded(d, tree)
            assert man["sharded"] and man["wts"] == 9
            for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_restore_sharded_rejects_dense_checkpoint(self):
        tree = {"a": jnp.ones((4,))}
        with tempfile.TemporaryDirectory() as d:
            ckpt.save(d, 1, tree)
            with pytest.raises(ValueError, match="save_sharded"):
                ckpt.restore_sharded(d, tree)

    def test_sharded_save_restore_across_mesh_shapes(self):
        """On a forced 2-device host mesh: save writes one piece per
        addressable shard (no gather), restore rebuilds through
        make_array_from_callback under the SAME sharding, a TRANSPOSED
        sharding (elastic mesh change), and no sharding at all -- all
        bit-identical.  Needs a subprocess: jax here is single-device."""
        src = os.path.join(os.path.dirname(__file__), "..", "src")
        env = dict(os.environ)
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                            + " --xla_force_host_platform_device_count=2")
        env["JAX_PLATFORMS"] = "cpu"
        code = """
import json, os, tempfile
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.checkpoint import ckpt

devs = np.array(jax.devices())
assert devs.size == 2
mesh = Mesh(devs, ("data",))
row = NamedSharding(mesh, P("data", None))
col = NamedSharding(mesh, P(None, "data"))
rep = NamedSharding(mesh, P())
tree = {"w": jax.device_put(jnp.arange(24.0).reshape(4, 6), row),
        "b": jax.device_put(jnp.arange(3.0), rep)}
with tempfile.TemporaryDirectory() as d:
    ckpt.save_sharded(d, 1, tree, wts=5)
    man = json.load(open(os.path.join(d, "step_1", "manifest.json")))
    by_idx = {e["idx"]: e for e in man["leaves"]}
    pieces = [len(e["pieces"]) for e in man["leaves"]]
    assert sorted(pieces) == [1, 2], pieces      # w split, b deduped
    # same mesh, same sharding
    out, _ = ckpt.restore_sharded(d, tree, shardings={"w": row, "b": rep})
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(tree["w"]))
    assert out["w"].sharding.is_equivalent_to(row, 2)
    # elastic: restore the row-saved pieces under a COLUMN sharding
    out, _ = ckpt.restore_sharded(d, tree, shardings={"w": col, "b": rep})
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(tree["w"]))
    assert out["w"].sharding.is_equivalent_to(col, 2)
    # host-side full assembly
    out, _ = ckpt.restore_sharded(d, tree)
    np.testing.assert_array_equal(np.asarray(out["b"]),
                                  np.asarray(tree["b"]))
print("SHARDED-CKPT-OK")
"""
        out = subprocess.run([sys.executable, "-c", code], env=env,
                             capture_output=True, text=True, timeout=300)
        assert out.returncode == 0, out.stderr
        assert "SHARDED-CKPT-OK" in out.stdout


class TestData:
    def test_deterministic(self):
        b1 = synthetic_batch(7, 42, 4, 64, 1000)
        b2 = synthetic_batch(7, 42, 4, 64, 1000)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        assert (b1["tokens"] < 1000).all() and (b1["tokens"] >= 0).all()
        b3 = synthetic_batch(7, 43, 4, 64, 1000)
        assert not np.array_equal(b1["tokens"], b3["tokens"])

    def test_labels_shifted(self):
        b = synthetic_batch(0, 0, 2, 32, 100)
        assert b["tokens"].shape == b["labels"].shape == (2, 32)


class TestOptim:
    def test_adamw_converges_quadratic(self):
        params = {"w": jnp.array([5.0, -3.0])}
        state = adamw.init(params)
        for _ in range(200):
            grads = {"w": 2 * params["w"]}
            params, state, m = adamw.update(params, grads, state, lr=0.1,
                                            weight_decay=0.0)
        assert float(jnp.abs(params["w"]).max()) < 0.1

    def test_clip(self):
        g = {"a": jnp.full((4,), 100.0)}
        clipped, norm = adamw.clip_by_global_norm(g, 1.0)
        assert abs(float(adamw.global_norm(clipped)) - 1.0) < 1e-5


class TestCompression:
    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=30, deadline=None)
    def test_error_feedback_bounded(self, seed):
        key = jax.random.PRNGKey(seed)
        g = {"w": jax.random.normal(key, (64,))}
        res = init_residual(g)
        # feed the same gradient repeatedly: error feedback keeps the
        # cumulative dequantized sum close to the true sum
        total_true = jnp.zeros((64,))
        total_deq = jnp.zeros((64,))
        for _ in range(10):
            qs, res = compress_grads(g, res)
            total_deq = total_deq + decompress_grads(qs)["w"]
            total_true = total_true + g["w"]
        scale = float(jnp.max(jnp.abs(g["w"])))
        err = float(jnp.max(jnp.abs(total_deq - total_true)))
        assert err <= scale / 127 + 1e-5      # residual never accumulates

    def test_microbatch_matches_full_batch(self):
        def loss_fn(p, b):
            return jnp.mean((b["x"] @ p["w"] - b["y"]) ** 2)
        p = {"w": jnp.ones((4,))}
        batch = {"x": jax.random.normal(jax.random.PRNGKey(0), (8, 4)),
                 "y": jnp.ones((8,))}
        l1, g1 = jax.value_and_grad(loss_fn)(p, batch)
        l2, g2 = microbatch_grads(loss_fn, p, batch, 4)
        np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(g1["w"]), np.asarray(g2["w"]),
                                   rtol=1e-5)


class TestShardingRules:
    class FakeMesh:
        def __init__(self, shape):
            self.shape = shape

    def test_divisibility_guard(self):
        from repro.dist.sharding import param_spec
        mesh = self.FakeMesh({"data": 16, "model": 16})
        # glm4 kv=2 heads * 128 = 256 divides 16 -> kept
        spec = param_spec(mesh, ("layers", "attn", "wk"), (40, 4096, 256))
        assert spec[2] == "model"
        # a 24-dim head vector must NOT shard over 16
        spec = param_spec(mesh, ("layers", "ssm", "A_log"), (24, 24))
        assert all(s is None for s in spec)

    def test_expert_weights_get_ep(self):
        from repro.dist.sharding import param_spec
        mesh = self.FakeMesh({"pod": 2, "data": 16, "model": 16})
        spec = param_spec(mesh, ("layers", "moe", "w_gate"),
                          (60, 384, 7168, 2048))
        assert spec[1] == "model"                      # experts on model (EP)
        assert spec[2] == ("pod", "data")              # FSDP on d_model

    def test_uneven_dp_drops_pod(self):
        from repro.dist.sharding import param_spec
        mesh = self.FakeMesh({"pod": 2, "data": 16, "model": 16})
        # dim 16 divides data(16) but not pod*data(32): pod must drop
        spec = param_spec(mesh, ("layers", "attn", "wq"), (2, 16, 512))
        assert spec[1] == "data"
