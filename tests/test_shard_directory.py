"""ShardedLeaseDirectory: the cross-host wave against the single-host truth.

The directory's contract is that sharding the lease table changes the
*wire*, never the *protocol*: per-shard engines evolve bit-for-bit like a
single engine driven with the same per-owner-partition batches, a wave
costs at most one request + one response per contacted owner shard, pages
migrate carrying exactly the lease the same wave extended, and the zero
columns (multicasts, invalidation messages) stay zero.  The migration
sanitizer turns double publishes, tampered carries, and use-after-migrate
into hard failures; the end-to-end check runs the SAME requests through a
2-host cluster and a single-host cluster and demands identical tokens.
The transport leg is pinned to the device path by running the
``dist.collectives`` lax wrappers under ``shard_map`` on forced host
devices and comparing against the numpy mirrors the directory tests ride.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.sanitize import SanitizeError
from repro.core import (FetchedPage, LeaseEngine, NumpyTransport,
                        ShardedLeaseDirectory)
from repro.core.shard_directory import DirStats
from repro.dist import collectives

N_BLOCKS = 16
N_SHARDS = 4
LEASE = 6
POOLS = {"k": (1, 2), "v": (1, 2)}


def _mk(n_hosts=2, pools=False, **kw):
    return ShardedLeaseDirectory(
        N_BLOCKS, N_SHARDS, n_hosts=n_hosts, lease=LEASE,
        kv_pools=POOLS if pools else None, kv_dtype=np.float32,
        block_bytes=16 if pools else 0, sanitize=True, **kw)


def _page(val):
    return {n: np.full((1,) + s, val, np.float32) for n, s in POOLS.items()}


# ---------------------------------------------------------------------------
# Protocol equivalence: sharding never changes the tables
# ---------------------------------------------------------------------------

def test_directory_tables_match_single_engine_oracle():
    """Random wave streams: the reassembled global (wts, rts) tables and
    every returned pts are bit-identical to ONE LeaseEngine driven with
    the same batches partitioned by owner shard (the partition is the
    only thing sharding is allowed to change)."""
    rng = np.random.default_rng(7)
    d = _mk(n_hosts=2)
    oracle = LeaseEngine(N_BLOCKS, lease=LEASE, backend="numpy")
    pts = 0
    for step in range(60):
        host = step % 2
        if rng.random() < 0.35:
            bids = sorted(rng.choice(N_BLOCKS, rng.integers(1, 5),
                                     replace=False).tolist())
            res = d.wave(host, pts, write_bids=bids,
                         tag_writes_with_ts=True)
            # oracle: same per-owner-shard batches at the wave's shared pts
            exp_ts = {}
            for s in sorted({d.owner(b) for b in bids}):
                part = [b for b in bids if d.owner(b) == s]
                ts = oracle.write(part, pts)
                exp_ts.update({b: ts for b in part})
            assert res.write_ts == exp_ts
            pts = res.new_pts
            assert pts == max(exp_ts.values())
        else:
            groups = [sorted(rng.choice(N_BLOCKS, rng.integers(1, 6),
                                        replace=False).tolist())
                      for _ in range(rng.integers(1, 4))]
            req = {b: int(oracle.wts[b]) - int(rng.integers(0, 2))
                   for g in groups for b in g}
            res = d.wave(host, pts, read_groups=groups, req_wts=req)
            for g, bids in enumerate(groups):
                for s in sorted({d.owner(b) for b in bids}):
                    part = [b for b in bids if d.owner(b) == s]
                    r = oracle.read(part, pts,
                                    req_wts=[req[b] for b in part])
                    for j, b in enumerate(part):
                        assert res.leases[b] == (int(r.wts[j]),
                                                 int(r.rts[j]))
                    assert res.group_pts[g] >= r.new_pts
            pts = res.new_pts
        np.testing.assert_array_equal(d.wts, oracle.wts)
        np.testing.assert_array_equal(d.rts, oracle.rts)
    assert d.stats.multicasts == 0
    assert d.stats.invalidation_msgs == 0


def test_wave_message_invariant_one_pair_per_owner_shard():
    d = _mk(n_hosts=N_SHARDS)      # shard s lives on host s
    # host 0 touches blocks on every shard: 3 remote pairs, shard 0 free
    res = d.wave(0, 0, read_groups=[[0, 1, 2, 3]],
                 write_bids=[4, 5, 6, 7], tag_writes_with_ts=True)
    assert res.shards_contacted == 3
    assert res.msgs == 6                       # one req + one rep each
    assert d.stats.req_msgs == 3 and d.stats.rep_msgs == 3
    # purely local wave: zero cross-host traffic
    res = d.wave(0, res.new_pts, read_groups=[[0, 4, 8, 12]])
    assert res.msgs == 0 and res.shards_contacted == 0
    assert d.max_msgs_per_wave() == 6
    assert d.stats.flits > 0 and d.stats.wire_bytes > d.stats.flits


def test_transport_routes_every_remote_wave():
    d = _mk(n_hosts=2)
    assert isinstance(d.transport, NumpyTransport)
    d.wave(0, 0, read_groups=[[1]])            # shard 1 -> host 1: remote
    d.wave(0, 1, read_groups=[[0]])            # shard 0: local, no route
    assert d.transport.routes == 1


# ---------------------------------------------------------------------------
# Timestamp-ordered page migration + write-behind publishing
# ---------------------------------------------------------------------------

def test_page_migration_round_trip():
    d = _mk(pools=True)
    res = d.wave(0, 0, write_bids=[1], write_tags=[77])
    ts = res.write_ts[1]
    assert int(d.tags[1]) == 77 and not d.home_ok(1)
    d.defer_publish(0, 1, _page(ts))
    assert not d.home_ok(1)                    # write-behind: not yet home
    d.flush_deferred(0)
    assert d.home_ok(1) and d.stats.publishes == 1
    res = d.wave(1, ts, fetch_bids=[1])        # host 1 borrows the page
    page = res.fetched[1]
    assert (page.wts, page.rts) == res.leases[1]
    assert page.tag == 77 and page.wver == int(d.wver[1])
    for name, arr in page.blocks.items():
        np.testing.assert_array_equal(np.asarray(arr), _page(ts)[name])
    assert d.stats.migrations == 1


def test_stale_publish_dropped_on_retag():
    d = _mk(pools=True)
    d.wave(0, 0, write_bids=[2], write_tags=[5])
    d.defer_publish(0, 2, _page(1.0))
    d.wave(1, 9, write_bids=[2], write_tags=[6])   # re-tag underneath
    d.flush_deferred(0)
    assert d.stats.publishes_dropped == 1
    assert d.stats.publishes == 0 and not d.home_ok(2)


def test_publish_barrier_invalidates_home_and_drops_queued():
    d = _mk(pools=True)
    d.wave(0, 0, write_bids=[1], write_tags=[3])
    d.defer_publish(0, 1, _page(1.0))
    d.flush_deferred(0)
    assert d.home_ok(1)
    d.wave(0, 5, write_bids=[5], write_tags=[4])
    d.defer_publish(0, 5, _page(2.0))
    ver = d.wver.copy()
    d.publish_barrier()                        # weight publish swept hosts
    assert not d.home_ok(1)                    # old-weight content is dead
    np.testing.assert_array_equal(d.wver, ver + 1)
    d.flush_deferred(0)
    assert d.stats.publishes_dropped == 1      # queued old-version payload


def test_pending_publishes_ride_the_next_wave():
    d = _mk(pools=True)
    d.wave(0, 0, write_bids=[1], write_tags=[9])
    d.defer_publish(0, 1, _page(3.0))
    flits_before = d.stats.flits
    res = d.wave(0, 3, read_groups=[[1]])      # organic wave to shard 1
    assert d.home_ok(1)                        # pend rode the request
    assert d.stats.publishes == 1
    assert res.msgs == 2
    assert d.stats.flits > flits_before + 2    # payload flits were charged


def test_subscribe_notifies_on_publish_install():
    d = _mk(pools=True)
    d.wave(0, 0, write_bids=[1, 2], write_tags=[9, 8])
    d.defer_publish(0, 1, _page(3.0))
    d.defer_publish(0, 2, _page(4.0))
    landed = d.subscribe(1, [1, 2], tags=[9, 8])
    assert landed == [] and d.stats.watches == 2
    assert d.pop_notifications(1) == []        # nothing landed yet
    d.flush_deferred(0)                        # installs fire the notify
    assert sorted(d.pop_notifications(1)) == [1, 2]
    assert d.stats.notifies == 2
    assert d.pop_notifications(1) == []        # drained exactly once
    # watch + notify exchanges stay inside the per-shard message budget
    for w in d.wave_log:
        if w["kind"] in ("watch", "notify"):
            assert w["msgs"] <= 2 * max(1, len(w["shards"]))


def test_subscribe_returns_already_home_gids_without_watching():
    d = _mk(pools=True)
    d.wave(0, 0, write_bids=[3], write_tags=[7])
    d.defer_publish(0, 3, _page(1.0))
    d.flush_deferred(0)
    watches = d.stats.watches
    msgs = d.stats.msgs
    assert d.subscribe(1, [3], tags=[7]) == [3]
    assert d.stats.watches == watches          # no watch registered
    assert d.stats.msgs == msgs                # and no messages priced


def test_subscribe_tag_mismatch_drops_notify():
    d = _mk(pools=True)
    d.wave(0, 0, write_bids=[2], write_tags=[5])
    assert d.subscribe(1, [2], tags=[4]) == []  # wants DIFFERENT content
    d.defer_publish(0, 2, _page(2.0))
    d.flush_deferred(0)                        # tag-5 content lands
    assert d.pop_notifications(1) == []        # stale watch never fires
    with pytest.raises(ValueError, match="align"):
        d.subscribe(1, [2], tags=[4, 5])


def test_maybe_rebase_shifts_all_shards_uniformly():
    d = ShardedLeaseDirectory(N_BLOCKS, N_SHARDS, n_hosts=2, lease=LEASE,
                              ts_bits=8, sanitize=True)
    res = d.wave(0, 300, write_bids=list(range(N_BLOCKS)),
                 tag_writes_with_ts=True)
    assert res.new_pts >= 1 << 8               # past the 8-bit guard
    before_w = d.wts.copy()
    shift = d.maybe_rebase()
    assert shift > 0 and d.rebases == 1
    np.testing.assert_array_equal(d.wts, np.maximum(before_w - shift, 0))
    assert d.ts_shift == shift
    assert all(e.ts_shift == shift for e in d.shards)


# ---------------------------------------------------------------------------
# Migration sanitizer: the three bug classes raise
# ---------------------------------------------------------------------------

def test_sanitizer_double_publish_raises():
    d = _mk(pools=True)
    d.wave(0, 0, write_bids=[1], write_tags=[2])
    d.defer_publish(0, 1, _page(1.0))
    with pytest.raises(SanitizeError, match="double publish"):
        d.defer_publish(0, 1, _page(1.0))


def test_sanitizer_tampered_carry_raises():
    d = _mk(pools=True)
    page = FetchedPage(gid=1, wts=10, rts=20, tag=3, wver=0,
                       blocks=_page(1.0))
    with pytest.raises(SanitizeError, match="migrated under"):
        d._msan.check_carried(page, (11, 20), 3)
    with pytest.raises(SanitizeError, match="content tag"):
        d._msan.check_carried(page, (10, 20), 4)


def test_sanitizer_use_after_migrate_raises():
    d = _mk(pools=True)
    san = d._msan
    san.mark_installed(1, 7, tag=5)
    san.on_use(1, 7, 5)                        # still current: fine
    with pytest.raises(SanitizeError, match="use-after-migrate"):
        san.on_use(1, 7, 6)                    # directory moved on
    san.on_invalidate(1, 7)
    with pytest.raises(SanitizeError, match="never installed"):
        san.on_use(1, 7, 5)


# ---------------------------------------------------------------------------
# Broadcast counterfactual
# ---------------------------------------------------------------------------

def test_broadcast_baseline_prices_the_multicast_tardis_never_sends():
    d = _mk(n_hosts=4)
    for i in range(8):
        d.wave(i % 4, i * 10, write_bids=[i], tag_writes_with_ts=True)
    base = d.broadcast_baseline()
    assert base["writes"] == 8
    assert base["bcast_inv_msgs"] == 8 * 3 * 2     # INV + ACK per sharer
    assert base["tardis_inv_msgs"] == 0
    assert base["bcast_inv_bytes"] > 0
    rep = d.report()
    assert rep["xhost_multicasts"] == 0
    assert rep["xhost_invalidation_msgs"] == 0


# ---------------------------------------------------------------------------
# End-to-end: 2 hosts serve the same tokens as 1
# ---------------------------------------------------------------------------

def _requests(cfg, n, shared=12, tail=6, max_new=2):
    from repro.runtime import Request
    rng = np.random.default_rng(0)
    system = rng.integers(1, cfg.vocab, shared).astype(np.int32)
    return [Request(i, np.concatenate(
        [system, rng.integers(1, cfg.vocab, tail).astype(np.int32)]),
        max_new=max_new) for i in range(n)]


def test_two_host_cluster_matches_single_host_tokens():
    from repro.configs import get_arch, reduced
    from repro.models import init_params
    from repro.runtime import MultiHostServingCluster, ServingCluster
    cfg = reduced(get_arch("tinyllama-1.1b"), n_layers=2, d_model=64,
                  vocab=128)
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    kw = dict(n_replicas=1, prefix_block_tokens=4, kv_lease=16,
              cache_len=96, selfinc_period=4, n_decode_pages=64,
              max_pages=16, max_batch=2)
    mh = MultiHostServingCluster(cfg, lambda: params, n_hosts=2,
                                 sanitize=True, **kw)
    reqs = _requests(cfg, 4)
    # host 0 prefills + publishes the shared prefix, then host 1 serves
    # the same system prompt suffix-only (the cross-host reuse pitch)
    mh.run(reqs[:2], affinity=[0, 0])
    _, rep = mh.run(reqs[2:], affinity=[1, 1])
    assert rep["host1_prefix_prefill_tokens_skipped"] > 0
    assert rep["host1_xhost_pages_fetched"] > 0
    assert rep["xhost_multicasts"] == 0
    assert rep["xhost_invalidation_msgs"] == 0
    assert rep["xhost_max_msgs_per_wave"] <= \
        2 * max(1, rep["xhost_max_shards_per_wave"])
    single = ServingCluster(cfg, lambda: params, **kw)
    sreqs = _requests(cfg, 4)
    single.run(sreqs[:2])
    single.run(sreqs[2:])
    for a, b in zip(reqs, sreqs):
        assert a.done and b.done
        np.testing.assert_array_equal(np.asarray(a.output),
                                      np.asarray(b.output),
                                      err_msg=f"request {a.rid}")


# ---------------------------------------------------------------------------
# Device collectives vs the numpy mirrors (forced host devices)
# ---------------------------------------------------------------------------

_COLLECTIVE_CODE = """
import numpy as np, jax, jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P
from repro.dist import collectives as C

devs = np.array(jax.devices())
n = devs.size
assert n == 4, n
mesh = Mesh(devs, ("data",))

def run(fn, x):
    f = shard_map(lambda v: fn(v, "data"), mesh=mesh,
                  in_specs=P("data"), out_specs=P("data"))
    return np.asarray(jax.jit(f)(jnp.asarray(x)))

# one row per device
x = np.arange(n * 4, dtype=np.float32).reshape(n, 4) + 1.0
xs = [x[i:i + 1] for i in range(n)]
np.testing.assert_allclose(run(C.psum, x), np.concatenate(C.np_psum(xs)))
np.testing.assert_allclose(run(C.all_gather, x),
                           np.concatenate(C.np_all_gather(xs)))

# n rows per device (scatter/all-to-all need dim0 divisible by n)
y = np.arange(n * n * 2, dtype=np.float32).reshape(n * n, 2)
ys = [y[i * n:(i + 1) * n] for i in range(n)]
np.testing.assert_allclose(run(C.reduce_scatter, y),
                           np.concatenate(C.np_reduce_scatter(ys)))
np.testing.assert_allclose(run(C.all_to_all, y),
                           np.concatenate(C.np_all_to_all(ys)))
print("COLLECTIVES-OK")
"""


def test_device_collectives_match_numpy_mirrors():
    """The lax wrappers under shard_map on 4 forced host devices agree
    with the numpy mirrors the NumpyTransport rides (needs a subprocess:
    jax is already initialized single-device in this one)."""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4")
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", _COLLECTIVE_CODE], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr
    assert "COLLECTIVES-OK" in out.stdout
