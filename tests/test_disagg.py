"""Disaggregated prefill/decode serving over the sharded directory.

The tentpole claims, each asserted here:
  * a decode pod performs ZERO cold-prefix prefills -- the router forwards
    cold work to a prefill pod and hands the stream back after the
    publish-then-notify wake;
  * the handed-back stream serves suffix-only from migrated pages,
    token-identical to a single-host cluster;
  * the split keeps the directory's guarantees: zero multicasts, zero
    invalidation messages, <=1 message pair per contacted shard per wave.

Plus the PR's reporting/affinity bugfixes: config scalars reported once
(not summed across hosts), high-water marks maxed, ``publish_weights``
returning the fleet max with a version-consensus check, and ``affinity``
validated up front.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.models import init_params
from repro.runtime import MultiHostServingCluster, Request, ServingCluster

KW = dict(n_replicas=1, prefix_block_tokens=4, kv_lease=16,
          cache_len=96, selfinc_period=4, n_decode_pages=64,
          max_pages=16, max_batch=2)


@pytest.fixture(scope="module")
def cfg():
    return reduced(get_arch("tinyllama-1.1b"), n_layers=2, d_model=64,
                   vocab=128)


@pytest.fixture(scope="module")
def params(cfg):
    return init_params(cfg, jax.random.PRNGKey(0), jnp.float32)


def _requests(cfg, n, shared=12, tail=6, max_new=2, seed=0):
    rng = np.random.default_rng(seed)
    system = rng.integers(1, cfg.vocab, shared).astype(np.int32)
    return [Request(i, np.concatenate(
        [system, rng.integers(1, cfg.vocab, tail).astype(np.int32)]),
        max_new=max_new) for i in range(n)]


def _single_host_reference(cfg, params, reqs_fn, **kw):
    single = ServingCluster(cfg, lambda: params, **dict(KW, **kw))
    sreqs = reqs_fn()
    single.run(sreqs)
    return sreqs


def _assert_same_tokens(reqs, sreqs):
    for a, b in zip(reqs, sreqs):
        assert a.done and b.done
        np.testing.assert_array_equal(np.asarray(a.output),
                                      np.asarray(b.output),
                                      err_msg=f"request {a.rid}")


# ---------------------------------------------------------------------------
# Role plumbing validation
# ---------------------------------------------------------------------------

def test_roles_validation(cfg, params):
    with pytest.raises(ValueError, match="unknown roles"):
        MultiHostServingCluster(cfg, lambda: params, n_hosts=2,
                                roles=["prefill", "deocde"], **KW)
    with pytest.raises(ValueError, match="entries for"):
        MultiHostServingCluster(cfg, lambda: params, n_hosts=2,
                                roles=["mixed"], **KW)
    with pytest.raises(ValueError, match="forward cold prefixes"):
        MultiHostServingCluster(cfg, lambda: params, n_hosts=2,
                                roles=["decode", "decode"], **KW)
    with pytest.raises(ValueError, match="hand streams back"):
        MultiHostServingCluster(cfg, lambda: params, n_hosts=2,
                                roles=["prefill", "prefill"], **KW)


def test_affinity_validation(cfg, params):
    mh = MultiHostServingCluster(cfg, lambda: params, n_hosts=2, **KW)
    reqs = _requests(cfg, 2)
    with pytest.raises(ValueError, match="out of range"):
        mh.run(reqs, affinity=[0, 2])
    with pytest.raises(ValueError, match="negative ids do not wrap"):
        mh.run(reqs, affinity=[0, -1])
    with pytest.raises(ValueError, match="entries for"):
        mh.run(reqs, affinity=[0])
    assert not any(r.done for r in reqs)   # validation precedes serving

    dis = MultiHostServingCluster(cfg, lambda: params, n_hosts=2,
                                  roles=["prefill", "decode"], **KW)
    with pytest.raises(ValueError, match="prefill-only"):
        dis.run(_requests(cfg, 2), affinity=[0, 1])


# ---------------------------------------------------------------------------
# Reporting bugfixes: scalars once, maxes maxed, publish consensus
# ---------------------------------------------------------------------------

def test_report_config_scalars_not_summed(cfg, params):
    mh = MultiHostServingCluster(cfg, lambda: params, n_hosts=2,
                                 sanitize=True, **KW)
    reqs = _requests(cfg, 4)
    _, rep = mh.run(reqs)
    eng = mh.hosts[0].prefix_engine
    # the old aggregation summed these across hosts (2x the real value)
    assert rep["ts_bits"] == eng.ts_bits
    assert rep["kv_lease"] == eng.lease
    assert rep["n_prefix_blocks"] == mh.hosts[0].n_prefix_blocks
    assert rep["pool_page_peak"] == max(
        h.prefix_stats["pool_page_peak"] for h in mh.hosts)
    assert rep["roles"] == "mixed,mixed"
    assert rep["host0_role"] == "mixed"


def test_publish_weights_returns_fleet_max_and_agrees(cfg, params):
    mh = MultiHostServingCluster(cfg, lambda: params, n_hosts=2, **KW)
    pts = mh.publish_weights(params)
    assert pts == max(h.publisher.pts for h in mh.hosts)
    vers = {h.store.versions()["params"] for h in mh.hosts}
    assert len(vers) == 1
    # desynchronize one host's store: the consensus check must trip
    mh.hosts[1].publisher.write("params", params,
                                nbytes=mh.hosts[1].param_bytes)
    with pytest.raises(RuntimeError, match="disagree"):
        mh.publish_weights(params)


# ---------------------------------------------------------------------------
# The tentpole: 1 prefill pod + 1 decode pod
# ---------------------------------------------------------------------------

def test_disagg_decode_pod_never_cold_prefills(cfg, params):
    mh = MultiHostServingCluster(cfg, lambda: params, n_hosts=2,
                                 roles=["prefill", "decode"],
                                 sanitize=True, **KW)
    reqs = _requests(cfg, 4)
    _, rep = mh.run(reqs)             # default affinity: the decode pod
    # the disaggregation contract
    assert rep["host1_role_cold_prefills"] == 0
    assert rep["host0_role_prefill_jobs"] > 0
    assert rep["host0_role_pages_published"] > 0
    assert rep["host1_prefix_prefill_tokens_skipped"] > 0
    assert rep["host1_xhost_pages_fetched"] > 0
    assert rep["host1_role_suffix_admissions"] == len(reqs)
    # the router actually routed: cold forwards woke back as handoffs
    assert rep["router_cold_forwards"] > 0
    assert rep["router_handoffs"] == rep["router_cold_forwards"]
    assert rep["router_forced_admissions"] == 0
    assert rep["xhost_watches"] > 0
    assert rep["xhost_notifies"] > 0
    # the directory's guarantees survive the split
    assert rep["xhost_multicasts"] == 0
    assert rep["xhost_invalidation_msgs"] == 0
    # decode-pod steady-state lease traffic: batched data-less renewals
    assert rep["host1_decode_ticks"] > 0
    _assert_same_tokens(reqs, _single_host_reference(
        cfg, params, lambda: _requests(cfg, 4)))


def test_disagg_wave_budget_holds(cfg, params):
    mh = MultiHostServingCluster(cfg, lambda: params, n_hosts=2,
                                 roles=["prefill", "decode"],
                                 sanitize=True, **KW)
    mh.run(_requests(cfg, 4))
    # every logged exchange -- waves, flushes, watches, notifies -- stays
    # within one request + one response per contacted remote shard
    for w in mh.directory.wave_log:
        shards = w.get("remote_shards")
        if shards is None:
            shards = len(w.get("shards", ()))
        assert w["msgs"] <= 2 * max(1, shards), w


def test_disagg_warm_prefix_goes_straight_to_decode(cfg, params):
    mh = MultiHostServingCluster(cfg, lambda: params, n_hosts=2,
                                 roles=["prefill", "decode"],
                                 sanitize=True, **KW)
    mh.run(_requests(cfg, 2))
    cold = mh._route_stats["router_cold_forwards"]
    assert cold > 0
    # same prefix again: now home in the directory, no forward needed
    _, rep = mh.run(_requests(cfg, 2))
    assert rep["router_cold_forwards"] == cold
    assert rep["router_warm_direct"] >= 2
    assert rep["host1_role_cold_prefills"] == 0


def test_disagg_mixed_fleet_prefers_pure_prefill_pods(cfg, params):
    mh = MultiHostServingCluster(cfg, lambda: params, n_hosts=3,
                                 roles=["prefill", "decode", "mixed"],
                                 sanitize=True, **KW)
    assert mh._prefill_pool == [0]
    reqs = _requests(cfg, 4)
    _, rep = mh.run(reqs)
    assert rep["host1_role_cold_prefills"] == 0
    assert rep["host2_role_cold_prefills"] == 0
    assert rep["host0_role_prefill_jobs"] > 0
    _assert_same_tokens(reqs, _single_host_reference(
        cfg, params, lambda: _requests(cfg, 4)))


# ---------------------------------------------------------------------------
# Randomized mixed-affinity fleet with a forced mid-run rebase
# ---------------------------------------------------------------------------

def test_randomized_affinity_with_midrun_rebase(cfg, params):
    kw = dict(KW, ts_bits=4, max_batch=4)      # 4-bit guard: rebases fire
    mh = MultiHostServingCluster(cfg, lambda: params, n_hosts=3,
                                 sanitize=True, **kw)

    def mk():
        rng = np.random.default_rng(7)
        sys_a = rng.integers(1, cfg.vocab, 12).astype(np.int32)
        sys_b = rng.integers(1, cfg.vocab, 12).astype(np.int32)
        # long enough that per-host timestamps walk past the 4-bit guard
        return [Request(i, np.concatenate(
            [sys_a if i % 2 == 0 else sys_b,
             rng.integers(1, cfg.vocab, 6).astype(np.int32)]),
            max_new=8) for i in range(24)]

    reqs = mk()
    affinity = np.random.default_rng(11).integers(
        0, 3, len(reqs)).tolist()
    _, rep = mh.run(reqs, affinity=affinity)
    assert rep["xhost_rebases"] > 0            # the rebase really fired
    assert rep["xhost_multicasts"] == 0
    assert rep["xhost_invalidation_msgs"] == 0
    _assert_same_tokens(reqs, _single_host_reference(
        cfg, params, mk, ts_bits=4, max_batch=4))
