"""Guarded-action model checker: closure, cross-validation, sensitivity.

Three layers of evidence that the checker actually checks:

  * the bounded 2-core/1-block configuration CLOSES (the frontier is
    exhausted, not capped) with zero invariant violations, every protocol
    rule fired, and every distinct guard/update call cross-validated
    bit-for-bit against ``core.protocol`` and the LeaseEngine numpy
    mirror,
  * seeded guard mutations -- dropping the renewable wts check, dropping
    the store jump-ahead, letting a lease extension land below wts, an
    over-predicting Tardis 2.0 lease with no cap -- are each detected with
    a named invariant and a witness trace (the checker is sensitive, not
    vacuously green),
  * the runtime sanitizer trips on the same bug classes when they are
    injected into a live engine driving a litmus-shaped history.
"""
import ast
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.analysis import (Bridge, Config, Rules, SanitizeError,
                            TardisModel, explore)
from repro.core import LeaseEngine

CFG = Config(n_cores=2, n_blocks=1, lease=2, ts_bits=2)


# ---------------------------------------------------------------------------
# Closure + cross-validation (the CI lane's bounded config)
# ---------------------------------------------------------------------------

def test_two_core_one_block_closes_and_cross_validates():
    model = TardisModel(CFG)
    res = explore(model, bridge=Bridge(CFG.lease))
    assert res.closed, "state space did not close under the cap"
    assert res.ok, [str(v) for v in res.violations[:3]]
    assert res.n_states > 1000 and res.n_transitions > res.n_states
    # every guarded-action rule fired at least once (pw_opt replaces the
    # store_hit_e rule on exclusive hits, so it is exempt here and covered
    # by the no-pw-opt lane below)
    fired = set(res.rule_counts)
    for rule in ("load_hit_s", "load_hit_e", "load_llc_s", "load_wb",
                 "load_dram", "store_hit_pw", "store_llc_s", "store_flush",
                 "store_dram", "evict_s", "evict_e", "self_inc",
                 "llc_evict", "llc_evict_owned", "rebase"):
        assert rule in fired, f"rule {rule} never fired"
    # every protocol scalar and both engine transitions cross-validated
    for fn in ("load_no_cache", "store_no_cache", "load_hit_shared",
               "load_hit_exclusive", "store_hit_private", "shared_expired",
               "renewable", "writeback_rts", "lease_extend", "dram_fill_ts",
               "evict_mts", "engine.read", "engine.write", "engine.rebase"):
        assert res.bridge_counts.get(fn, 0) > 0, \
            f"{fn} never cross-validated"


def test_no_pw_opt_lane_exercises_store_hit_exclusive():
    cfg = Config(n_cores=2, n_blocks=1, lease=2, ts_bits=2, pw_opt=False)
    res = explore(TardisModel(cfg), bridge=Bridge(cfg.lease))
    assert res.ok, [str(v) for v in res.violations[:3]]
    assert res.rule_counts.get("store_hit_e", 0) > 0
    assert res.bridge_counts.get("store_hit_exclusive", 0) > 0


def test_mutant_rejects_bridge():
    class Mutant(Rules):
        @staticmethod
        def renewable(req_wts, llc_wts):
            return True
    with pytest.raises(ValueError, match="mutant"):
        explore(TardisModel(CFG, rules=Mutant()), bridge=Bridge(CFG.lease))


def test_deadlock_is_reported():
    class Frozen(TardisModel):
        def successors(self, state):
            return iter(())
    res = explore(Frozen(CFG))
    assert not res.ok
    assert any(v.kind == "deadlock" for v in res.violations)


# ---------------------------------------------------------------------------
# Sensitivity: seeded guard mutations must be detected, with witnesses
# ---------------------------------------------------------------------------

class DropRenewableCheck(Rules):
    """Renew any lease regardless of the requester's cached wts: a stale
    version gets its validity interval extended past the successor."""

    @staticmethod
    def renewable(req_wts, llc_wts):
        return True


class StoreNoJumpAhead(Rules):
    """Forget the ``rts + 1`` jump: a write lands INSIDE outstanding read
    leases instead of after them."""

    @staticmethod
    def store_no_cache(pts, wts, rts):
        ts = max(pts, rts)
        return ts, ts, ts


class LeaseBelowWts(Rules):
    """Drop the maxes in the lease extension: the manager's rts can fall
    below wts / below an already-granted private lease."""

    @staticmethod
    def lease_extend(llc_wts, llc_rts, req_pts, lease):
        return req_pts + lease


class OverPredictLease(Rules):
    """A Tardis 2.0 lease predictor with no cap: every extension grants 8x
    the configured lease past the progress frontier, breaking the
    lease-horizon invariant on its very first grant."""

    @staticmethod
    def lease_extend(llc_wts, llc_rts, req_pts, lease):
        return max(llc_rts, llc_wts, req_pts) + 8 * lease


@pytest.mark.parametrize("rules,needle", [
    (DropRenewableCheck, "stale"),
    (StoreNoJumpAhead, "jump"),
    (LeaseBelowWts, "rts"),
    (OverPredictLease, "over-predicted"),
])
def test_seeded_mutation_is_detected_with_witness(rules, needle):
    res = explore(TardisModel(CFG, rules=rules()), max_violations=4)
    assert not res.ok, f"{rules.__name__} slipped through the checker"
    assert res.violations, "no violation recorded"
    assert any(needle in v.message for v in res.violations), \
        [v.message for v in res.violations]
    # a witness: every violation carries the rule path from the initial
    # state and a state description
    v = res.violations[0]
    assert v.state_repr
    assert str(v)


# ---------------------------------------------------------------------------
# The runtime sanitizer trips on the same bug classes, live
# ---------------------------------------------------------------------------

class _RtsBelowWtsEngine(LeaseEngine):
    """LeaseBelowWts injected into the live engine: after every write the
    block's read lease is clawed back below wts."""

    def write(self, idx, pts):
        ts = super().write(idx, pts)
        self._rts[np.asarray(idx, np.int64)] = max(ts - 1, 0)
        return ts


class _BackwardsWtsEngine(LeaseEngine):
    """A write that time-travels: wts stamped below the previous value."""

    def write(self, idx, pts):
        ts = super().write(idx, pts)
        self._wts[np.asarray(idx, np.int64)] = 0
        self._rts[np.asarray(idx, np.int64)] = 0
        return ts


class _OverPredictLeaseEngine(LeaseEngine):
    """OverPredictLease injected live: each read's extension is inflated
    far past the ``lease_max`` cap after the healthy grant."""

    def read(self, idx, pts, req_wts=None):
        r = super().read(idx, pts, req_wts=req_wts)
        self._rts[np.asarray(idx, np.int64)] += 64 * self.lease
        return r


@pytest.mark.parametrize("bad_engine", [_RtsBelowWtsEngine,
                                        _BackwardsWtsEngine])
def test_sanitizer_trips_on_injected_bug_during_litmus_history(bad_engine):
    eng = bad_engine(2, lease=4, backend="numpy", sanitize=True)
    with pytest.raises(SanitizeError, match="TARDIS_SANITIZE"):
        # the SB litmus shape: two cores, stores then cross reads
        pts = [0, 0]
        pts[0] = eng.write([0], pts[0])          # c0: st X
        pts[1] = eng.write([1], pts[1])          # c1: st Y
        r = eng.read([1], pts[0], req_wts=[-1])  # c0: ld Y
        pts[0] = r.new_pts
        r = eng.read([0], pts[1], req_wts=[-1])  # c1: ld X
        pts[1] = r.new_pts


def test_sanitizer_trips_on_over_predicted_lease():
    eng = _OverPredictLeaseEngine(2, lease=4, backend="numpy",
                                  sanitize=True)
    pts = eng.write([0], 0)
    r = eng.read([0], pts, req_wts=[-1])
    with pytest.raises(SanitizeError, match="over-predicted lease"):
        eng.read([0], int(r.new_pts), req_wts=[-1])


def test_sanitizer_clean_on_healthy_engine_and_zero_cost_off():
    eng = LeaseEngine(2, lease=4, backend="numpy", sanitize=True)
    pts = eng.write([0], 0)
    pts = eng.write([1], pts)
    r = eng.read([1], pts, req_wts=[-1])
    assert eng.sanitize_checks == 3
    off = LeaseEngine(2, lease=4, backend="numpy")
    assert off._san is None and off.sanitize_checks == 0


def test_sanitizer_env_var_toggle():
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ, TARDIS_SANITIZE="1")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    code = ("from repro.core import LeaseEngine; "
            "e = LeaseEngine(2, lease=2, backend='numpy'); "
            "e.write([0], 0); "
            "assert e.sanitize_checks == 1, e.sanitize_checks; "
            "print('SANITIZED')")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True)
    assert out.returncode == 0, out.stderr
    assert "SANITIZED" in out.stdout


# ---------------------------------------------------------------------------
# The protocol lint's core rule, exercised as a library
# ---------------------------------------------------------------------------

def test_lint_flags_table_mutation_outside_core():
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                    "scripts"))
    try:
        import lint_protocol as lp
    finally:
        sys.path.pop(0)
    fake = lp.ROOT / "src" / "repro" / "runtime" / "x.py"
    bad = ast.parse("engine._rts[idx] = 0\n"
                    "self.wts, other = a, b\n"
                    "eng.rts += 1\n")
    findings = lp.check_table_mutation(fake, bad)
    assert len(findings) == 3, findings
    assert all("timestamp table" in f for f in findings)
    good = ast.parse("local_copy = engine.rts\n"
                     "engine.other[idx] = 0\n"
                     "wts = 3\n")
    assert not lp.check_table_mutation(fake, good)
    # the whole tree is clean right now
    assert lp.main() == 0
