"""Activation sharding annotations bound to the ambient mesh.

Model code calls :func:`batch_activations` on residual streams and
:func:`replicate` on tiny decode activations.  Under a mesh context (the
dry-run's ``with mesh:`` / ``set_mesh``) these lower to
``with_sharding_constraint``; outside any mesh context they are exact
no-ops, so the same model code runs unannotated on a single host.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec

from .sharding import _dp_axes


def ambient_mesh():
    """The mesh currently in scope, or None.

    Prefers the new-style abstract mesh (``jax.sharding.set_mesh`` /
    ``use_mesh``); falls back to the legacy ``with mesh:`` context
    (``thread_resources.env.physical_mesh``) on older jax.
    """
    get_abstract = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_abstract is not None:
        try:
            m = get_abstract()
            if m is not None and not m.empty:
                return m
        except Exception:
            pass
    try:
        from jax.interpreters import pxla
        m = pxla.thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    return None


def batch_activations(x):
    """Constrain an activation's leading (batch) dim to the DP axes.

    Re-anchors the residual stream to batch-over-DP so feature shardings
    introduced by TP weights don't propagate layer to layer.  No-op without
    an ambient mesh or when the batch dim doesn't divide the DP axes.
    """
    mesh = ambient_mesh()
    if mesh is None or x.ndim == 0:
        return x
    dp = _dp_axes(mesh, x.shape[0])
    if dp is None:
        return x
    spec = [None] * x.ndim
    spec[0] = dp
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, PartitionSpec(*spec)))


def replicate(x):
    """Constrain to fully-replicated; no-op without an ambient mesh."""
    mesh = ambient_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, PartitionSpec()))
