"""Activation sharding annotations bound to the ambient mesh.

Model code calls :func:`batch_activations` on residual streams and
:func:`replicate` on tiny decode activations.  Under a mesh context (the
dry-run's ``with mesh:`` / ``set_mesh``) these lower to
``with_sharding_constraint``; outside any mesh context they are exact
no-ops, so the same model code runs unannotated on a single host.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec

from .sharding import activation_spec


def ambient_mesh():
    """The mesh currently in scope, or None.

    Prefers the new-style abstract mesh (``jax.sharding.set_mesh`` /
    ``use_mesh``); falls back to the legacy ``with mesh:`` context
    (``thread_resources.env.physical_mesh``) on older jax.
    """
    get_abstract = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_abstract is not None:
        try:
            m = get_abstract()
            if m is not None and not m.empty:
                return m
        except Exception:
            pass
    try:
        from jax.interpreters import pxla
        m = pxla.thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    return None


def batch_activations(x):
    """Constrain an activation to batch-over-DP plus sequence-over-model.

    Re-anchors the residual stream so feature shardings introduced by TP
    weights don't propagate layer to layer, and parks a 3-D+ activation's
    sequence dim on the otherwise-idle ``model`` axis (sequence
    parallelism -- see :func:`repro.dist.sharding.activation_spec`).
    No-op without an ambient mesh or when no dim divides its axes.
    """
    mesh = ambient_mesh()
    if mesh is None or x.ndim == 0:
        return x
    spec = activation_spec(mesh, x.shape)
    if all(ax is None for ax in spec):
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec))


def replicate(x):
    """Constrain to fully-replicated; no-op without an ambient mesh."""
    mesh = ambient_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, PartitionSpec()))
