"""Sharding rules for the (pod, data, model) production mesh.

One function -- :func:`param_spec` -- decides the placement of every weight
from its tree path and shape alone (configs never annotate tensors):

  * stacked per-layer weights keep their leading layer axis replicated (it is
    scanned over, never sharded),
  * 2-D+ weight bodies get tensor parallelism on their last dim over
    ``model`` and FSDP on their first dim over ``('pod', 'data')``,
  * MoE expert weights put the expert dim on ``model`` (expert parallelism)
    and FSDP on the d_model dim,
  * every placement is divisibility-guarded: a dim that does not divide the
    full axis product falls back -- ``('pod', 'data')`` degrades to ``data``
    alone (uneven-DP pod drop), and an indivisible dim replicates,
  * 1-D bodies (norms, biases, A_log/D vectors) replicate.

The ``*_shardings`` helpers wrap the specs into NamedSharding trees for the
dry-run / launch machinery.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple, Union

import jax
from jax.sharding import NamedSharding, PartitionSpec

Axes = Union[None, str, Tuple[str, ...]]

# Leading stacked axes per top-level parameter collection: per-layer weights
# are stacked on an L axis (hybrid "groups" adds an application axis too).
_STACK_DEPTH = {"layers": 1, "dense_layers": 1, "enc_layers": 1,
                "dec_layers": 1, "groups": 2}

# MoE expert weights: body-relative index of the d_model dim.
# w_gate / w_up are (E, D, F); w_down is (E, F, D).
_MOE_EXPERT_DMODEL = {"w_gate": 1, "w_up": 1, "w_down": 2}


def _axis_sizes(mesh) -> Dict[str, int]:
    return dict(mesh.shape)


def _dp_axes(mesh, dim: int) -> Axes:
    """FSDP placement for ``dim``: shard over ('pod', 'data') when divisible
    by the full product, drop the pod axis when only ``data`` divides, and
    replicate otherwise."""
    ax = _axis_sizes(mesh)
    data, pod = ax.get("data", 1), ax.get("pod", 1)
    if pod > 1 and data > 1 and dim % (pod * data) == 0:
        return ("pod", "data")
    if data > 1 and dim % data == 0:
        return "data"
    return None


def _model_axis(mesh, dim: int) -> Axes:
    model = _axis_sizes(mesh).get("model", 1)
    return "model" if model > 1 and dim % model == 0 else None


def param_spec(mesh, path: Tuple[str, ...], shape) -> PartitionSpec:
    """PartitionSpec for one weight, from its tree path and shape."""
    path = tuple(str(p) for p in path)
    stack = _STACK_DEPTH.get(path[0], 0) if path else 0
    stack = min(stack, max(0, len(shape) - 1))
    leaf = path[-1] if path else ""
    body = len(shape) - stack
    spec: list = [None] * len(shape)
    if "moe" in path and leaf in _MOE_EXPERT_DMODEL and body == 3:
        spec[stack] = _model_axis(mesh, shape[stack])        # experts -> EP
        d_idx = stack + _MOE_EXPERT_DMODEL[leaf]
        spec[d_idx] = _dp_axes(mesh, shape[d_idx])           # FSDP on d_model
    elif body >= 2:
        spec[-1] = _model_axis(mesh, shape[-1])              # TP on features
        spec[stack] = _dp_axes(mesh, shape[stack])           # FSDP on inputs
    return PartitionSpec(*spec)


def _path_names(key_path) -> Tuple[str, ...]:
    names = []
    for k in key_path:
        if hasattr(k, "key"):
            names.append(str(k.key))
        elif hasattr(k, "idx"):
            names.append(str(k.idx))
        elif hasattr(k, "name"):
            names.append(str(k.name))
        else:
            names.append(str(k))
    return tuple(names)


def param_shardings(mesh, params) -> Any:
    """NamedSharding tree mirroring ``params``."""
    return jax.tree_util.tree_map_with_path(
        lambda kp, leaf: NamedSharding(
            mesh, param_spec(mesh, _path_names(kp), leaf.shape)),
        params)


def opt_shardings(mesh, opt, param_shardings) -> Any:
    """Optimizer-state shardings: moments mirror the parameters (ZeRO);
    everything else (step counters etc.) replicates."""
    rep = NamedSharding(mesh, PartitionSpec())
    return {key: (param_shardings if key in ("m", "v")
                  else jax.tree.map(lambda _: rep, sub))
            for key, sub in opt.items()}


def batch_spec(mesh, shape) -> PartitionSpec:
    """Leading (batch) dim over the DP axes, everything else replicated."""
    if len(shape) == 0:
        return PartitionSpec()
    spec = [None] * len(shape)
    spec[0] = _dp_axes(mesh, shape[0])
    return PartitionSpec(*spec)


def activation_spec(mesh, shape) -> PartitionSpec:
    """Residual-stream placement: batch over the DP axes plus **sequence
    parallelism** -- a 3-D+ activation's second (sequence) dim shards over
    ``model`` when divisible.  Between TP regions the model axis is idle,
    so parking the sequence dim there cuts per-device activation memory by
    the TP degree (norms and element-wise ops are position-local); the TP
    matmuls' own all-gather re-materializes the full sequence exactly where
    it is needed.  Divisibility-guarded like every other placement: an
    indivisible sequence dim replicates."""
    if len(shape) == 0:
        return PartitionSpec()
    spec = [None] * len(shape)
    spec[0] = _dp_axes(mesh, shape[0])
    if len(shape) >= 3:
        spec[1] = _model_axis(mesh, shape[1])
    return PartitionSpec(*spec)


def batch_shardings(mesh, batch) -> Any:
    """Shard every batch leaf's leading (batch) dim over the DP axes."""
    return jax.tree.map(
        lambda leaf: NamedSharding(mesh, batch_spec(mesh, leaf.shape)), batch)


# Decode-cache leaves by dict key: (heads_dim_index) for the model axis.
# KV caches are (L, B, T, H, Dh); SSM state is (L, B, H, P, N); conv buffers
# are (L, B, W, C).
_CACHE_MODEL_DIM = {"k": 3, "v": 3, "ak": 3, "av": 3, "ck": 3, "cv": 3,
                    "dk": 3, "dv": 3, "state": 2, "conv": 3}


def cache_spec(mesh, name: str, shape) -> PartitionSpec:
    """Batch dim (index 1) over DP, heads/channels dim over model."""
    spec: list = [None] * len(shape)
    if len(shape) >= 2:
        spec[1] = _dp_axes(mesh, shape[1])
    mdim = _CACHE_MODEL_DIM.get(name)
    if mdim is not None and mdim < len(shape):
        spec[mdim] = _model_axis(mesh, shape[mdim])
    return PartitionSpec(*spec)


def cache_shardings(mesh, cache) -> Any:
    """Decode caches: batch dim over DP, heads/channels dim over model."""
    def one(kp, leaf):
        names = _path_names(kp)
        name = names[-1] if names else ""
        return NamedSharding(mesh, cache_spec(mesh, name, leaf.shape))
    return jax.tree_util.tree_map_with_path(one, cache)
