"""Gradient collectives: int8 error-feedback compression and microbatching.

``compress_grads`` quantizes each gradient leaf to int8 with a per-leaf
scale and carries the quantization error forward as a residual (error
feedback), so the *cumulative* dequantized sum tracks the true gradient sum
to within one quantization step -- the residual never accumulates.  This is
what crosses the data-parallel axis when ``TrainConfig.grad_compression``
is on (4x fewer bytes than fp32 all-reduce).

``microbatch_grads`` accumulates gradients over ``n_micro`` equal slices of
the batch with ``lax.scan`` (O(1) HLO in the microbatch count), matching the
full-batch gradient of the mean loss exactly for equal slice sizes.

The device collectives (``psum`` / ``all_gather`` / ``reduce_scatter`` /
``all_to_all``) are thin named-axis wrappers for use inside ``shard_map``
over the ``data``/``pod`` mesh axes; the ``np_*`` functions are their
deterministic host mirrors over a list of per-device arrays, so transport
code (the sharded lease directory's per-wave shard exchange) can be tested
bit-for-bit on CPU without a multi-device runtime.
"""
from __future__ import annotations

from typing import Any, Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Tree = Any


def init_residual(grads: Tree) -> Tree:
    """Zero error-feedback residual matching the gradient tree (fp32)."""
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def _quantize(e):
    scale = jnp.max(jnp.abs(e)) / 127.0
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(e / safe), -127, 127).astype(jnp.int8)
    return q, scale


def compress_grads(grads: Tree, residual: Tree) -> Tuple[Tree, Tree]:
    """Returns ((int8_tree, scale_tree), new_residual).

    Each leaf is quantized as ``q = round((g + r) * 127 / max|g + r|)``;
    the new residual is the leftover ``(g + r) - dequant(q)``.
    """
    g_leaves, treedef = jax.tree_util.tree_flatten(grads)
    r_leaves = jax.tree.leaves(residual)
    qs, scales, res = [], [], []
    for g, r in zip(g_leaves, r_leaves):
        e = g.astype(jnp.float32) + r
        q, scale = _quantize(e)
        qs.append(q)
        scales.append(scale)
        res.append(e - q.astype(jnp.float32) * scale)
    return ((treedef.unflatten(qs), treedef.unflatten(scales)),
            treedef.unflatten(res))


def decompress_grads(compressed) -> Tree:
    """Inverse of :func:`compress_grads`: int8 * scale -> fp32 gradients."""
    qs, scales = compressed
    return jax.tree.map(lambda q, s: q.astype(jnp.float32) * s, qs, scales)


def microbatch_grads(loss_fn: Callable[[Tree, Tree], jnp.ndarray],
                     params: Tree, batch: Tree, n_micro: int
                     ) -> Tuple[jnp.ndarray, Tree]:
    """Mean (loss, grads) over ``n_micro`` equal batch slices via lax.scan.

    ``loss_fn(params, microbatch)`` must be a *mean* loss; with equal slice
    sizes the accumulated mean equals the full-batch value to fp32 rounding.
    """
    n_micro = int(n_micro)
    if n_micro <= 1:
        return jax.value_and_grad(loss_fn)(params, batch)

    def split(x):
        b = x.shape[0]
        if b % n_micro:
            raise ValueError(
                f"batch dim {b} not divisible by n_micro={n_micro}")
        return x.reshape((n_micro, b // n_micro) + x.shape[1:])

    micro = jax.tree.map(split, batch)
    grad_fn = jax.value_and_grad(loss_fn)

    def body(carry, mb):
        acc_loss, acc_grads = carry
        loss, grads = grad_fn(params, mb)
        acc_grads = jax.tree.map(
            lambda a, g: a + g.astype(jnp.float32), acc_grads, grads)
        return (acc_loss + loss.astype(jnp.float32), acc_grads), None

    zero = (jnp.zeros((), jnp.float32),
            jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))
    (loss_sum, grad_sum), _ = jax.lax.scan(body, zero, micro)
    inv = 1.0 / n_micro
    grads = jax.tree.map(
        lambda g, p: (g * inv).astype(p.dtype), grad_sum, params)
    return loss_sum * inv, grads


# ---------------------------------------------------------------------------
# Named-axis device collectives (shard_map bodies over the data/pod axes)
# ---------------------------------------------------------------------------

def psum(x, axis):
    """All-reduce-sum over the named mesh axis (or tuple of axes)."""
    return jax.lax.psum(x, axis)


def all_gather(x, axis):
    """Concatenate every device's shard along dim 0 (tiled all-gather)."""
    return jax.lax.all_gather(x, axis, axis=0, tiled=True)


def reduce_scatter(x, axis):
    """Sum across the axis, then split the result along dim 0.

    Device ``i`` keeps rows ``[i*n/N, (i+1)*n/N)`` of the sum -- the
    standard reduce-scatter building block of a bandwidth-optimal
    all-reduce (all-gather of the scattered sums completes it).
    """
    return jax.lax.psum_scatter(x, axis, scatter_dimension=0, tiled=True)


def all_to_all(x, axis):
    """Transpose shards across the axis: row block j of device i lands on
    device j as row block i.  This is the one-message-per-peer exchange the
    sharded lease directory rides."""
    return jax.lax.all_to_all(x, axis, split_axis=0, concat_axis=0,
                              tiled=True)


# ---------------------------------------------------------------------------
# Deterministic numpy mirrors: lists of per-device arrays in, same out.
# Shapes/semantics match the tiled device ops above exactly.
# ---------------------------------------------------------------------------

def np_psum(shards: Sequence[np.ndarray]) -> List[np.ndarray]:
    total = np.sum(np.stack([np.asarray(s) for s in shards]), axis=0)
    return [total.copy() for _ in shards]


def np_all_gather(shards: Sequence[np.ndarray]) -> List[np.ndarray]:
    full = np.concatenate([np.asarray(s) for s in shards], axis=0)
    return [full.copy() for _ in shards]


def np_reduce_scatter(shards: Sequence[np.ndarray]) -> List[np.ndarray]:
    n = len(shards)
    total = np.sum(np.stack([np.asarray(s) for s in shards]), axis=0)
    if total.shape[0] % n:
        raise ValueError(
            f"reduce_scatter dim 0 ({total.shape[0]}) not divisible by "
            f"device count {n}")
    return [p.copy() for p in np.split(total, n, axis=0)]


def np_all_to_all(shards: Sequence[np.ndarray]) -> List[np.ndarray]:
    n = len(shards)
    pieces = []
    for s in shards:
        s = np.asarray(s)
        if s.shape[0] % n:
            raise ValueError(
                f"all_to_all dim 0 ({s.shape[0]}) not divisible by "
                f"device count {n}")
        pieces.append(np.split(s, n, axis=0))
    # device j receives piece j of every device, in device order
    return [np.concatenate([pieces[i][j] for i in range(n)], axis=0)
            for j in range(n)]
