"""Gradient collectives: int8 error-feedback compression and microbatching.

``compress_grads`` quantizes each gradient leaf to int8 with a per-leaf
scale and carries the quantization error forward as a residual (error
feedback), so the *cumulative* dequantized sum tracks the true gradient sum
to within one quantization step -- the residual never accumulates.  This is
what crosses the data-parallel axis when ``TrainConfig.grad_compression``
is on (4x fewer bytes than fp32 all-reduce).

``microbatch_grads`` accumulates gradients over ``n_micro`` equal slices of
the batch with ``lax.scan`` (O(1) HLO in the microbatch count), matching the
full-batch gradient of the mean loss exactly for equal slice sizes.
"""
from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

Tree = Any


def init_residual(grads: Tree) -> Tree:
    """Zero error-feedback residual matching the gradient tree (fp32)."""
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def _quantize(e):
    scale = jnp.max(jnp.abs(e)) / 127.0
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(e / safe), -127, 127).astype(jnp.int8)
    return q, scale


def compress_grads(grads: Tree, residual: Tree) -> Tuple[Tree, Tree]:
    """Returns ((int8_tree, scale_tree), new_residual).

    Each leaf is quantized as ``q = round((g + r) * 127 / max|g + r|)``;
    the new residual is the leftover ``(g + r) - dequant(q)``.
    """
    g_leaves, treedef = jax.tree_util.tree_flatten(grads)
    r_leaves = jax.tree.leaves(residual)
    qs, scales, res = [], [], []
    for g, r in zip(g_leaves, r_leaves):
        e = g.astype(jnp.float32) + r
        q, scale = _quantize(e)
        qs.append(q)
        scales.append(scale)
        res.append(e - q.astype(jnp.float32) * scale)
    return ((treedef.unflatten(qs), treedef.unflatten(scales)),
            treedef.unflatten(res))


def decompress_grads(compressed) -> Tree:
    """Inverse of :func:`compress_grads`: int8 * scale -> fp32 gradients."""
    qs, scales = compressed
    return jax.tree.map(lambda q, s: q.astype(jnp.float32) * s, qs, scales)


def microbatch_grads(loss_fn: Callable[[Tree, Tree], jnp.ndarray],
                     params: Tree, batch: Tree, n_micro: int
                     ) -> Tuple[jnp.ndarray, Tree]:
    """Mean (loss, grads) over ``n_micro`` equal batch slices via lax.scan.

    ``loss_fn(params, microbatch)`` must be a *mean* loss; with equal slice
    sizes the accumulated mean equals the full-batch value to fp32 rounding.
    """
    n_micro = int(n_micro)
    if n_micro <= 1:
        return jax.value_and_grad(loss_fn)(params, batch)

    def split(x):
        b = x.shape[0]
        if b % n_micro:
            raise ValueError(
                f"batch dim {b} not divisible by n_micro={n_micro}")
        return x.reshape((n_micro, b // n_micro) + x.shape[1:])

    micro = jax.tree.map(split, batch)
    grad_fn = jax.value_and_grad(loss_fn)

    def body(carry, mb):
        acc_loss, acc_grads = carry
        loss, grads = grad_fn(params, mb)
        acc_grads = jax.tree.map(
            lambda a, g: a + g.astype(jnp.float32), acc_grads, grads)
        return (acc_loss + loss.astype(jnp.float32), acc_grads), None

    zero = (jnp.zeros((), jnp.float32),
            jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))
    (loss_sum, grad_sum), _ = jax.lax.scan(body, zero, micro)
    inv = 1.0 / n_micro
    grads = jax.tree.map(
        lambda g, p: (g * inv).astype(p.dtype), grad_sum, params)
    return loss_sum * inv, grads
