"""Distribution layer: sharding rules, gradient collectives, activation
annotations.

This is the single place that knows how tensors land on the (pod, data,
model) production mesh:

  * :mod:`repro.dist.sharding`    -- NamedSharding trees for params /
    optimizer state / batches / decode caches (divisibility-guarded,
    expert-parallel MoE placement, pod-axis fallback),
  * :mod:`repro.dist.collectives` -- int8 error-feedback gradient
    compression and scan-based microbatch accumulation,
  * :mod:`repro.dist.annotate`    -- activation sharding constraints that
    bind to the ambient mesh (no-ops outside a mesh context, so model code
    runs unchanged on a single host).
"""
from . import annotate, collectives, sharding

__all__ = ["annotate", "collectives", "sharding"]
