"""Config for --arch mistral-nemo-12b (see registry.py for the source citation)."""
from .registry import get_arch

CONFIG = get_arch("mistral-nemo-12b")
