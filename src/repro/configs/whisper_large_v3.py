"""Config for --arch whisper-large-v3 (see registry.py for the source citation)."""
from .registry import get_arch

CONFIG = get_arch("whisper-large-v3")
