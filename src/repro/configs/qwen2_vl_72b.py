"""Config for --arch qwen2-vl-72b (see registry.py for the source citation)."""
from .registry import get_arch

CONFIG = get_arch("qwen2-vl-72b")
