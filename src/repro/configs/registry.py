"""--arch registry: the 10 assigned architectures (exact public configs).

Sources are cited per entry; `[...]` verification tiers follow the
assignment sheet.  Every config is exercised two ways:
  * reduced smoke test (tests/test_configs_smoke.py) -- one real step on CPU,
  * full config -- dry-run only (ShapeDtypeStruct lowering, no allocation).
"""
from __future__ import annotations

from .base import ArchConfig

ARCHS = {
    # [hybrid] Mamba2 + shared attn blocks [arXiv:2411.15242; hf]
    "zamba2-2.7b": ArchConfig(
        name="zamba2-2.7b", family="hybrid", n_layers=54, d_model=2560,
        n_heads=32, n_kv_heads=32, d_ff=10240, vocab=32000,
        ssm_state=64, ssm_headdim=64, ssm_expand=2, attn_every=6),
    # [audio] enc-dec, conv frontend stub [arXiv:2212.04356]
    "whisper-large-v3": ArchConfig(
        name="whisper-large-v3", family="encdec", n_layers=32, d_model=1280,
        n_heads=20, n_kv_heads=20, d_ff=5120, vocab=51866,
        n_enc_layers=32, frontend="audio"),
    # [moe] Kimi K2 trillion-param MoE [arXiv:2501.kimi2]
    "kimi-k2-1t-a32b": ArchConfig(
        name="kimi-k2-1t-a32b", family="moe", n_layers=61, d_model=7168,
        n_heads=64, n_kv_heads=8, d_ff=0, vocab=163840,
        n_experts=384, top_k=8, d_ff_expert=2048, n_shared_experts=1,
        first_dense_layers=1),
    # [moe] 128 experts top-2 + dense residual [hf:Snowflake/snowflake-arctic-base]
    "arctic-480b": ArchConfig(
        name="arctic-480b", family="moe", n_layers=35, d_model=7168,
        n_heads=56, n_kv_heads=8, d_ff=0, vocab=32000,
        n_experts=128, top_k=2, d_ff_expert=4864, residual_ff=4864),
    # [dense] 128k ctx [hf:mistralai/Mistral-Nemo-Base-2407]
    "mistral-nemo-12b": ArchConfig(
        name="mistral-nemo-12b", family="dense", n_layers=40, d_model=5120,
        n_heads=32, n_kv_heads=8, d_ff=14336, vocab=131072, d_head=128,
        rope_theta=1e6),
    # [dense] GQA 128k vocab [arXiv:2407.21783]
    "llama3-405b": ArchConfig(
        name="llama3-405b", family="dense", n_layers=126, d_model=16384,
        n_heads=128, n_kv_heads=8, d_ff=53248, vocab=128256,
        rope_theta=5e5),
    # [dense] llama2-arch small [arXiv:2401.02385]
    "tinyllama-1.1b": ArchConfig(
        name="tinyllama-1.1b", family="dense", n_layers=22, d_model=2048,
        n_heads=32, n_kv_heads=4, d_ff=5632, vocab=32000),
    # [dense] RoPE, GQA kv=2 [hf:THUDM/glm-4-9b]
    "glm4-9b": ArchConfig(
        name="glm4-9b", family="dense", n_layers=40, d_model=4096,
        n_heads=32, n_kv_heads=2, d_ff=13696, vocab=151552),
    # [ssm] SSD state-space duality [arXiv:2405.21060]
    "mamba2-130m": ArchConfig(
        name="mamba2-130m", family="ssm", n_layers=24, d_model=768,
        n_heads=0, n_kv_heads=0, d_ff=0, vocab=50280,
        ssm_state=128, ssm_headdim=64, ssm_expand=2,
        tie_embeddings=True),      # mamba2-130m ties embed/lm_head
    # [vlm] M-RoPE, dynamic resolution backbone [arXiv:2409.12191]
    "qwen2-vl-72b": ArchConfig(
        name="qwen2-vl-72b", family="vlm", n_layers=80, d_model=8192,
        n_heads=64, n_kv_heads=8, d_ff=29568, vocab=152064,
        rope_theta=1e6, mrope=True, frontend="vision"),
}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]
