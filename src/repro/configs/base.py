"""Architecture configuration dataclass + input-shape sets.

One :class:`ArchConfig` per assigned architecture lives in its own module in
this package; ``registry.py`` maps ``--arch`` ids to them.  The dataclass is
hashable (frozen) so model functions can take it as a static argument.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0                # 0 -> d_model // n_heads
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    n_shared_experts: int = 0
    residual_ff: int = 0           # arctic: parallel dense-residual MLP width
    first_dense_layers: int = 0    # kimi: leading dense layers
    capacity_factor: float = 1.25

    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    conv_width: int = 4

    # --- hybrid (zamba2) ---
    attn_every: int = 0            # shared attention block period (0 = none)

    # --- enc-dec (whisper) ---
    n_enc_layers: int = 0          # 0 -> decoder-only
    frontend: Optional[str] = None # "audio" | "vision" stub frontends

    # --- VLM ---
    mrope: bool = False            # 3-section rotary (M-RoPE)

    # --- attention behaviour ---
    sliding_window: int = 0        # 0 = full attention

    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def ssm_heads(self) -> int:
        return (self.ssm_expand * self.d_model) // self.ssm_headdim

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def param_count(self) -> int:
        """Analytic parameter count (embedding + stacked blocks + head)."""
        d, v = self.d_model, self.vocab
        total = v * d                     # embedding
        if not self.tie_embeddings:
            total += d * v                # lm head (untied)
        total += d                        # final norm
        blocks = 0
        hd = self.head_dim() if self.n_heads else 0
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
            + (self.n_heads * hd) * d + 2 * d
        dense_mlp = 3 * d * self.d_ff + d if self.d_ff else 0
        if self.family in ("dense", "vlm"):
            blocks = self.n_layers * (attn + dense_mlp)
        elif self.family == "moe":
            moe_mlp = 3 * d * self.d_ff_expert * self.n_experts
            moe_mlp += self.n_shared_experts * 3 * d * self.d_ff_expert
            moe_mlp += d * self.n_experts          # router
            moe_mlp += 3 * d * self.residual_ff    # arctic dense residual
            moe_mlp += d
            n_moe = self.n_layers - self.first_dense_layers
            blocks = n_moe * (attn + moe_mlp) \
                + self.first_dense_layers * (attn + dense_mlp)
        elif self.family == "ssm":
            blocks = self.n_layers * self._ssm_block_params()
        elif self.family == "hybrid":
            blocks = self.n_layers * self._ssm_block_params()
            blocks += attn + dense_mlp             # one shared attn block
        elif self.family == "encdec":
            enc_blocks = self.n_enc_layers * (attn + dense_mlp)
            dec_blocks = self.n_layers * (2 * attn + dense_mlp)
            blocks = enc_blocks + dec_blocks
        return total + blocks

    def _ssm_block_params(self) -> int:
        d, di, n, h = self.d_model, self.d_inner, self.ssm_state, self.ssm_heads
        p = d * (2 * di + 2 * n + h)      # in_proj -> (x, z, B, C, dt)
        p += self.conv_width * (di + 2 * n)
        p += 2 * h                        # A_log, D
        p += di * d + 2 * d               # out_proj + norms
        return p

    def active_param_count(self) -> int:
        """6*N_active*D convention for MoE rooflines."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        hd = self.head_dim()
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
            + (self.n_heads * hd) * d + 2 * d
        act_mlp = 3 * d * self.d_ff_expert * (self.top_k + self.n_shared_experts)
        act_mlp += 3 * d * self.residual_ff + d * self.n_experts + d
        dense_mlp = 3 * d * self.d_ff + d if self.d_ff else 0
        n_moe = self.n_layers - self.first_dense_layers
        total = 2 * self.vocab * d + d
        return total + n_moe * (attn + act_mlp) \
            + self.first_dense_layers * (attn + dense_mlp)


@dataclasses.dataclass(frozen=True)
class ShapeSet:
    """One assigned (shape-id -> concrete shapes) cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # "train" | "prefill" | "decode"


SHAPES: Tuple[ShapeSet, ...] = (
    ShapeSet("train_4k", 4096, 256, "train"),
    ShapeSet("prefill_32k", 32768, 32, "prefill"),
    ShapeSet("decode_32k", 32768, 128, "decode"),
    ShapeSet("long_500k", 524288, 1, "decode"),
)

SHAPE_BY_NAME = {s.name: s for s in SHAPES}

# Archs whose attention is fully quadratic skip long_500k (see DESIGN.md §4).
SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def reduced(cfg: ArchConfig, **overrides) -> ArchConfig:
    """A tiny same-family config for CPU smoke tests."""
    base = dict(
        n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=max(1, min(cfg.n_kv_heads, 2)), d_ff=128,
        vocab=256, d_head=16)
    if cfg.family == "moe":
        base.update(n_experts=4, top_k=min(2, cfg.top_k), d_ff_expert=64,
                    n_shared_experts=min(1, cfg.n_shared_experts),
                    residual_ff=64 if cfg.residual_ff else 0,
                    first_dense_layers=min(1, cfg.first_dense_layers))
    if cfg.family in ("ssm", "hybrid"):
        base.update(ssm_state=16, ssm_headdim=16, ssm_chunk=16)
    if cfg.family == "hybrid":
        base.update(attn_every=1)      # keep >=1 shared-attn application
    if cfg.family == "encdec":
        base.update(n_enc_layers=2)
    base.update(overrides)
    return dataclasses.replace(cfg, **base)
