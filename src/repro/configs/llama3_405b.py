"""Config for --arch llama3-405b (see registry.py for the source citation)."""
from .registry import get_arch

CONFIG = get_arch("llama3-405b")
