"""Config for --arch zamba2-2.7b (see registry.py for the source citation)."""
from .registry import get_arch

CONFIG = get_arch("zamba2-2.7b")
