from .base import (ArchConfig, ShapeSet, SHAPES, SHAPE_BY_NAME,
                   SUBQUADRATIC_FAMILIES, reduced)
from .registry import ARCHS, get_arch

__all__ = ["ArchConfig", "ShapeSet", "SHAPES", "SHAPE_BY_NAME",
           "SUBQUADRATIC_FAMILIES", "reduced", "ARCHS", "get_arch"]
