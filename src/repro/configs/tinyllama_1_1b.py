"""Config for --arch tinyllama-1.1b (see registry.py for the source citation)."""
from .registry import get_arch

CONFIG = get_arch("tinyllama-1.1b")
