"""Config for --arch arctic-480b (see registry.py for the source citation)."""
from .registry import get_arch

CONFIG = get_arch("arctic-480b")
