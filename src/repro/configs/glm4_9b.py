"""Config for --arch glm4-9b (see registry.py for the source citation)."""
from .registry import get_arch

CONFIG = get_arch("glm4-9b")
