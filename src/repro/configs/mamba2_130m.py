"""Config for --arch mamba2-130m (see registry.py for the source citation)."""
from .registry import get_arch

CONFIG = get_arch("mamba2-130m")
