"""Config for --arch kimi-k2-1t-a32b (see registry.py for the source citation)."""
from .registry import get_arch

CONFIG = get_arch("kimi-k2-1t-a32b")
