"""Mixture-of-Experts layer: top-k routing with sort-based capacity dispatch.

The dispatch avoids the (tokens, experts, capacity) one-hot einsum entirely:
assignments are sorted by expert id, positions-within-expert come from a
cumsum, and tokens scatter into an (E, C, D) buffer (overflow drops into a
sacrificial capacity slot).  This keeps memory O(E*C*D) and lowers to
gather/scatter + batched matmuls that GSPMD shards cleanly with experts on
the `model` axis (EP) and capacity on the `data` axis.

Supports the assigned MoE variants:
  * kimi-k2: 384 experts top-8, 1 shared expert, first layer dense,
  * arctic:  128 experts top-2 plus a parallel dense-residual MLP.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense_init, swiglu, swiglu_init


def moe_init(key, cfg, dtype):
    d, f, e = cfg.d_model, cfg.d_ff_expert, cfg.n_experts
    ks = jax.random.split(key, 6)
    p = {
        "router": dense_init(ks[0], (d, e), jnp.float32, scale=0.02),
        "w_gate": dense_init(ks[1], (e, d, f), dtype),
        "w_up": dense_init(ks[2], (e, d, f), dtype),
        "w_down": dense_init(ks[3], (e, f, d), dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = swiglu_init(ks[4], d, f * cfg.n_shared_experts, dtype)
    if cfg.residual_ff:
        p["residual"] = swiglu_init(ks[5], d, cfg.residual_ff, dtype)
    return p


def _capacity(cfg, n_tokens: int) -> int:
    """Expert capacity for a dispatch of ``n_tokens`` (= B*S).

    Decode calls this with S=1, so capacity tracks the LIVE batch size --
    the paged decode step (``decoding.decode_step_paged``, moe stacks
    through LeaseEngine named pools) and the dense-cache ``decode_step``
    see the same ``n_tokens`` for the same batch, which is what keeps the
    paged-vs-dense differential bit-exact: capacity (and therefore token
    drop behaviour) is a function of the schedule, not of the KV substrate.
    """
    c = int(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(8, -(-c // 8) * 8)     # round up to 8 for lane alignment


def moe_apply(p, cfg, x):
    """x: (B, S, D) -> (B, S, D)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    xf = x.reshape(t, d)

    # --- routing (fp32) ----------------------------------------------------
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)            # (t, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    # --- sort-based dispatch ------------------------------------------------
    flat_exp = expert_ids.reshape(t * k)
    flat_tok = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    order = jnp.argsort(flat_exp)                              # stable
    sorted_exp = flat_exp[order]
    sorted_tok = flat_tok[order]
    # position of each assignment within its expert
    ones = jnp.ones_like(sorted_exp)
    pos_global = jnp.cumsum(ones) - 1
    seg_start = jnp.searchsorted(sorted_exp, jnp.arange(e), side="left")
    pos = pos_global - seg_start[sorted_exp]
    cap = _capacity(cfg, t)
    slot = jnp.minimum(pos, cap)                               # cap = overflow bin
    buf = jnp.zeros((e, cap + 1, d), x.dtype)
    buf = buf.at[sorted_exp, slot].set(xf[sorted_tok], mode="drop")
    buf = buf[:, :cap]                                         # drop overflow

    # --- expert computation (batched over experts) --------------------------
    g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    y = jnp.einsum("ecf,efd->ecd", h, p["w_down"])

    # --- combine -------------------------------------------------------------
    kept = pos < cap
    gathered = y[sorted_exp, jnp.minimum(pos, cap - 1)]        # (t*k, d)
    gathered = jnp.where(kept[:, None], gathered, 0)
    contrib = jnp.zeros((t * k, d), x.dtype).at[order].set(gathered)
    contrib = contrib.reshape(t, k, d)
    out = jnp.einsum("tkd,tk->td", contrib.astype(jnp.float32),
                     gate_vals).astype(x.dtype)

    if cfg.n_shared_experts:
        out = out + swiglu(p["shared"], xf)
    if cfg.residual_ff:
        out = out + swiglu(p["residual"], xf)
    return out.reshape(b, s, d)


def aux_load_balance_loss(p, cfg, x):
    """Switch-style load-balance auxiliary loss (fraction * prob per expert)."""
    b, s, d = x.shape
    t = b * s
    logits = jnp.einsum("td,de->te", x.reshape(t, d).astype(jnp.float32),
                        p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    _, ids = jax.lax.top_k(probs, cfg.top_k)
    frac = jnp.mean(jax.nn.one_hot(ids, cfg.n_experts, dtype=jnp.float32),
                    axis=(0, 1))
    imp = jnp.mean(probs, axis=0)
    return cfg.n_experts * jnp.sum(frac * imp)
