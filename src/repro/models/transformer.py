"""Model assembly for every assigned architecture family.

All families share one parameter/layout discipline:
  * per-layer params are stacked on a leading L axis and the forward pass is
    a ``lax.scan`` over layers (HLO size O(1) in depth; bodies are
    rematerialized for training),
  * caches for decode are stacked the same way and threaded through the scan,
  * the hybrid (zamba2) model interleaves scanned Mamba2 groups with a single
    *weight-shared* attention block applied every ``attn_every`` layers
    (its KV caches are per-application),
  * enc-dec (whisper) runs a bidirectional encoder scan + causal/cross
    decoder scan; the conv/audio frontend is a stub (inputs arrive as frame
    embeddings, per the assignment).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .attention import attend, decode_attention
from .layers import apply_rope, chunked_xent, dense_init, rmsnorm, swiglu, \
    swiglu_init
from .moe import moe_apply, moe_init
from .ssm import ssm_block, ssm_init

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Attention block (params + apply), GQA + RoPE/M-RoPE + optional cross-attn
# ---------------------------------------------------------------------------

def attn_init(key, cfg: ArchConfig, dtype, cross: bool = False):
    d, h, hk = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    dh = cfg.head_dim()
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (d, h * dh), dtype),
        "wk": dense_init(ks[1], (d, hk * dh), dtype),
        "wv": dense_init(ks[2], (d, hk * dh), dtype),
        "wo": dense_init(ks[3], (h * dh, d), dtype),
        "norm": jnp.ones((d,), dtype),
    }


def _qkv(p, cfg: ArchConfig, x, kv_src=None):
    b, s, d = x.shape
    dh = cfg.head_dim()
    src = x if kv_src is None else kv_src
    q = jnp.einsum("bsd,de->bse", x, p["wq"]).reshape(b, s, cfg.n_heads, dh)
    k = jnp.einsum("bsd,de->bse", src, p["wk"]).reshape(
        b, src.shape[1], cfg.n_kv_heads, dh)
    v = jnp.einsum("bsd,de->bse", src, p["wv"]).reshape(
        b, src.shape[1], cfg.n_kv_heads, dh)
    return q, k, v


def attn_apply(p, cfg: ArchConfig, x, positions, *, causal=True,
               use_rope=True):
    """Self-attention over a full sequence (train / prefill)."""
    xn = rmsnorm(x, p["norm"], cfg.norm_eps)
    q, k, v = _qkv(p, cfg, xn)
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope)
    out = attend(q, k, v, causal=causal, window=cfg.sliding_window)
    b, s, _ = x.shape
    return jnp.einsum("bse,ed->bsd", out.reshape(b, s, -1), p["wo"])


def attn_prefill(p, cfg, x, positions, cache_len: int):
    """Prefill that also returns the (padded) KV cache."""
    xn = rmsnorm(x, p["norm"], cfg.norm_eps)
    q, k, v = _qkv(p, cfg, xn)
    q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope)
    out = attend(q, k, v, causal=True, window=cfg.sliding_window)
    b, s, _ = x.shape
    pad = cache_len - s
    kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    y = jnp.einsum("bse,ed->bsd", out.reshape(b, s, -1), p["wo"])
    return y, (kc, vc)


def attn_prefill_cached(p, cfg, x, positions, kc, vc, prefix_len: int):
    """Chunked prefill against a partially-filled cache.

    The first ``prefix_len`` cache slots already hold leased prefix KV
    (RoPE'd at their absolute positions, so any request sharing the prefix
    reuses them verbatim); only the suffix queries/KV are computed here.
    ``positions`` must start at ``prefix_len``.  Returns (y, kc, vc).
    """
    b, s, _ = x.shape
    xn = rmsnorm(x, p["norm"], cfg.norm_eps)
    q, k, v = _qkv(p, cfg, xn)
    q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope)
    kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype),
                                      (0, prefix_len, 0, 0))
    vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype),
                                      (0, prefix_len, 0, 0))
    out = attend(q, kc, vc, causal=True, q_offset=prefix_len,
                 window=cfg.sliding_window, kv_len=prefix_len + s)
    y = jnp.einsum("bse,ed->bsd", out.reshape(b, s, -1), p["wo"])
    return y, kc, vc


def attn_decode(p, cfg, x, kc, vc, cur_idx):
    """One-token decode: insert k/v at cur_idx, attend over cache.

    ``cur_idx`` is a scalar (a wave decoding in lockstep) or a (B,) vector
    (continuous batching: each request at its own position).
    """
    b = x.shape[0]
    xn = rmsnorm(x, p["norm"], cfg.norm_eps)
    q, k, v = _qkv(p, cfg, xn)
    cur = jnp.asarray(cur_idx, jnp.int32)
    pos = jnp.full((b, 1), cur, jnp.int32) if cur.ndim == 0 else cur[:, None]
    q = apply_rope(q, pos, cfg.rope_theta, cfg.mrope)
    k = apply_rope(k, pos, cfg.rope_theta, cfg.mrope)
    if cur.ndim == 0:
        kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype),
                                          (0, cur, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype),
                                          (0, cur, 0, 0))
    else:
        slot = jnp.arange(kc.shape[1], dtype=jnp.int32)[None, :] == pos
        kc = jnp.where(slot[..., None, None], k.astype(kc.dtype), kc)
        vc = jnp.where(slot[..., None, None], v.astype(vc.dtype), vc)
    out = decode_attention(q, kc, vc, cur + 1)
    y = jnp.einsum("bse,ed->bsd", out.reshape(b, 1, -1), p["wo"])
    return y, kc, vc


def attn_decode_paged(p, cfg, x, pool_rows, page_rows, lengths, k_off: int,
                      v_off: int, *, pool_off: int = 0, chunk: int,
                      interpret: bool = False, use_kernel=None):
    """One-token decode where the KV cache lives in LeaseEngine pool pages.

    ``pool_rows`` is the engine pool's (n_blocks*chunk, token_row) view;
    ``page_rows`` (B, P) int32 names each request's pages (prefix blocks
    shared under leases + privately allocated decode pages); ``lengths``
    (B,) counts the tokens already in pages.  ``k_off`` / ``v_off`` are the
    layer's static column offsets WITHIN its cache stack's segment and
    ``pool_off`` is the stack's pool offset inside the interleaved token
    row (see :func:`repro.models.decoding.pool_layout`; 0 for
    single-stack families).  Returns (y, k_cur, v_cur): the fresh RoPE'd
    KV in pool dtype -- the caller accumulates every stack's layer slices
    into one token row and appends it once per step.

    ``use_kernel=None`` routes through the Pallas paged flash-decode kernel
    on TPU; the default elsewhere is gather-then-reference, which is
    bit-exact with the dense-cache decode path.
    """
    b = x.shape[0]
    xn = rmsnorm(x, p["norm"], cfg.norm_eps)
    q, k, v = _qkv(p, cfg, xn)
    pos = jnp.asarray(lengths, jnp.int32)[:, None]
    q = apply_rope(q, pos, cfg.rope_theta, cfg.mrope)
    k = apply_rope(k, pos, cfg.rope_theta, cfg.mrope)
    hk, dh = cfg.n_kv_heads, cfg.head_dim()
    kd, vd = k.astype(pool_rows.dtype), v.astype(pool_rows.dtype)
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if use_kernel:
        from ..kernels.decode_attention.ops import paged_decode_attention
        out = paged_decode_attention(
            q, kd, vd, pool_rows, page_rows, jnp.asarray(lengths, jnp.int32),
            chunk=chunk, k_off=k_off, v_off=v_off, hkv=hk,
            pool_off=pool_off, interpret=interpret)
    else:
        t = page_rows.shape[1] * chunk
        rows_idx = (jnp.asarray(page_rows, jnp.int32)[:, :, None] * chunk
                    + jnp.arange(chunk, dtype=jnp.int32)).reshape(b, t)
        gathered = pool_rows[rows_idx]                # (B, T, token_row)
        lo_k, lo_v = pool_off + k_off, pool_off + v_off
        kc = gathered[..., lo_k:lo_k + hk * dh].reshape(b, t, hk, dh)
        vc = gathered[..., lo_v:lo_v + hk * dh].reshape(b, t, hk, dh)
        slot = jnp.arange(t, dtype=jnp.int32)[None, :] == pos
        kc = jnp.where(slot[..., None, None], kd, kc)
        vc = jnp.where(slot[..., None, None], vd, vc)
        out = decode_attention(q, kc, vc, pos[:, 0] + 1, use_kernel=False)
    y = jnp.einsum("bse,ed->bsd", out.reshape(b, 1, -1), p["wo"])
    return y, kd, vd


def cross_apply(p, cfg, x, enc_kv):
    """Cross-attention against precomputed encoder K/V (no rope)."""
    xn = rmsnorm(x, p["norm"], cfg.norm_eps)
    b, s, d = x.shape
    dh = cfg.head_dim()
    q = jnp.einsum("bsd,de->bse", xn, p["wq"]).reshape(b, s, cfg.n_heads, dh)
    k, v = enc_kv
    out = attend(q, k, v, causal=False)
    return jnp.einsum("bse,ed->bsd", out.reshape(b, s, -1), p["wo"])


def enc_kv_of(p, cfg, enc_out):
    b, se, _ = enc_out.shape
    dh = cfg.head_dim()
    k = jnp.einsum("bsd,de->bse", enc_out, p["wk"]).reshape(
        b, se, cfg.n_kv_heads, dh)
    v = jnp.einsum("bsd,de->bse", enc_out, p["wv"]).reshape(
        b, se, cfg.n_kv_heads, dh)
    return k, v


# ---------------------------------------------------------------------------
# Layer init (family-specific) and parameter assembly
# ---------------------------------------------------------------------------

def _mlp_layer_init(key, cfg, dtype, d_ff):
    k1, k2 = jax.random.split(key)
    p = {"attn": attn_init(k1, cfg, dtype),
         "mlp": swiglu_init(k2, cfg.d_model, d_ff, dtype),
         "mlp_norm": jnp.ones((cfg.d_model,), dtype)}
    return p


def _moe_layer_init(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {"attn": attn_init(k1, cfg, dtype),
            "moe": moe_init(k2, cfg, dtype),
            "mlp_norm": jnp.ones((cfg.d_model,), dtype)}


def _encdec_dec_layer_init(key, cfg, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"attn": attn_init(k1, cfg, dtype),
            "cross": attn_init(k2, cfg, dtype),
            "mlp": swiglu_init(k3, cfg.d_model, cfg.d_ff, dtype),
            "mlp_norm": jnp.ones((cfg.d_model,), dtype)}


def _stack(init_fn, key, n):
    return jax.vmap(init_fn)(jax.random.split(key, n))


def init_params(cfg: ArchConfig, key, dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(key, 8)
    p: Params = {
        "embed": dense_init(ks[0], (cfg.vocab, cfg.d_model), dtype, scale=0.02),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[1], (cfg.d_model, cfg.vocab), dtype)
    fam = cfg.family
    if fam in ("dense", "vlm"):
        p["layers"] = _stack(
            lambda k: _mlp_layer_init(k, cfg, dtype, cfg.d_ff),
            ks[2], cfg.n_layers)
    elif fam == "moe":
        n_moe = cfg.n_layers - cfg.first_dense_layers
        p["layers"] = _stack(lambda k: _moe_layer_init(k, cfg, dtype),
                             ks[2], n_moe)
        if cfg.first_dense_layers:
            dff = cfg.d_ff or cfg.d_ff_expert * max(1, cfg.top_k)
            p["dense_layers"] = _stack(
                lambda k: _mlp_layer_init(k, cfg, dtype, dff),
                ks[3], cfg.first_dense_layers)
    elif fam == "ssm":
        p["layers"] = _stack(lambda k: {"ssm": ssm_init(k, cfg, dtype)},
                             ks[2], cfg.n_layers)
    elif fam == "hybrid":
        n_app = cfg.n_layers // cfg.attn_every
        per = cfg.attn_every
        p["groups"] = jax.vmap(
            lambda kg: _stack(lambda k: {"ssm": ssm_init(k, cfg, dtype)},
                              kg, per))(jax.random.split(ks[2], n_app))
        p["shared"] = _mlp_layer_init(ks[3], cfg, dtype, cfg.d_ff)
    elif fam == "encdec":
        p["enc_layers"] = _stack(
            lambda k: _mlp_layer_init(k, cfg, dtype, cfg.d_ff),
            ks[2], cfg.n_enc_layers)
        p["dec_layers"] = _stack(
            lambda k: _encdec_dec_layer_init(k, cfg, dtype),
            ks[3], cfg.n_layers)
    else:
        raise ValueError(f"unknown family {fam}")
    return p


def abstract_params(cfg: ArchConfig, dtype=jnp.bfloat16):
    """ShapeDtypeStruct pytree -- the dry-run's no-allocation param stand-in."""
    return jax.eval_shape(
        lambda k: init_params(cfg, k, dtype), jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def _dense_layer_fwd(cfg, layer, x, positions):
    from ..dist.annotate import batch_activations
    x = x + attn_apply(layer["attn"], cfg, x, positions)
    xn = rmsnorm(x, layer["mlp_norm"], cfg.norm_eps)
    return batch_activations(x + swiglu(layer["mlp"], xn))


def _moe_layer_fwd(cfg, layer, x, positions):
    from ..dist.annotate import batch_activations
    x = x + attn_apply(layer["attn"], cfg, x, positions)
    xn = rmsnorm(x, layer["mlp_norm"], cfg.norm_eps)
    return batch_activations(x + moe_apply(layer["moe"], cfg, xn))


def _embed(cfg, p, batch):
    x = jnp.take(p["embed"], batch["tokens"], axis=0)
    if cfg.family == "vlm" and "vision_embeds" in batch:
        ve = batch["vision_embeds"].astype(x.dtype)
        x = jnp.concatenate([ve, x[:, ve.shape[1]:]], axis=1)
    # re-anchor the residual stream to batch-over-DP: the vocab/TP-sharded
    # table otherwise propagates feature sharding into every layer
    # (EXPERIMENTS.md section Perf, iteration 1)
    from ..dist.annotate import batch_activations
    return batch_activations(x)


def _head(cfg, p):
    return p["embed"].T if cfg.tie_embeddings else p["lm_head"]


def forward(cfg: ArchConfig, p: Params, batch) -> jnp.ndarray:
    """Full-sequence forward -> final hidden states (B, S, D)."""
    fam = cfg.family
    if fam == "encdec":
        return _encdec_forward(cfg, p, batch)
    x = _embed(cfg, p, batch)
    b, s, _ = x.shape
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    if fam in ("dense", "vlm", "moe"):
        fwd = _dense_layer_fwd if fam != "moe" else _moe_layer_fwd
        if fam == "moe" and cfg.first_dense_layers:
            def dbody(xx, layer):
                return jax.checkpoint(
                    lambda a, l: _dense_layer_fwd(cfg, l, a, positions))(
                        xx, layer), None
            x, _ = jax.lax.scan(dbody, x, p["dense_layers"])

        def body(xx, layer):
            return jax.checkpoint(
                lambda a, l: fwd(cfg, l, a, positions))(xx, layer), None
        x, _ = jax.lax.scan(body, x, p["layers"])
    elif fam == "ssm":
        from ..dist.annotate import batch_activations

        def body(xx, layer):
            def blk(a, l):
                y, _ = ssm_block(l["ssm"], cfg, a)
                return batch_activations(a + y)
            return jax.checkpoint(blk)(xx, layer), None
        x, _ = jax.lax.scan(body, x, p["layers"])
    elif fam == "hybrid":
        from ..dist.annotate import batch_activations
        n_app = cfg.n_layers // cfg.attn_every

        def body(xx, layer):
            def blk(a, l):
                y, _ = ssm_block(l["ssm"], cfg, a)
                return batch_activations(a + y)
            return jax.checkpoint(blk)(xx, layer), None
        for a in range(n_app):
            group = jax.tree.map(lambda t, a=a: t[a], p["groups"])
            x, _ = jax.lax.scan(body, x, group)
            x = jax.checkpoint(
                lambda xx: _dense_layer_fwd(cfg, p["shared"], xx, positions))(x)
    return rmsnorm(x, p["final_norm"], cfg.norm_eps)


def _encdec_forward(cfg, p, batch):
    enc = batch["frames"].astype(p["embed"].dtype)     # stub frontend output
    b, se, _ = enc.shape
    epos = jnp.broadcast_to(jnp.arange(se, dtype=jnp.int32), (b, se))

    def ebody(xx, layer):
        def blk(a, l):
            a = a + attn_apply(l["attn"], cfg, a, epos, causal=False)
            an = rmsnorm(a, l["mlp_norm"], cfg.norm_eps)
            return a + swiglu(l["mlp"], an)
        return jax.checkpoint(blk)(xx, layer), None
    enc, _ = jax.lax.scan(ebody, enc, p["enc_layers"])

    x = jnp.take(p["embed"], batch["tokens"], axis=0)
    sd = x.shape[1]
    dpos = jnp.broadcast_to(jnp.arange(sd, dtype=jnp.int32), (b, sd))

    def dbody(xx, layer):
        def blk(a, l):
            a = a + attn_apply(l["attn"], cfg, a, dpos)
            a = a + cross_apply(l["cross"], cfg, a, enc_kv_of(l["cross"], cfg, enc))
            an = rmsnorm(a, l["mlp_norm"], cfg.norm_eps)
            return a + swiglu(l["mlp"], an)
        return jax.checkpoint(blk)(xx, layer), None
    x, _ = jax.lax.scan(dbody, x, p["dec_layers"])
    return rmsnorm(x, p["final_norm"], cfg.norm_eps)


def loss_fn(cfg: ArchConfig, p: Params, batch) -> jnp.ndarray:
    hidden = forward(cfg, p, batch)
    return chunked_xent(hidden, _head(cfg, p), batch["labels"])


def logits_fn(cfg, p, hidden):
    return jnp.einsum("bsd,dv->bsv", hidden, _head(cfg, p)).astype(jnp.float32)
