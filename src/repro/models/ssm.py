"""Mamba2 / SSD (state-space duality) layer, chunked (arXiv:2405.21060).

Training/prefill uses the SSD chunked algorithm: within-chunk attention-like
quadratic term + inter-chunk state recurrence over chunk boundaries, all as
batched matmuls (MXU-friendly).  Decode keeps an (H, P, N) state plus a
short conv buffer and costs O(1) per token in sequence length -- this is why
mamba2/zamba2 are the archs that run the long_500k cell.

``repro.kernels.ssd_scan`` implements the chunk scan as a Pallas kernel;
:func:`ssd_chunked` is its oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense_init, rmsnorm


def ssm_init(key, cfg, dtype):
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    cw = cfg.conv_width
    ks = jax.random.split(key, 5)
    return {
        # fused input projection -> [x(di), z(di), B(n), C(n), dt(h)]
        "in_proj": dense_init(ks[0], (d, 2 * di + 2 * n + h), dtype),
        "conv_w": dense_init(ks[1], (cw, di + 2 * n), dtype, scale=cw ** -0.5),
        "A_log": jnp.zeros((h,), jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "out_proj": dense_init(ks[2], (di, d), dtype),
        "norm_w": jnp.ones((di,), jnp.float32).astype(dtype),
    }


def _split_proj(cfg, proj):
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    x = proj[..., :di]
    z = proj[..., di:2 * di]
    bc = proj[..., 2 * di:2 * di + 2 * n]
    dt = proj[..., 2 * di + 2 * n:]
    return x, z, bc, dt


def _causal_conv(u, w):
    """Depthwise causal conv: u (B, S, C), w (K, C)."""
    k = w.shape[0]
    up = jnp.pad(u, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(u)
    for i in range(k):      # K is tiny (4); unrolled taps stay fusable
        out = out + up[:, i:i + u.shape[1]] * w[i]
    return out


def ssd_chunked(x, dt, A, B, C, D, chunk: int):
    """SSD chunk scan.

    x: (b, s, h, p); dt: (b, s, h) (softplus-ed); A: (h,) negative;
    B, C: (b, s, n); D: (h,).  Returns (y, final_state (b, h, p, n)).
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[1] // chunk
    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h).astype(jnp.float32)
    Bc = B.reshape(b, nc, chunk, n).astype(jnp.float32)
    Cc = C.reshape(b, nc, chunk, n).astype(jnp.float32)

    da = dtc * A                                   # (b, nc, q, h), negative
    cum = jnp.cumsum(da, axis=2)                   # within-chunk log-decay
    # decay from step j (exclusive) to step i within a chunk:
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (b,nc,i,j,h)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    L = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)

    xdt = xc.astype(jnp.float32) * dtc[..., None]  # (b,nc,q,h,p)
    # intra-chunk (the "attention-like" quadratic term)
    cb = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)     # (b,nc,i,j)
    y_intra = jnp.einsum("bcij,bcijh,bcjhp->bcihp", cb, L, xdt)

    # chunk-boundary states: S_c = sum_j decay(end..j) B_j (x dt)_j
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)          # (b,nc,q,h)
    S = jnp.einsum("bcjn,bcjh,bcjhp->bchpn", Bc, decay_to_end, xdt)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                  # (b,nc,h)

    def scan_fn(carry, inp):
        s_in, (s_chunk, dec) = carry, inp
        s_out = s_in * dec[:, :, None, None] + s_chunk
        return s_out, s_in                                   # emit pre-state

    s0 = jnp.zeros((b, h, p, n), jnp.float32)
    final, s_prev = jax.lax.scan(
        scan_fn, s0, (S.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    s_prev = s_prev.transpose(1, 0, 2, 3, 4)                 # (b,nc,h,p,n)

    # inter-chunk: y_i += C_i . decay(start..i) s_prev
    decay_from_start = jnp.exp(cum)                          # (b,nc,q,h)
    y_inter = jnp.einsum("bcin,bcih,bchpn->bcihp",
                         Cc, decay_from_start, s_prev)
    y = (y_intra + y_inter).reshape(b, nc * chunk, h, p)
    y = y[:, :s] + x[:, :s].astype(jnp.float32) * D[:, None]
    return y, final


def ssd_decode_step(state, x, dt, A, B, C, D):
    """One-token recurrence: state (b,h,p,n); x (b,h,p); dt (b,h);
    B, C: (b, n).  Returns (y (b,h,p), new_state)."""
    da = jnp.exp(dt.astype(jnp.float32) * A)                 # (b,h)
    xdt = x.astype(jnp.float32) * dt[..., None]
    new_state = state * da[:, :, None, None] + jnp.einsum(
        "bhp,bn->bhpn", xdt, B.astype(jnp.float32))
    y = jnp.einsum("bhpn,bn->bhp", new_state, C.astype(jnp.float32))
    return y + x.astype(jnp.float32) * D[:, None], new_state


def ssm_block(p, cfg, x, *, decode_state=None):
    """Full Mamba2 block. x: (B, S, D).

    Prefill/train: returns (out, (ssm_state, conv_tail)).
    Decode (decode_state given): S == 1, uses cached conv tail + state.
    """
    b, s, d = x.shape
    di, n, h, pdim = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    proj = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xi, z, bc_in, dt_raw = _split_proj(cfg, proj)
    conv_in = jnp.concatenate([xi, bc_in], axis=-1)          # (B,S,di+2n)

    if decode_state is None:
        conv = _causal_conv(conv_in, p["conv_w"])
        conv_tail = conv_in[:, -(cfg.conv_width - 1):]
    else:
        ssm_state, conv_buf = decode_state                   # buf (B,K-1,C)
        window = jnp.concatenate([conv_buf, conv_in], axis=1)
        conv = jnp.einsum("bkc,kc->bc", window, p["conv_w"])[:, None]
        conv_tail = window[:, 1:]
    conv = jax.nn.silu(conv.astype(jnp.float32)).astype(x.dtype)
    xs = conv[..., :di].reshape(b, s, h, pdim)
    B_ = conv[..., di:di + n]
    C_ = conv[..., di + n:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    if decode_state is None:
        y, final = ssd_chunked(xs, dt, A, B_, C_, p["D"], cfg.ssm_chunk)
        new_state = (final, conv_tail)
    else:
        y1, final = ssd_decode_step(decode_state[0], xs[:, 0], dt[:, 0],
                                    A, B_[:, 0], C_[:, 0], p["D"])
        y = y1[:, None]
        new_state = (final, conv_tail)

    y = y.reshape(b, s, di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                p["norm_w"], cfg.norm_eps)
    return jnp.einsum("bsd,de->bse", y, p["out_proj"]), new_state
