"""Serving paths: cache init, prefill, and single-token decode per family.

``decode_step`` is the dry-run's ``serve_step``: one new token against a KV /
SSM-state cache of the cell's sequence length.  Caches are stacked on a
leading layer axis and threaded through the layer scan as scan inputs/outputs,
so decode HLO is O(1) in depth like the forward pass.
"""
from __future__ import annotations

from typing import Any, Dict, List, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers import rmsnorm, swiglu
from .moe import moe_apply
from .ssm import ssm_block
from .transformer import (Params, _embed, attn_decode,
                          attn_decode_paged, attn_prefill,
                          attn_prefill_cached, cross_apply, enc_kv_of,
                          logits_fn)

Cache = Dict[str, Any]

# families whose decode KV can live in LeaseEngine pool pages (an SSM state
# is not position-addressable block-wise; moe pages BOTH its cache stacks
# through named pools interleaved in one token row)
PAGED_FAMILIES = ("dense", "vlm", "moe")


class StackSpec(NamedTuple):
    """One paged KV cache stack: which params/cache it belongs to and where
    its segment lives inside the engine's interleaved pool token row."""
    pool: str           # LeaseEngine pool name
    params_key: str     # p[...] stacked layer params
    cache_keys: Tuple[str, str]   # dense-cache (k, v) names for this stack
    n_layers: int       # layers in this stack
    kind: str           # "mlp" | "moe" (the layer body after attention)
    offset: int         # element column offset of the segment in the row
    token_elems: int    # unpadded elements per token (2 * n_layers*hk*dh)


def pool_layout(cfg: ArchConfig) -> List[StackSpec]:
    """Ordered cache stacks of a paged family and their token-row layout.

    The single source of truth shared by the models (static ``k_off`` /
    ``v_off`` per layer), the serving engine (``kv_pools`` construction --
    ``ServingCluster`` asserts the engine computed the same offsets), and
    the differential tests.  Each stack's per-token segment packs all its
    layers' K then all its layers' V and is lane-padded; segments are laid
    out back to back in forward-pass order, so the moe family's leading
    dense stack comes first.
    """
    if cfg.family not in PAGED_FAMILIES:
        raise NotImplementedError(
            f"no paged layout for family {cfg.family!r}")
    hkd = cfg.n_kv_heads * cfg.head_dim()
    if cfg.family == "moe":
        stacks = []
        if cfg.first_dense_layers:
            stacks.append(("dense", "dense_layers", ("dk", "dv"),
                           cfg.first_dense_layers, "mlp"))
        stacks.append(("moe", "layers", ("k", "v"),
                       cfg.n_layers - cfg.first_dense_layers, "moe"))
    else:
        stacks = [("kv", "layers", ("k", "v"), cfg.n_layers, "mlp")]
    from ..kernels.tardis_lease.kernel import LANES
    out, off = [], 0
    for pool, pkey, ckeys, n, kind in stacks:
        te = 2 * n * hkd
        out.append(StackSpec(pool, pkey, ckeys, n, kind, off, te))
        off += -(-te // LANES) * LANES
    return out


def _attn_cache(cfg, n, b, t, dtype):
    hk, dh = cfg.n_kv_heads, cfg.head_dim()
    return (jnp.zeros((n, b, t, hk, dh), dtype),
            jnp.zeros((n, b, t, hk, dh), dtype))


def _ssm_cache(cfg, n, b, dtype):
    h, pd, ns = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    c = cfg.d_inner + 2 * cfg.ssm_state
    return (jnp.zeros((n, b, h, pd, ns), jnp.float32),
            jnp.zeros((n, b, cfg.conv_width - 1, c), dtype))


def init_cache(cfg: ArchConfig, b: int, t: int,
               enc_len: int = 0, dtype=jnp.bfloat16) -> Cache:
    fam = cfg.family
    if fam in ("dense", "vlm"):
        k, v = _attn_cache(cfg, cfg.n_layers, b, t, dtype)
        return {"k": k, "v": v}
    if fam == "moe":
        n_moe = cfg.n_layers - cfg.first_dense_layers
        k, v = _attn_cache(cfg, n_moe, b, t, dtype)
        out = {"k": k, "v": v}
        if cfg.first_dense_layers:
            dk, dv = _attn_cache(cfg, cfg.first_dense_layers, b, t, dtype)
            out.update(dk=dk, dv=dv)
        return out
    if fam == "ssm":
        s, c = _ssm_cache(cfg, cfg.n_layers, b, dtype)
        return {"state": s, "conv": c}
    if fam == "hybrid":
        n_app = cfg.n_layers // cfg.attn_every
        s, c = _ssm_cache(cfg, cfg.n_layers, b, dtype)
        ak, av = _attn_cache(cfg, n_app, b, t, dtype)
        return {"state": s, "conv": c, "ak": ak, "av": av}
    if fam == "encdec":
        k, v = _attn_cache(cfg, cfg.n_layers, b, t, dtype)
        ck, cv = _attn_cache(cfg, cfg.n_layers, b, enc_len, dtype)
        return {"k": k, "v": v, "ck": ck, "cv": cv}
    raise ValueError(fam)


# ---------------------------------------------------------------------------
# Decode step (one token)
# ---------------------------------------------------------------------------

def decode_step(cfg: ArchConfig, p: Params, cache: Cache, tokens,
                cur_idx) -> Tuple[Cache, jnp.ndarray]:
    """tokens: (B, 1) int32; cur_idx: int32 scalar (next cache slot) or a
    (B,) vector for attention-cache families decoding a continuous batch
    (each request at its own position).

    Returns (new_cache, logits (B, 1, V)).
    """
    fam = cfg.family
    x = jnp.take(p["embed"], tokens, axis=0)
    b = x.shape[0]
    new_cache = dict(cache)

    if fam in ("dense", "vlm", "moe"):
        from ..dist.annotate import replicate

        def body(xx, xs):
            layer, kc, vc = xs
            xx = replicate(xx)        # (B,1,D) is tiny: never gather weights
            y, kc, vc = attn_decode(layer["attn"], cfg, xx, kc, vc, cur_idx)
            xx = xx + y
            xn = rmsnorm(xx, layer["mlp_norm"], cfg.norm_eps)
            if fam == "moe":
                xx = xx + moe_apply(layer["moe"], cfg, xn)
            else:
                xx = xx + swiglu(layer["mlp"], xn)
            return xx, (kc, vc)
        if fam == "moe" and cfg.first_dense_layers:
            def dbody(xx, xs):
                layer, kc, vc = xs
                y, kc, vc = attn_decode(layer["attn"], cfg, xx, kc, vc, cur_idx)
                xx = xx + y
                xn = rmsnorm(xx, layer["mlp_norm"], cfg.norm_eps)
                return xx + swiglu(layer["mlp"], xn), (kc, vc)
            x, (dk, dv) = jax.lax.scan(
                dbody, x, (p["dense_layers"], cache["dk"], cache["dv"]))
            new_cache.update(dk=dk, dv=dv)
        x, (k, v) = jax.lax.scan(body, x, (p["layers"], cache["k"], cache["v"]))
        new_cache.update(k=k, v=v)

    elif fam == "ssm":
        def body(xx, xs):
            layer, s, cbuf = xs
            y, (s2, c2) = ssm_block(layer["ssm"], cfg, xx,
                                    decode_state=(s, cbuf))
            return xx + y, (s2, c2)
        x, (s2, c2) = jax.lax.scan(
            body, x, (p["layers"], cache["state"], cache["conv"]))
        new_cache.update(state=s2, conv=c2)

    elif fam == "hybrid":
        n_app = cfg.n_layers // cfg.attn_every
        per = cfg.attn_every
        states, convs, aks, avs = [], [], [], []

        def body(xx, xs):
            layer, s, cbuf = xs
            y, (s2, c2) = ssm_block(layer["ssm"], cfg, xx,
                                    decode_state=(s, cbuf))
            return xx + y, (s2, c2)
        for a in range(n_app):
            group = jax.tree.map(lambda t_, a=a: t_[a], p["groups"])
            sl = jax.lax.dynamic_slice_in_dim(cache["state"], a * per, per)
            cl = jax.lax.dynamic_slice_in_dim(cache["conv"], a * per, per)
            x, (s2, c2) = jax.lax.scan(body, x, (group, sl, cl))
            states.append(s2)
            convs.append(c2)
            y, kc, vc = attn_decode(p["shared"]["attn"], cfg, x,
                                    cache["ak"][a], cache["av"][a], cur_idx)
            x = x + y
            xn = rmsnorm(x, p["shared"]["mlp_norm"], cfg.norm_eps)
            x = x + swiglu(p["shared"]["mlp"], xn)
            aks.append(kc)
            avs.append(vc)
        new_cache.update(state=jnp.concatenate(states),
                         conv=jnp.concatenate(convs),
                         ak=jnp.stack(aks), av=jnp.stack(avs))

    elif fam == "encdec":
        def body(xx, xs):
            layer, kc, vc, ck, cv = xs
            y, kc, vc = attn_decode(layer["attn"], cfg, xx, kc, vc, cur_idx)
            xx = xx + y
            xx = xx + cross_apply(layer["cross"], cfg, xx, (ck, cv))
            xn = rmsnorm(xx, layer["mlp_norm"], cfg.norm_eps)
            return xx + swiglu(layer["mlp"], xn), (kc, vc)
        x, (k, v) = jax.lax.scan(
            body, x, (p["dec_layers"], cache["k"], cache["v"],
                      cache["ck"], cache["cv"]))
        new_cache.update(k=k, v=v)
    else:
        raise ValueError(fam)

    x = rmsnorm(x, p["final_norm"], cfg.norm_eps)
    return new_cache, logits_fn(cfg, p, x)


def decode_step_paged(cfg: ArchConfig, p: Params, pool_rows, page_rows,
                      lengths, tokens, *, chunk: int,
                      interpret: bool = False, use_kernel=None
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One decode step where every KV byte lives in LeaseEngine pool pages.

    ``pool_rows``: the engine pool's (n_blocks*chunk, token_row) view (one
    lane-padded row per token, all layers packed); ``page_rows``: (B, P)
    int32 per-request page tables (entries past a request's pages clamped
    to a valid id -- they are masked by ``lengths``); ``lengths``: (B,)
    int32 tokens already in pages (== the decode position); ``tokens``:
    (B, 1) int32.  Returns (new_pool_rows, logits (B, 1, V)): every
    layer's fresh KV for the new token is accumulated into ONE token row
    and scattered into its page by the ``tardis_lease`` append kernel --
    no host round trip, no dense per-request cache anywhere.

    The layer loop is unrolled (the pool is one shared buffer, not a
    per-layer scan operand); serving configs keep n_layers small, and the
    unrolled body is bit-identical to the scanned dense path.
    """
    if cfg.family not in PAGED_FAMILIES:
        raise NotImplementedError(
            f"paged decode supports attention-cache families, "
            f"not {cfg.family!r}")
    from ..dist.annotate import replicate
    from ..kernels.tardis_lease.kernel import scatter_rows

    x = jnp.take(p["embed"], tokens, axis=0)
    b = x.shape[0]
    hkd = cfg.n_kv_heads * cfg.head_dim()
    lengths = jnp.asarray(lengths, jnp.int32)
    # one token row spanning EVERY cache stack's segment: the moe family's
    # dual stacks accumulate into the same buffer at their pool offsets and
    # land in the page together, in the single scatter below
    row_buf = jnp.zeros((b, pool_rows.shape[1]), pool_rows.dtype)
    for spec in pool_layout(cfg):
        for li in range(spec.n_layers):
            layer = jax.tree.map(lambda t, li=li: t[li], p[spec.params_key])
            if not (cfg.family == "moe" and spec.kind == "mlp"):
                # the dense decode path replicates inside the moe/dense
                # scan bodies but not in moe's leading dense stack --
                # mirror it exactly (replicate is numerically identity)
                x = replicate(x)
            y, kd, vd = attn_decode_paged(
                layer["attn"], cfg, x, pool_rows, page_rows, lengths,
                li * hkd, (spec.n_layers + li) * hkd, pool_off=spec.offset,
                chunk=chunk, interpret=interpret, use_kernel=use_kernel)
            x = x + y
            xn = rmsnorm(x, layer["mlp_norm"], cfg.norm_eps)
            if spec.kind == "moe":
                x = x + moe_apply(layer["moe"], cfg, xn)
            else:
                x = x + swiglu(layer["mlp"], xn)
            k_off = spec.offset + li * hkd
            v_off = spec.offset + (spec.n_layers + li) * hkd
            row_buf = row_buf.at[:, k_off:k_off + hkd].set(
                kd.reshape(b, hkd))
            row_buf = row_buf.at[:, v_off:v_off + hkd].set(
                vd.reshape(b, hkd))
    # ONE append per step: the token's whole row (every stack's, every
    # layer's K and V) lands in its page via the scalar-prefetched scatter
    # kernel
    flat_idx = (page_rows[jnp.arange(b), lengths // chunk] * chunk
                + lengths % chunk)
    pool_rows = scatter_rows(pool_rows, flat_idx, row_buf,
                             interpret=interpret)
    x = rmsnorm(x, p["final_norm"], cfg.norm_eps)
    return pool_rows, logits_fn(cfg, p, x)


# ---------------------------------------------------------------------------
# Prefill: full forward that also materializes the caches
# ---------------------------------------------------------------------------

def _last_logits(cfg, p, x, last_idx):
    """Logits at the prompt's true last position: ``last_idx=None`` keeps
    the trailing position (the unpadded case); a traced index lets callers
    right-pad prompts to a shape bucket (bounding retraces) and still read
    the real last token -- causality makes positions < last_idx identical
    bits either way."""
    if last_idx is None:
        return logits_fn(cfg, p, x[:, -1:])
    return logits_fn(cfg, p, jax.lax.dynamic_slice_in_dim(
        x, jnp.asarray(last_idx, jnp.int32), 1, 1))


def prefill(cfg: ArchConfig, p: Params, batch, cache_len: int,
            dtype=jnp.bfloat16, last_idx=None) -> Tuple[Cache, jnp.ndarray]:
    """Processes the prompt, returns (cache, last-token logits)."""
    fam = cfg.family
    if fam == "encdec":
        return _encdec_prefill(cfg, p, batch, cache_len)
    x = _embed(cfg, p, batch)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    cache: Cache = {}

    if fam in ("dense", "vlm", "moe"):
        def body(xx, layer):
            y, (kc, vc) = attn_prefill(layer["attn"], cfg, xx, positions,
                                       cache_len)
            xx = xx + y
            xn = rmsnorm(xx, layer["mlp_norm"], cfg.norm_eps)
            if fam == "moe":
                xx = xx + moe_apply(layer["moe"], cfg, xn)
            else:
                xx = xx + swiglu(layer["mlp"], xn)
            return xx, (kc.astype(dtype), vc.astype(dtype))
        if fam == "moe" and cfg.first_dense_layers:
            def dbody(xx, layer):
                y, (kc, vc) = attn_prefill(layer["attn"], cfg, xx, positions,
                                           cache_len)
                xx = xx + y
                xn = rmsnorm(xx, layer["mlp_norm"], cfg.norm_eps)
                return xx + swiglu(layer["mlp"], xn), (kc.astype(dtype),
                                                       vc.astype(dtype))
            x, (dk, dv) = jax.lax.scan(dbody, x, p["dense_layers"])
            cache.update(dk=dk, dv=dv)
        x, (k, v) = jax.lax.scan(body, x, p["layers"])
        cache.update(k=k, v=v)
    elif fam == "ssm":
        def body(xx, layer):
            y, (st, cv) = ssm_block(layer["ssm"], cfg, xx)
            return xx + y, (st, cv.astype(dtype))
        x, (st, cv) = jax.lax.scan(body, x, p["layers"])
        cache.update(state=st, conv=cv)
    elif fam == "hybrid":
        n_app = cfg.n_layers // cfg.attn_every
        states, convs, aks, avs = [], [], [], []

        def body(xx, layer):
            y, (st, cv) = ssm_block(layer["ssm"], cfg, xx)
            return xx + y, (st, cv.astype(dtype))
        for a in range(n_app):
            group = jax.tree.map(lambda t_, a=a: t_[a], p["groups"])
            x, (st, cv) = jax.lax.scan(body, x, group)
            states.append(st)
            convs.append(cv)
            y, (kc, vc) = attn_prefill(p["shared"]["attn"], cfg, x,
                                       positions, cache_len)
            x = x + y
            xn = rmsnorm(x, p["shared"]["mlp_norm"], cfg.norm_eps)
            x = x + swiglu(p["shared"]["mlp"], xn)
            aks.append(kc.astype(dtype))
            avs.append(vc.astype(dtype))
        cache.update(state=jnp.concatenate(states),
                     conv=jnp.concatenate(convs),
                     ak=jnp.stack(aks), av=jnp.stack(avs))
    x = rmsnorm(x, p["final_norm"], cfg.norm_eps)
    return cache, _last_logits(cfg, p, x, last_idx)


def prefill_suffix(cfg: ArchConfig, p: Params, batch, cache: Cache,
                   prefix_len: int, last_idx=None) -> Tuple[Cache,
                                                            jnp.ndarray]:
    """Chunked prefill that skips the prompt's leased prefix.

    ``cache`` arrives with its first ``prefix_len`` slots already holding
    the prefix KV (materialized from the serving engine's paged pool);
    ``batch["tokens"]`` carries only the suffix.  Each suffix query attends
    over [leased prefix KV; its own causal suffix KV], so the prefix's
    attention + MLP/MoE flops are skipped entirely.  Attention-cache
    families only (an SSM state is not position-addressable block-wise);
    the moe family runs its leading dense stack and its moe stack through
    the same cached-prefill attention, each against its own cache stack.
    """
    fam = cfg.family
    if fam not in PAGED_FAMILIES:
        raise NotImplementedError(
            f"prefix-KV suffix prefill supports attention-cache families, "
            f"not {fam!r}")
    x = _embed(cfg, p, batch)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(
        prefix_len + jnp.arange(s, dtype=jnp.int32), (b, s))

    def make_body(kind):
        def body(xx, xs):
            layer, kc, vc = xs
            y, kc, vc = attn_prefill_cached(layer["attn"], cfg, xx,
                                            positions, kc, vc, prefix_len)
            xx = xx + y
            xn = rmsnorm(xx, layer["mlp_norm"], cfg.norm_eps)
            if kind == "moe":
                xx = xx + moe_apply(layer["moe"], cfg, xn)
            else:
                xx = xx + swiglu(layer["mlp"], xn)
            return xx, (kc, vc)
        return body

    out: Cache = {}
    for spec in pool_layout(cfg):
        ck, cv = spec.cache_keys
        x, (k, v) = jax.lax.scan(make_body(spec.kind), x,
                                 (p[spec.params_key], cache[ck], cache[cv]))
        out[ck], out[cv] = k, v
    x = rmsnorm(x, p["final_norm"], cfg.norm_eps)
    return out, _last_logits(cfg, p, x, last_idx)


def _encdec_prefill(cfg, p, batch, cache_len, dtype=jnp.bfloat16):
    enc = batch["frames"].astype(p["embed"].dtype)
    b, se, _ = enc.shape
    epos = jnp.broadcast_to(jnp.arange(se, dtype=jnp.int32), (b, se))

    def ebody(xx, layer):
        from .transformer import attn_apply
        xx = xx + attn_apply(layer["attn"], cfg, xx, epos, causal=False)
        xn = rmsnorm(xx, layer["mlp_norm"], cfg.norm_eps)
        return xx + swiglu(layer["mlp"], xn), None
    enc, _ = jax.lax.scan(ebody, enc, p["enc_layers"])

    x = jnp.take(p["embed"], batch["tokens"], axis=0)
    sd = x.shape[1]
    dpos = jnp.broadcast_to(jnp.arange(sd, dtype=jnp.int32), (b, sd))

    def dbody(xx, layer):
        y, (kc, vc) = attn_prefill(layer["attn"], cfg, xx, dpos, cache_len)
        xx = xx + y
        ck, cv = enc_kv_of(layer["cross"], cfg, enc)
        xx = xx + cross_apply(layer["cross"], cfg, xx, (ck, cv))
        xn = rmsnorm(xx, layer["mlp_norm"], cfg.norm_eps)
        return xx + swiglu(layer["mlp"], xn), (
            kc.astype(dtype), vc.astype(dtype),
            ck.astype(dtype), cv.astype(dtype))
    x, (k, v, ck, cv) = jax.lax.scan(dbody, x, p["dec_layers"])
    x = rmsnorm(x, p["final_norm"], cfg.norm_eps)
    return {"k": k, "v": v, "ck": ck, "cv": cv}, logits_fn(cfg, p, x[:, -1:])
