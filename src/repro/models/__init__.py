"""Model zoo: every assigned architecture family as pure-functional JAX."""
from .transformer import (abstract_params, forward, init_params, logits_fn,
                          loss_fn)
from .decoding import (PAGED_FAMILIES, StackSpec, decode_step,
                       decode_step_paged, init_cache, pool_layout, prefill,
                       prefill_suffix)

__all__ = ["abstract_params", "forward", "init_params", "logits_fn",
           "loss_fn", "decode_step", "decode_step_paged", "PAGED_FAMILIES",
           "StackSpec", "pool_layout", "init_cache", "prefill",
           "prefill_suffix"]
