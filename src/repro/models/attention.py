"""GQA attention: blocked-flash training/prefill path + cached decode path.

The train/prefill path is a pure-jnp flash attention (outer scan over query
blocks, inner scan over KV blocks with an online softmax) so peak memory is
O(block_q x block_k) per head instead of O(S^2) -- mandatory for the 32k
prefill dry-run cells.  The inner body is rematerialized, so the backward
pass recomputes scores blockwise too.  ``repro.kernels.flash_attention``
implements the same schedule as a Pallas TPU kernel; this module is its
numerics oracle and the default XLA path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _pad_to(x, mult, axis):
    s = x.shape[axis]
    pad = (-s) % mult
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


def flash_attention(q, k, v, *, causal: bool, q_offset=0,
                    window: int = 0, block_q: int = 512,
                    block_k: int = 1024, kv_len=None):
    """q: (B, Sq, H, Dh); k, v: (B, Skv, Hkv, Dh); returns (B, Sq, H, Dh).

    ``q_offset`` positions queries at kv index ``q_offset + i`` (decode /
    chunked prefill).  ``kv_len`` masks out cache slots >= kv_len.
    ``window > 0`` restricts attention to the last ``window`` kv positions.
    """
    b, sq, h, dh = q.shape
    _, skv, hkv, _ = k.shape
    g = h // hkv
    scale = dh ** -0.5

    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    q, pq = _pad_to(q, block_q, 1)
    k, pk = _pad_to(k, block_k, 1)
    v, _ = _pad_to(v, block_k, 1)
    nq, nk = q.shape[1] // block_q, k.shape[1] // block_k

    qb = q.reshape(b, nq, block_q, hkv, g, dh).transpose(1, 0, 2, 3, 4, 5)
    kb = k.reshape(b, nk, block_k, hkv, dh).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nk, block_k, hkv, dh).transpose(1, 0, 2, 3, 4)
    limit = jnp.asarray(kv_len if kv_len is not None else skv, jnp.int32)

    def one_q_block(iq, qi):
        qpos = q_offset + iq * block_q + jnp.arange(block_q)

        @jax.checkpoint
        def kv_step(carry, xs):
            m, l, acc = carry
            ik, kj, vj = xs
            kpos = ik * block_k + jnp.arange(block_k)
            s = jnp.einsum("bqkgd,btkd->bqkgt", qi.astype(jnp.float32),
                           kj.astype(jnp.float32)) * scale
            mask = kpos[None, :] < limit
            if causal:
                mask &= qpos[:, None] >= kpos[None, :]
            if window:
                mask &= kpos[None, :] > (qpos[:, None] - window)
            s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bqkgt,btkd->bqkgd", p, vj.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, block_q, hkv, g), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, block_q, hkv, g), jnp.float32)
        a0 = jnp.zeros((b, block_q, hkv, g, dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nk), kb, vb))
        return acc / jnp.maximum(l, 1e-30)[..., None]

    out = jax.lax.map(lambda xs: one_q_block(*xs), (jnp.arange(nq), qb))
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(b, nq * block_q, h, dh)
    return out[:, :sq].astype(q.dtype)


def reference_attention(q, k, v, *, causal: bool, q_offset=0,
                        window: int = 0, kv_len=None):
    """Naive masked attention -- test oracle and small-shape path.

    ``kv_len`` may be a scalar or a per-request (B,) vector (continuous
    batching: each request's cache fill differs).
    """
    b, sq, h, dh = q.shape
    _, skv, hkv, _ = k.shape
    g = h // hkv
    qg = q.reshape(b, sq, hkv, g, dh)
    s = jnp.einsum("bqkgd,btkd->bqkgt", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * dh ** -0.5
    qpos = q_offset + jnp.arange(sq)
    kpos = jnp.arange(skv)
    mask = jnp.ones((1, sq, skv), bool)
    if kv_len is not None:
        lim = jnp.asarray(kv_len, jnp.int32).reshape(-1, 1, 1)  # () or (B,)
        mask &= kpos[None, None, :] < lim
    if causal:
        mask &= (qpos[:, None] >= kpos[None, :])[None]
    if window:
        mask &= (kpos[None, :] > (qpos[:, None] - window))[None]
    s = jnp.where(mask[:, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqkgt,btkd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(b, sq, h, dh).astype(q.dtype)


# Flash-decode kernel routing (``repro.kernels.decode_attention``): eligible
# GQA shapes go through the Pallas split-KV kernel -- compiled on TPU,
# interpret-mode fallback elsewhere.  The dense einsum below remains the
# reference (and the default for small caches, where one fused einsum beats
# a kernel launch and tests stay pinned to the oracle's exact bits).
DECODE_KERNEL_MIN_T = 2048


def _kernel_eligible(q, k_cache, cur_len, min_t: int) -> bool:
    b, sq, h, dh = q.shape
    t, hkv = k_cache.shape[1], k_cache.shape[2]
    if sq != 1 or h % hkv or t < min_t:
        return False
    if jnp.ndim(cur_len) != 0:         # per-request lengths: paged path only
        return False
    if t % min(512, t):                # kernel block size must tile the cache
        return False
    # auto-route only where the kernel compiles (TPU); off-TPU callers can
    # still force use_kernel=True and get the interpret-mode fallback
    return jax.default_backend() == "tpu"


def decode_attention(q, k_cache, v_cache, cur_len, *, use_kernel=None,
                     min_t: int = DECODE_KERNEL_MIN_T):
    """Single-token attention over a cache: q (B, 1, H, Dh),
    caches (B, T, Hkv, Dh), cur_len = valid cache slots (scalar or (B,)).

    ``use_kernel=None`` routes eligible GQA shapes (long caches) through
    the Pallas flash-decode kernel; True forces it; False forces the
    reference einsum.
    """
    if use_kernel is None:
        use_kernel = _kernel_eligible(q, k_cache, cur_len, min_t)
    if use_kernel:
        from ..kernels.decode_attention.ops import \
            decode_attention as decode_kernel
        return decode_kernel(q, k_cache, v_cache, cur_len,
                             interpret=jax.default_backend() != "tpu")
    return reference_attention(q, k_cache, v_cache, causal=False,
                               kv_len=cur_len)


def attend(q, k, v, *, causal: bool, q_offset=0, window: int = 0,
           kv_len=None, flash_threshold: int = 1024):
    """Dispatch: naive for short sequences (smoke tests), flash otherwise."""
    if q.shape[1] * k.shape[1] <= flash_threshold ** 2:
        return reference_attention(q, k, v, causal=causal, q_offset=q_offset,
                                   window=window, kv_len=kv_len)
    return flash_attention(q, k, v, causal=causal, q_offset=q_offset,
                           window=window, kv_len=kv_len)
