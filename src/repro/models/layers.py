"""Shared neural building blocks (pure functions over param pytrees).

Conventions:
  * params are nested dicts of jnp arrays; layer stacks carry a leading L dim
    so the transformer scans over layers (O(1) HLO size in depth),
  * math that is precision-sensitive (norms, softmax, loss) runs in fp32,
  * every init function is usable under ``jax.eval_shape`` for the dry-run
    (no host randomness at trace time).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def rmsnorm(x, w, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * w.astype(jnp.float32)).astype(x.dtype)


def dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def swiglu_init(key, d_model: int, d_ff: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d_model, d_ff), dtype),
        "w_up": dense_init(k2, (d_model, d_ff), dtype),
        "w_down": dense_init(k3, (d_ff, d_model), dtype),
    }


def swiglu(p, x):
    g = jnp.einsum("...d,df->...f", x, p["w_gate"])
    u = jnp.einsum("...d,df->...f", x, p["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, p["w_down"])


# ---------------------------------------------------------------------------
# Rotary embeddings (standard RoPE + 3-section M-RoPE for the VLM backbone)
# ---------------------------------------------------------------------------

def rope_freqs(d_head: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, jnp.float32) / d_head))


def apply_rope(x, positions, theta: float, mrope: bool = False):
    """x: (..., S, H, Dh); positions: (..., S) int32 or (..., S, 3) for M-RoPE.

    M-RoPE splits the rotary dims into 3 sections (temporal/height/width);
    when only text positions are given they are broadcast to all sections
    (exactly Qwen2-VL's behaviour on pure-text inputs).
    """
    d_head = x.shape[-1]
    freqs = rope_freqs(d_head, theta)                      # (Dh/2,)
    if mrope:
        if positions.ndim == x.ndim - 2:                   # text-only: (..., S)
            positions = jnp.broadcast_to(
                positions[..., None], positions.shape + (3,))
        nf = freqs.shape[0]
        sec = [nf - 2 * (nf // 3), nf // 3, nf // 3]
        sel = jnp.repeat(jnp.arange(3), jnp.asarray(sec),
                         total_repeat_length=nf)           # (Dh/2,) section id
        pos = jnp.take_along_axis(
            positions.astype(jnp.float32),
            jnp.broadcast_to(sel, positions.shape[:-1] + (nf,)).astype(jnp.int32),
            axis=-1)                                       # (..., S, Dh/2)
        angles = pos * freqs
    else:
        angles = positions.astype(jnp.float32)[..., None] * freqs
    cos = jnp.cos(angles)[..., None, :]                    # (..., S, 1, Dh/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Chunked softmax cross-entropy: never materializes (B, S, V) logits
# ---------------------------------------------------------------------------

def chunked_xent(hidden, w_head, labels, chunk: int = 512):
    """Mean token cross-entropy, scanned over sequence chunks.

    hidden: (B, S, D); w_head: (D, V); labels: (B, S) int32 (-1 = masked).
    The per-chunk body is rematerialized so the backward pass also never
    holds more than one chunk of logits.
    """
    b, s, d = hidden.shape
    chunk = min(chunk, s)
    n = s // chunk
    hc = hidden[:, :n * chunk].reshape(b, n, chunk, d).swapaxes(0, 1)
    lc = labels[:, :n * chunk].reshape(b, n, chunk).swapaxes(0, 1)

    @jax.checkpoint
    def body(carry, xs):
        h, lab = xs
        logits = jnp.einsum("bcd,dv->bcv", h, w_head).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lab, 0)[..., None], axis=-1)[..., 0]
        mask = (lab >= 0).astype(jnp.float32)
        loss = jnp.sum((lse - gold) * mask)
        cnt = jnp.sum(mask)
        return (carry[0] + loss, carry[1] + cnt), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)),
                                 (hc, lc))
    return tot / jnp.maximum(cnt, 1.0)
