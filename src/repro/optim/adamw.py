"""AdamW with fp32 moments, decoupled weight decay, and global-norm clipping.

Moments are stored fp32 regardless of parameter dtype (ZeRO-sharded by
``repro.dist.sharding.opt_shardings``).  Parameters update in their own dtype
(bf16 weights + fp32 moments; no separate master copy -- documented memory
trade-off in DESIGN.md).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp


def init(params) -> Dict[str, Any]:
    def zeros(p):
        return jnp.zeros(p.shape, jnp.float32)

    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def abstract_init(params):
    return jax.eval_shape(init, params)


def global_norm(tree) -> jnp.ndarray:
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def update(params, grads, state, *, lr, b1: float = 0.9, b2: float = 0.95,
           eps: float = 1e-8, weight_decay: float = 0.1,
           max_grad_norm: float = 1.0):
    """Returns (new_params, new_state, metrics)."""
    grads32, gnorm = clip_by_global_norm(grads, max_grad_norm)
    step = state["step"] + 1
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        mh = m2 / bc1
        vh = v2 / bc2
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * delta
        return p2.astype(p.dtype), m2, v2

    flat = jax.tree.map(upd, params, grads32, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, \
        {"grad_norm": gnorm}


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr_fn(step):
        s = step.astype(jnp.float32)
        warm = base_lr * s / max(1, warmup)
        prog = jnp.clip((s - warmup) / max(1, total - warmup), 0.0, 1.0)
        cos = base_lr * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < warmup, warm, cos)
    return lr_fn
