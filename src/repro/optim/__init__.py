from . import adamw
from .adamw import clip_by_global_norm, cosine_schedule, global_norm

__all__ = ["adamw", "clip_by_global_norm", "cosine_schedule", "global_norm"]
