"""TardisStore: lease-coherent distributed object store (the framework layer).

This is the paper's protocol applied where a DSM protocol lives in an ML
system: coherence of *runtime objects* -- parameter versions, paged KV-cache
blocks, router/balance tables -- shared by many replicas:

  * readers take time-bounded leases (wts/rts per block, O(log N) metadata;
    no sharer lists anywhere),
  * a writer never broadcasts invalidations: it jumps ahead of every
    outstanding lease (``pts' = max(pts, rts+1)``) and publishes the new
    version instantly,
  * an expired reader *renews*; if its cached version still matches the
    manager's wts the renewal is data-less (RENEW_REP) -- for multi-GB
    parameter shards this is the difference between a header RPC and a full
    retransfer,
  * livelock is avoided exactly as in the paper: replicas self-increment
    their pts every ``selfinc_period`` operations.

Block-table metadata lives in :class:`repro.core.lease_engine.LeaseEngine`
(the ``tardis_lease`` Pallas kernel executes the transitions on device);
:class:`BlockTable` below is a thin adapter over it.  The store tracks the
same message statistics the simulator does -- including per-message flits
from :data:`repro.core.protocol.MESSAGE_FLITS` -- so the serving/elastic
examples can report renewal/traffic savings vs. a directory-style
invalidation broadcast.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Dict, Optional, Tuple

import numpy as np

from . import protocol
from .lease_engine import LeaseEngine


@dataclasses.dataclass
class StoreStats:
    reads: int = 0
    writes: int = 0
    renews: int = 0
    renew_data_less: int = 0
    payload_transfers: int = 0
    bytes_transferred: int = 0
    flits: int = 0                 # message flits incl. headers (SH_REQ/...)
    # what a full-map directory would have done for the same op stream
    dir_invalidations: int = 0
    dir_sharer_bits: int = 0

    @property
    def wire_bytes(self) -> int:
        """On-wire bytes including metadata headers (128-bit flits)."""
        return self.flits * protocol.FLIT_BYTES


class TardisStore:
    """Timestamp manager for a keyed set of versioned objects."""

    def __init__(self, lease: int = 10):
        self.lease = int(lease)
        self._lock = threading.Lock()
        self._wts: Dict[str, int] = {}
        self._rts: Dict[str, int] = {}
        self._val: Dict[str, Any] = {}
        self._nbytes: Dict[str, int] = {}
        # directory-comparison accounting only (Tardis never stores this):
        self._sharers: Dict[str, set] = {}
        self.stats = StoreStats()

    # -- manager-side protocol ops -----------------------------------------

    def publish(self, key: str, value: Any, pts: int, nbytes: int = 0) -> int:
        """Store: jump ahead of every lease (Table I store rule).

        Returns the writer's new pts.  No invalidation is sent; existing
        readers keep using their leased (older) versions legally.
        """
        with self._lock:
            rts = self._rts.get(key, 0)
            ts = max(pts, rts + 1)
            self._wts[key] = ts
            self._rts[key] = ts
            self._val[key] = value
            self._nbytes[key] = int(nbytes)
            self.stats.writes += 1
            # publish: EX_REQ header/ts flits + the new version's payload.
            self.stats.flits += (protocol.MESSAGE_FLITS["EX_REQ"]
                                 + protocol.data_flits(nbytes))
            # directory bookkeeping for comparison
            self.stats.dir_invalidations += len(self._sharers.get(key, ()))
            self._sharers[key] = set()
            return ts

    def acquire(self, key: str, pts: int, have_wts: Optional[int] = None,
                reader: str = "") -> Tuple[Any, int, int, bool]:
        """Load / renew: returns (value_or_None, wts, rts_lease, data_less).

        ``have_wts`` is the reader's cached version; when it matches, the
        renewal succeeds without a payload (value None, data_less=True).
        """
        with self._lock:
            if key not in self._wts:
                raise KeyError(key)
            wts = self._wts[key]
            new_rts = max(self._rts[key], wts + self.lease, pts + self.lease)
            self._rts[key] = new_rts
            self.stats.reads += 1
            self.stats.flits += protocol.MESSAGE_FLITS["SH_REQ"]
            self._sharers.setdefault(key, set()).add(reader)
            self.stats.dir_sharer_bits = max(
                self.stats.dir_sharer_bits,
                sum(len(s) for s in self._sharers.values()))
            if have_wts is not None:
                self.stats.renews += 1
                if have_wts == wts:
                    self.stats.renew_data_less += 1
                    self.stats.flits += protocol.MESSAGE_FLITS["RENEW_REP"]
                    return None, wts, new_rts, True
            nbytes = self._nbytes.get(key, 0)
            self.stats.payload_transfers += 1
            self.stats.bytes_transferred += nbytes
            # SH_REP: header + timestamp flits, plus the object payload.
            self.stats.flits += (protocol.MESSAGE_FLITS["RENEW_REP"]
                                 + protocol.data_flits(nbytes))
            return self._val[key], wts, new_rts, False

    def versions(self) -> Dict[str, int]:
        with self._lock:
            return dict(self._wts)


class Replica:
    """A reader node: private lease cache + program timestamp.

    Mirrors the paper's private cache: reads hit locally while the lease
    covers ``pts``; expiry triggers a renewal (usually data-less); the
    replica's pts self-increments every ``selfinc_period`` local ops so
    remote updates become visible in bounded logical time.
    """

    def __init__(self, store: TardisStore, name: str = "",
                 selfinc_period: int = 100):
        self.store = store
        self.name = name
        self.pts = 1
        self.selfinc_period = int(selfinc_period)
        self._ops = 0
        self._cache: Dict[str, Tuple[Any, int, int]] = {}  # key -> (v, wts, rts)
        self.local_hits = 0
        self.renewals = 0
        self.refetches = 0

    def _tick(self):
        self._ops += 1
        if self._ops % self.selfinc_period == 0:
            self.pts += 1

    def read(self, key: str) -> Any:
        self._tick()
        ent = self._cache.get(key)
        if ent is not None:
            val, wts, rts = ent
            if self.pts <= rts:                      # unexpired lease: hit
                self.pts = max(self.pts, wts)
                self.local_hits += 1
                return val
            # expired: renew (data-less when version unchanged)
            self.renewals += 1
            nv, nwts, nrts, data_less = self.store.acquire(
                key, self.pts, have_wts=wts, reader=self.name)
            if data_less:
                self._cache[key] = (val, nwts, nrts)
                self.pts = max(self.pts, nwts)
                return val
            self.refetches += 1
            self._cache[key] = (nv, nwts, nrts)
            self.pts = max(self.pts, nwts)
            return nv
        nv, wts, rts, _ = self.store.acquire(key, self.pts, reader=self.name)
        self.refetches += 1
        self._cache[key] = (nv, wts, rts)
        self.pts = max(self.pts, wts)
        return nv

    def write(self, key: str, value: Any, nbytes: int = 0) -> None:
        self._tick()
        self.pts = self.store.publish(key, value, self.pts, nbytes)
        self._cache[key] = (value, self.pts, self.pts)

    def cached_version(self, key: str) -> Optional[int]:
        """The wts of this replica's cached copy (None when not cached)."""
        ent = self._cache.get(key)
        return ent[1] if ent is not None else None


class BlockTable:
    """Vectorized lease metadata for paged KV blocks.

    Thin adapter over :class:`repro.core.lease_engine.LeaseEngine`: the
    Pallas ``tardis_lease`` kernel is the single source of truth for the
    Table I-III transitions; pass ``backend="numpy"`` to run the engine's
    host mirror instead (kept for differential tests).
    """

    def __init__(self, n_blocks: int, lease: int = 64, *,
                 backend: str = "pallas", kv_block_shape=None):
        self.engine = LeaseEngine(n_blocks, lease=lease, backend=backend,
                                  kv_block_shape=kv_block_shape)
        self.lease = int(lease)

    @property
    def wts(self) -> np.ndarray:
        return self.engine.wts

    @property
    def rts(self) -> np.ndarray:
        return self.engine.rts

    def read_blocks(self, idx: np.ndarray, pts: int) -> Tuple[np.ndarray, int]:
        """Lease-extend a batch of blocks; returns (expired_mask, new_pts)."""
        res = self.engine.read(idx, pts)
        return res.expired, res.new_pts

    def write_blocks(self, idx: np.ndarray, pts: int) -> int:
        """Writer jump-ahead over every block in ``idx``."""
        return self.engine.write(idx, pts)

    def read_blocks_many(self, groups, pts: int) -> Tuple[np.ndarray, int]:
        """Per-wave batched form: G overlapping groups, one kernel dispatch.
        Returns (per-group expired flags over the union, the wave's pts)."""
        res = self.engine.read_many(groups, pts)
        return res.expired, int(res.new_pts.max(initial=pts))

    def write_blocks_many(self, groups, pts: int) -> int:
        """One jump-ahead over the union of the groups' blocks."""
        return self.engine.write_many(groups, pts)
