"""Synthetic SPLASH-2-like memory traces for the coherence simulator.

SPLASH-2 itself cannot run in this environment, so each generator below
synthesizes the *sharing pattern* that dominates the corresponding paper
benchmark (phase transposes for FFT, migratory lock-protected records for
WATER-NSQ, task-queue spinning for CHOLESKY/VOLREND, ...).  EXPERIMENTS.md
documents the mapping and which paper claim each trace exercises.

Op encoding (int32 arrays of shape (n_cores, trace_len)):
  op_type : 0=load 1=store 2=spin_until 3=barrier 4=end(padding)
  op_addr : cache-line granular address
  op_aux  : spin target version (type 2) / barrier id (type 3)
  op_think: compute cycles consumed before the op issues

Determinism: ticket locks are pre-scheduled (acquisition k of lock l is
assigned to a fixed core), so a `spin_until(lock, k)` + `store(lock)` pair
models acquire/release exactly, and the global outcome is reproducible.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict

import numpy as np

LOAD, STORE, SPIN, BARRIER, END = 0, 1, 2, 3, 4


@dataclasses.dataclass
class Trace:
    """A complete multi-core trace plus its address-space size."""
    op_type: np.ndarray
    op_addr: np.ndarray
    op_aux: np.ndarray
    op_think: np.ndarray
    n_addr: int
    name: str = ""

    @property
    def n_cores(self) -> int:
        return self.op_type.shape[0]

    @property
    def length(self) -> int:
        return self.op_type.shape[1]

    def total_ops(self) -> int:
        return int((self.op_type != END).sum())


class _Builder:
    """Per-core op-list builder that pads to a rectangular trace."""

    def __init__(self, n_cores: int):
        self.n = n_cores
        self.ops = [[] for _ in range(n_cores)]
        self._n_barriers = 0
        self._lock_counts: Dict[int, int] = {}

    def load(self, c, addr, think=0):
        self.ops[c].append((LOAD, addr, 0, think))

    def store(self, c, addr, think=0):
        self.ops[c].append((STORE, addr, 0, think))

    def barrier(self, cores=None):
        bid = self._n_barriers
        self._n_barriers += 1
        for c in (cores if cores is not None else range(self.n)):
            self.ops[c].append((BARRIER, 0, bid, 0))

    def lock_acquire(self, c, lock_addr, think=0):
        """Pre-scheduled ticket acquire: spin until `k` prior releases."""
        k = self._lock_counts.get(lock_addr, 0)
        self._lock_counts[lock_addr] = k + 1
        self.ops[c].append((SPIN, lock_addr, k, think))

    def lock_release(self, c, lock_addr):
        self.ops[c].append((STORE, lock_addr, 0, 0))

    def rmw(self, c, addr, think=0):
        """Uncontended lock / atomic: load+store pair (migratory traffic
        without a pre-scheduled spin -- models locks whose arrival order is
        not serialization-critical, avoiding false trace dependencies)."""
        self.ops[c].append((LOAD, addr, 0, think))
        self.ops[c].append((STORE, addr, 0, 0))

    def build(self, n_addr: int, name: str) -> Trace:
        length = max(len(o) for o in self.ops) + 1   # +1: END sentinel column
        t = np.full((self.n, length), END, np.int32)
        a = np.zeros((self.n, length), np.int32)
        x = np.zeros((self.n, length), np.int32)
        k = np.zeros((self.n, length), np.int32)
        for c, lst in enumerate(self.ops):
            for j, (ty, ad, au, th) in enumerate(lst):
                t[c, j], a[c, j], x[c, j], k[c, j] = ty, ad, au, th
        return Trace(t, a, x, k, n_addr, name)


def _zipf_idx(rng, n, size, a=1.2):
    z = rng.zipf(a, size=size)
    return np.minimum(z - 1, n - 1).astype(np.int64)


# ---------------------------------------------------------------------------
# Generators.  `scale` multiplies per-core op counts (1.0 = benchmark size).
# ---------------------------------------------------------------------------

def gen_fft(n_cores, seed=0, scale=1.0):
    """Phase-parallel all-to-all transpose: little steady-state sharing, most
    pts advance comes from self-increment (paper Table VI: 88.5%)."""
    rng = np.random.default_rng(seed)
    b = _Builder(n_cores)
    part = 64                      # lines per core per phase
    phases = max(2, int(6 * scale))
    base = 0
    for p in range(phases):
        for c in range(n_cores):
            own = base + c * part
            for i in range(part // 2):
                b.load(c, own + rng.integers(part), think=3)
                b.store(c, own + rng.integers(part), think=3)
        b.barrier()
        # transpose read: core c reads lines owned by (c+p+1)%N last phase
        for c in range(n_cores):
            src = base + ((c + p + 1) % n_cores) * part
            for i in range(part // 4):
                b.load(c, src + rng.integers(part), think=2)
        b.barrier()
    return b.build(n_cores * part + 8, "fft")


def gen_radix(n_cores, seed=0, scale=1.0):
    """Scattered permutation writes into a global array + histogram reads."""
    rng = np.random.default_rng(seed + 1)
    b = _Builder(n_cores)
    glob = 2048
    priv = 32
    phases = max(2, int(4 * scale))
    for p in range(phases):
        for c in range(n_cores):
            pbase = glob + c * priv
            for i in range(24):
                b.load(c, pbase + rng.integers(priv), think=2)
                b.store(c, int(rng.integers(glob)), think=4)
        b.barrier()
        for c in range(n_cores):
            for i in range(16):
                b.load(c, int(rng.integers(glob)), think=2)
        b.barrier()
    return b.build(glob + n_cores * priv + 8, "radix")


def gen_lu(n_cores, seed=0, scale=1.0, contiguous=True):
    """Panel factorization: one producer writes a block, all consumers read it
    (wide read sharing), plus private trailing updates."""
    rng = np.random.default_rng(seed + 2)
    b = _Builder(n_cores)
    blk = 48
    steps = max(3, int(8 * scale))
    stride = 1 if contiguous else 17      # NC variant: conflict-miss prone
    panel0 = 0
    priv0 = blk * steps * stride + 16
    for s in range(steps):
        owner = s % n_cores
        pan = panel0 + s * blk * stride
        for i in range(blk):
            b.store(owner, pan + i * stride, think=2)
        b.barrier()
        for c in range(n_cores):
            for i in range(blk // 2):
                b.load(c, pan + int(rng.integers(blk)) * stride, think=1)
            pb = priv0 + c * 64
            for i in range(32):
                b.load(c, pb + rng.integers(64), think=1)
                b.store(c, pb + rng.integers(64), think=1)
        b.barrier()
    return b.build(priv0 + n_cores * 64 + 8, "lu_c" if contiguous else "lu_nc")


def gen_ocean(n_cores, seed=0, scale=1.0, contiguous=True):
    """Nearest-neighbour grid relaxation: boundary rows are point-to-point
    read-shared; interiors are private and large."""
    rng = np.random.default_rng(seed + 3)
    b = _Builder(n_cores)
    rows = 24
    stride = 1 if contiguous else 13
    iters = max(2, int(5 * scale))
    row0 = 0
    for it in range(iters):
        for c in range(n_cores):
            mine = row0 + c * rows * stride
            left = row0 + ((c - 1) % n_cores) * rows * stride
            right = row0 + ((c + 1) % n_cores) * rows * stride
            for i in range(6):          # neighbour boundary reads
                b.load(c, left + (rows - 1) * stride, think=1)
                b.load(c, right, think=1)
            for i in range(40):         # private interior sweep
                r = int(rng.integers(rows))
                b.load(c, mine + r * stride, think=1)
                b.store(c, mine + r * stride, think=1)
        b.barrier()
    return b.build(row0 + n_cores * rows * stride + 8,
                   "ocean_c" if contiguous else "ocean_nc")


def gen_barnes(n_cores, seed=0, scale=1.0):
    """Tree walk: zipf read-shared nodes, occasional node writes, per-body
    private updates and a few node locks."""
    rng = np.random.default_rng(seed + 4)
    b = _Builder(n_cores)
    nodes = 512
    locks0 = nodes
    nlocks = 16
    priv0 = nodes + nlocks
    steps = max(2, int(3 * scale))
    for s in range(steps):
        for c in range(n_cores):
            pb = priv0 + c * 32
            for i in range(60):
                b.load(c, int(_zipf_idx(rng, nodes, 1)[0]), think=2)
                if i % 10 == 9:
                    b.load(c, pb + rng.integers(32), think=1)
                    b.store(c, pb + rng.integers(32), think=1)
        # tree update phase: low-contention node locks (migratory RMW)
        order = rng.permutation(n_cores)
        for c in order:
            lk = locks0 + int(rng.integers(nlocks))
            b.rmw(int(c), lk, think=2)
            nd = int(_zipf_idx(rng, nodes, 1)[0])
            b.load(int(c), nd, think=1)
            b.store(int(c), nd, think=1)
        b.barrier()
    return b.build(priv0 + n_cores * 32 + 8, "barnes")


def gen_fmm(n_cores, seed=0, scale=1.0):
    """Like barnes but with heavier spin synchronization (paper: FMM is
    spin-sensitive at large self-increment periods)."""
    rng = np.random.default_rng(seed + 5)
    b = _Builder(n_cores)
    cells = 256
    flag0 = cells
    nflags = n_cores
    priv0 = cells + nflags
    steps = max(2, int(3 * scale))
    for s in range(steps):
        for c in range(n_cores):
            for i in range(40):
                b.load(c, int(_zipf_idx(rng, cells, 1)[0]), think=2)
            pb = priv0 + c * 16
            for i in range(10):
                b.store(c, pb + rng.integers(16), think=1)
        # producer-consumer flags: core c waits for c-1's flag (wavefront)
        for c in range(n_cores):
            b.store(c, flag0 + c, think=1)           # publish my result
        for c in range(n_cores):
            b.lock_acquire(c, flag0 + (c + 1) % n_cores, think=0)
            # spin until the neighbour's flag reaches this step's version;
            # lock_acquire pre-schedules exactly that count.
            b.lock_release(c, flag0 + (c + 1) % n_cores)
        b.barrier()
    return b.build(priv0 + n_cores * 16 + 8, "fmm")


def gen_water_nsq(n_cores, seed=0, scale=1.0):
    """Migratory sharing: lock-protected read-modify-write of molecule
    records that pass from core to core."""
    rng = np.random.default_rng(seed + 6)
    b = _Builder(n_cores)
    nmol = 64
    mol0 = 0
    lock0 = nmol * 4
    priv0 = lock0 + nmol
    rounds = max(2, int(4 * scale))
    for r in range(rounds):
        for c in range(n_cores):
            for i in range(6):
                m = int(rng.integers(nmol))
                b.rmw(c, lock0 + m, think=2)   # low-contention molecule lock
                base = mol0 + m * 4
                for w in range(3):
                    b.load(c, base + w, think=1)
                    b.store(c, base + w, think=1)
            pb = priv0 + c * 24
            for i in range(20):
                b.load(c, pb + rng.integers(24), think=1)
                b.store(c, pb + rng.integers(24), think=1)
        b.barrier()
    return b.build(priv0 + n_cores * 24 + 8, "water_nsq")


def gen_water_sp(n_cores, seed=0, scale=1.0):
    """Almost entirely private working set (paper's 3x-traffic outlier with a
    tiny absolute traffic level): very low miss rate, rare shared reads."""
    rng = np.random.default_rng(seed + 7)
    b = _Builder(n_cores)
    shared = 32
    priv0 = shared
    steps = max(2, int(4 * scale))
    for s in range(steps):
        for c in range(n_cores):
            pb = priv0 + c * 16       # fits in L1 -> near-zero misses
            for i in range(120):
                b.load(c, pb + rng.integers(16), think=1)
                if i % 3 == 0:
                    b.store(c, pb + rng.integers(16), think=1)
            for i in range(2):
                b.load(c, int(rng.integers(shared)), think=2)
        b.barrier()
    return b.build(priv0 + n_cores * 16 + 8, "water_sp")


def gen_cholesky(n_cores, seed=0, scale=1.0):
    """Task-queue heavy: a ticket-locked global counter feeds tasks; tasks
    read a shared panel and update private columns.  Spin-heavy."""
    rng = np.random.default_rng(seed + 8)
    b = _Builder(n_cores)
    nlocks = 8                           # per-column-group ticket locks
    locks0, head = 0, nlocks
    panel0 = nlocks + 2
    panel = 256
    priv0 = panel0 + panel
    ntasks = max(n_cores * 2, int(n_cores * 6 * scale))
    # column-group ticket locks + an atomic head counter: spin-heavy (the
    # paper's period-sensitive benchmark, Figs 7-8) but handoffs parallelize
    # across 8 locks, so 64 cores stay near parity while 256 cores (or
    # period=1000) saturate the spins -- matching the paper's behaviour.
    for t in range(ntasks):
        c = t % n_cores
        b.rmw(c, head, think=1)          # atomic task fetch
        lk = locks0 + (t % nlocks)
        b.lock_acquire(c, lk, think=1)
        # supernodal panel update under the column lock
        for i in range(24):
            b.load(c, panel0 + int(rng.integers(panel)), think=6)
        b.lock_release(c, lk)
        pb = priv0 + c * 24
        for i in range(30):
            b.load(c, pb + rng.integers(24), think=3)
            b.store(c, pb + rng.integers(24), think=3)
    b.barrier()
    return b.build(priv0 + n_cores * 24 + 8, "cholesky")


def gen_volrend(n_cores, seed=0, scale=1.0):
    """Read-mostly shared scene + work-stealing counters: the paper's most
    renewal-heavy benchmark (65.8% of LLC requests are renewals)."""
    rng = np.random.default_rng(seed + 9)
    b = _Builder(n_cores)
    qlock = 0
    scene0 = 4
    scene = 96        # fits L1: scene reads *hit but expire* -> renewals
    priv0 = scene0 + scene
    ntasks = max(n_cores * 2, int(n_cores * 5 * scale))
    # Work-stealing counters are *atomics*, not serialization points: each
    # task bumps the shared counter (rmw), which races every reader's pts
    # forward and expires the big read-only scene footprint -> the paper's
    # most renewal-heavy benchmark (65.8% of LLC requests are renewals).
    for t in range(ntasks):
        c = t % n_cores
        b.rmw(c, qlock, think=1)
        for i in range(30):              # big read-only scene footprint
            b.load(c, scene0 + int(rng.integers(scene)), think=1)
        pb = priv0 + c * 8
        for i in range(4):
            b.store(c, pb + rng.integers(8), think=1)
    b.barrier()
    return b.build(priv0 + n_cores * 8 + 8, "volrend")


TRACE_GENERATORS: Dict[str, Callable[..., Trace]] = {
    "fmm": gen_fmm,
    "barnes": gen_barnes,
    "cholesky": gen_cholesky,
    "volrend": gen_volrend,
    "ocean_c": lambda n, seed=0, scale=1.0: gen_ocean(n, seed, scale, True),
    "ocean_nc": lambda n, seed=0, scale=1.0: gen_ocean(n, seed, scale, False),
    "fft": gen_fft,
    "radix": gen_radix,
    "lu_c": lambda n, seed=0, scale=1.0: gen_lu(n, seed, scale, True),
    "lu_nc": lambda n, seed=0, scale=1.0: gen_lu(n, seed, scale, False),
    "water_nsq": gen_water_nsq,
    "water_sp": gen_water_sp,
}


def make_trace(name: str, n_cores: int, seed: int = 0, scale: float = 1.0) -> Trace:
    tr = TRACE_GENERATORS[name](n_cores, seed=seed, scale=scale)
    tr.name = name
    return tr
