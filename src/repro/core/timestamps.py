"""Base-delta timestamp compression (paper section IV-B).

On-chip, Tardis stores per-line timestamps as short deltas against a per-cache
64-bit base timestamp (``bts``).  When any delta would overflow the configured
width the cache *rebases*: ``bts += 2**(bits-1)`` and every delta shrinks by
the same amount.  Deltas that would go negative are clamped:

  * LLC Shared lines / private Exclusive lines: wts and rts may be safely
    *increased* to the new base (a hypothetical later write of the same value /
    later read -- neither violates SC),
  * private Shared lines whose rts would go negative must be invalidated
    (rts cannot grow without the timestamp manager's consent).

This module implements the compressed view functionally: callers keep
*absolute* int32 timestamps (the simulator's source of truth) plus a per-cache
``bts``; :func:`rebase_needed` and :func:`apply_rebase` express the hardware
events so the simulator can charge the rebase cost and perform the clamping /
invalidation side effects.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import protocol


def delta(ts, bts):
    """Compressed representation of an absolute timestamp."""
    return ts - bts


def rebase_needed(max_ts, bts, bits):
    """True when the largest timestamp in the cache no longer fits ``bits``."""
    return (max_ts - bts) >= (1 << bits)


def rebase_amount(bits):
    """The paper rebases by half of the maximum delta."""
    return 1 << (bits - 1)


def apply_rebase(bts, wts, rts, state, is_private, bits):
    """Apply one rebase step to a cache's timestamp arrays.

    Args:
      bts: scalar base timestamp of this cache.
      wts, rts: absolute timestamp arrays for every line.
      state: per-line state (protocol.INVALID/SHARED/EXCLUSIVE).
      is_private: python bool -- private cache (True) or LLC (False).
      bits: delta width.

    Returns (new_bts, new_wts, new_rts, new_state, invalidated_count).
    Absolute timestamps only *increase* (clamped to the new base); private
    Shared lines whose rts falls below the new base are invalidated.
    """
    new_bts = bts + rebase_amount(bits)
    valid = state != protocol.INVALID
    wts_low = valid & (wts < new_bts)
    rts_low = valid & (rts < new_bts)

    if is_private:
        # Shared lines cannot raise rts unilaterally -> invalidate them.
        kill = rts_low & (state == protocol.SHARED)
        new_state = jnp.where(kill, protocol.INVALID, state)
        new_wts = jnp.where(wts_low & ~kill, new_bts, wts)
        new_rts = jnp.where(rts_low & ~kill, new_bts, rts)
        return new_bts, new_wts, new_rts, new_state, jnp.sum(kill)
    # LLC: Shared lines may raise both; Exclusive LLC entries hold no
    # timestamps (owner has them) so leave untouched.
    sh = state == protocol.SHARED
    new_wts = jnp.where(wts_low & sh, new_bts, wts)
    new_rts = jnp.where(rts_low & sh, new_bts, rts)
    return new_bts, new_wts, new_rts, state, jnp.zeros((), jnp.int32)


@partial(jax.jit, static_argnames=("bits", "is_private"))
def maybe_rebase(bts, wts, rts, state, *, bits, is_private):
    """Jitted convenience wrapper: rebase iff needed.

    Returns (bts, wts, rts, state, rebased?, invalidated).
    """
    valid = state != protocol.INVALID
    max_ts = jnp.max(jnp.where(valid, jnp.maximum(wts, rts), 0))
    need = rebase_needed(max_ts, bts, bits)

    def do(_):
        return apply_rebase(bts, wts, rts, state, is_private, bits)

    def skip(_):
        return bts, wts, rts, state, jnp.zeros((), jnp.int32)

    nb, nw, nr, ns, killed = jax.lax.cond(need, do, skip, operand=None)
    return nb, nw, nr, ns, need, killed


def storage_bits_per_line(n_cores: int, scheme: str, delta_bits: int = 20,
                          ackwise_ptrs: int = 4) -> int:
    """Per-LLC-line metadata cost (paper Table VII).

    full-map MSI: one sharer bit per core.  Ackwise: k pointers of log2(N)
    bits each.  Tardis: two delta timestamps (owner id reuses the same bits
    when the line is Exclusive, so no extra cost).
    """
    import math
    logn = max(1, math.ceil(math.log2(n_cores)))
    if scheme == "full-map":
        return n_cores
    if scheme == "ackwise":
        return ackwise_ptrs * logn
    if scheme == "tardis":
        return 2 * delta_bits
    raise ValueError(f"unknown scheme {scheme!r}")
