"""Sequential-consistency validation of simulator op logs (numpy, host-side).

Mirrors the two SC rules from the paper (section II-A):

  Rule 1: per core, committed operations carry non-decreasing timestamps
          (program order implies physiological order).
  Rule 2: every load returns the value (version) of the most recent store in
          the global memory order <m, where
          X <m Y := X <ts Y or (X =ts Y and X <pt Y)
          and physical time (<pt) is the simulator's global commit sequence.

The Tardis simulator logs real logical timestamps; the directory simulator
logs its commit sequence as the timestamp, which reduces <m to physical
order -- the classic directory argument.
"""
from __future__ import annotations

from typing import Dict

import numpy as np


def check_rule1(log: Dict[str, np.ndarray], n_cores: int) -> None:
    """Timestamps are monotonically non-decreasing per core."""
    for c in range(n_cores):
        ts = log["ts"][log["core"] == c]
        if len(ts) > 1:
            bad = np.where(np.diff(ts.astype(np.int64)) < 0)[0]
            assert bad.size == 0, (
                f"Rule 1 violated on core {c}: ts decreases at op {bad[0]}"
                f" ({ts[bad[0]]} -> {ts[bad[0] + 1]})")


def check_rule2(log: Dict[str, np.ndarray]) -> None:
    """Each load observes the latest store in physiological order."""
    seq = np.arange(len(log["ts"]), dtype=np.int64)
    order = log["ts"].astype(np.int64) * (len(seq) + 1) + seq  # (ts, phys) key
    for addr in np.unique(log["addr"]):
        m = log["addr"] == addr
        kinds, vers, keys = log["kind"][m], log["ver"][m], order[m]
        stores = kinds == 1
        s_keys, s_vers = keys[stores], vers[stores]
        # sort stores by physiological order
        si = np.argsort(s_keys)
        s_keys, s_vers = s_keys[si], s_vers[si]
        for k, v, key in zip(kinds, vers, keys):
            if k == 1:
                continue
            pos = np.searchsorted(s_keys, key) - 1  # last store before load
            expect = s_vers[pos] if pos >= 0 else 0
            assert v == expect, (
                f"Rule 2 violated at addr {addr}: load observed v{v}, "
                f"expected v{expect} (physiological position {pos})")


def check_store_versions(log: Dict[str, np.ndarray]) -> None:
    """Stores to an address carry strictly increasing physiological order
    consistent with their version numbers (WAW kept in physical+logical
    order -- the paper keeps WAW correlated with physical time)."""
    seq = np.arange(len(log["ts"]), dtype=np.int64)
    for addr in np.unique(log["addr"]):
        m = (log["addr"] == addr) & (log["kind"] == 1)
        ts, vs, sq = log["ts"][m].astype(np.int64), log["ver"][m], seq[m]
        vi = np.argsort(vs)
        assert np.all(np.diff(sq[vi]) > 0), f"WAW physical order broken @ {addr}"
        assert np.all(np.diff(ts[vi]) >= 0), f"WAW ts order broken @ {addr}"


def check_sc(log: Dict[str, np.ndarray], n_cores: int) -> None:
    check_rule1(log, n_cores)
    check_store_versions(log)
    check_rule2(log)
