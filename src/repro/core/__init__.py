"""The paper's contribution: Tardis timestamp coherence, in JAX.

Layers:
  * :mod:`repro.core.protocol`   -- Tables I-III as pure functions,
  * :mod:`repro.core.timestamps` -- base-delta compression (section IV-B),
  * :mod:`repro.core.simulator`  -- vectorized multi-core simulator,
  * :mod:`repro.core.directory`  -- full-map MSI / Ackwise baselines,
  * :mod:`repro.core.traces`     -- SPLASH-2-like synthetic workloads,
  * :mod:`repro.core.check`      -- sequential-consistency validators,
  * :mod:`repro.core.store`      -- TardisStore: lease-coherent object store
                                    for params / KV blocks (framework layer),
  * :mod:`repro.core.lease_engine` -- LeaseEngine: the device-backed block
                                    table executing Tables I-III through the
                                    ``tardis_lease`` Pallas kernel.
"""
from .geometry import Geometry
from .lease_engine import LeaseEngine, LeaseStats, ReadManyResult, ReadResult
from .policy import CONSISTENCY_MODELS, CoherencePolicy
from .shard_directory import (DirStats, DirWaveResult, FetchedPage,
                              NumpyTransport, ShardedLeaseDirectory)
from .simulator import SimConfig, SimResult, simulate
from .traces import Trace, make_trace, TRACE_GENERATORS

__all__ = ["CONSISTENCY_MODELS", "CoherencePolicy", "DirStats",
           "DirWaveResult", "FetchedPage", "Geometry",
           "LeaseEngine", "LeaseStats", "NumpyTransport", "ReadManyResult",
           "ReadResult", "ShardedLeaseDirectory", "SimConfig", "SimResult",
           "simulate", "Trace", "make_trace", "TRACE_GENERATORS"]
