"""LeaseEngine: the single device-backed implementation of the lease rules.

The repo used to carry three divergent copies of the paper's Tables I-III --
scalar jnp rules in :mod:`repro.core.protocol`, a numpy ``BlockTable`` mirror
in :mod:`repro.core.store`, and an orphaned Pallas kernel under
``repro.kernels.tardis_lease``.  This module collapses them into one
subsystem:

  * the **Pallas kernel** executes every read/renew/write-jump-ahead
    transition against device-resident int32 ``(wts, rts)`` block tables
    (interpret-mode fallback off-TPU),
  * the scalar :mod:`repro.core.protocol` rules remain the differential-test
    oracle (``kernels/tardis_lease/ref.py``),
  * the numpy mirror survives only behind ``backend="numpy"`` so tests can
    diff the kernel against it bit-for-bit.

Timestamps are int32 logical counters guarded by a ``ts_bits`` wraparound
rebase (paper section IV-B applied manager-side): when any timestamp reaches
``2**ts_bits`` the whole table shifts down by ``2**(ts_bits-1)``
(:func:`repro.core.timestamps.rebase_amount`), clamped at zero -- clamping a
low timestamp up to the new base is the paper's "hypothetical later
write/read of the same value", which never violates SC.  Callers holding a
program timestamp or cached leases apply the same shift (see
:meth:`LeaseEngine.maybe_rebase`).

Traffic is charged in message flits from :data:`repro.core.protocol
.MESSAGE_FLITS` so the engine's ledger matches the simulator's accounting:
a read is SH_REQ per block, answered by RENEW_REP (data-less, the common
case once a reader holds the right version) or SH_REP headers plus payload
flits for ``block_bytes``; a write publishes header + payload flits.

Three extensions make leased blocks carry *real data*, make the wave the
unit of dispatch, and make the pool the only KV substrate decode touches:

  * **paged KV pool(s)** -- when constructed with ``kv_block_shape`` (the
    serving layout is ``(chunk, 2, kv_heads, head_dim)``) or with
    ``kv_pools`` (an ordered mapping of NAMED pools, one per cache stack --
    the MoE serving layout is ``{"dense": (chunk, 2, fd*kv_heads, hd),
    "moe": (chunk, 2, nm*kv_heads, hd)}``) the engine owns a
    device-resident ``(n_blocks, row)`` payload pool alongside the
    ``(wts, rts)`` metadata; each row is ``chunk`` lane-padded TOKEN rows,
    so a single token is one aligned row of the ``(n_blocks*chunk,
    token_row)`` flat view (``kv_rows_view``).  With multiple pools the
    token row **interleaves** every stack's segment (each lane-padded, at a
    static ``pool_offset``), so ONE block id leases every stack's payload
    and every transition -- lease, write, eviction, relocation, rebase,
    page alloc/free -- stays a single logical event covering all stacks.
    ``write_kv`` scatters block payloads in (all stacks in one dispatch),
    ``read_kv`` materializes them through the ``tardis_lease`` Pallas
    gather kernel (scalar-prefetched ids drive the DMA index map; a
    ``pool=`` argument gathers one stack's column window without touching
    its neighbors), and a host-side validity bitmap tracks which slots hold
    content for the *current* tag -- ``invalidate_kv`` frees a slot on
    collision eviction with zero messages.  ``maybe_rebase`` shifts
    metadata only: pool contents are timestamps-free and survive any
    rebase untouched.
  * **per-wave batched ops** -- ``read_many`` resolves the reads/renewals
    of a whole wave of requesters in ONE ``masked_lease_check_many`` kernel
    dispatch (the multi-row mask path), and ``write_many`` folds a wave's
    writes into one jump-ahead over the union of their blocks.  With every
    requester at the same program timestamp (the serving case: one logical
    tick per wave) the batched results are bit-identical in ``wts/rts/pts``
    to issuing the per-request ops back to back (``tests/test_litmus.py``).
  * **page allocator + token append** -- block ids in ``[alloc_reserve,
    n_blocks)`` are free-listed decode pages (``alloc_pages`` /
    ``free_pages``; admission control gates on ``free_page_count``), and
    ``append_kv`` scatters single-token rows into their page slots through
    the ``tardis_lease`` scatter kernel (ids drive the *output* index map
    with in/out aliasing) -- the serving engine's continuous-batching
    decode runs entirely against ``kv_rows_view`` and writes back with
    ``set_kv_rows``.
"""
from __future__ import annotations

import dataclasses
import os
import warnings
from functools import partial
from typing import Dict, List, Mapping, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from . import protocol, timestamps
from .policy import CoherencePolicy
from ..kernels.tardis_lease import ops as lease_ops


@jax.jit
def _gather4(a, b, c, d, idx):
    """One dispatch to slice the per-idx results out of full-table arrays
    (ship len(idx) entries to host, not the whole block table)."""
    return a[idx], b[idx], c[idx], d[idx]


@jax.jit
def _gather_many(expired, renew_ok, wts, rts, idx):
    """read_many's per-union-block slice: flags are (G, N), tables (N,)."""
    return expired[:, idx], renew_ok[:, idx], wts[idx], rts[idx]


@partial(jax.jit, donate_argnums=(0,))
def _scatter_rows(pool, idx, rows):
    """In-place pool update (donated buffer: no full-pool copy on TPU)."""
    return pool.at[idx].set(rows.astype(pool.dtype))


@dataclasses.dataclass
class LeaseStats:
    reads: int = 0               # blocks served through read()/renew
    writes: int = 0              # blocks written through write()
    read_ops: int = 0
    write_ops: int = 0
    expired: int = 0             # blocks whose lease had run out at read
    renewals: int = 0            # reads where the requester held a copy
    data_less: int = 0           # renewals answered RENEW_REP (no payload)
    payload_transfers: int = 0   # blocks answered SH_REP with data
    payload_bytes: int = 0
    flits: int = 0               # total message flits incl. headers
    rebases: int = 0
    kv_blocks_written: int = 0   # payload blocks scattered into the pool
    kv_blocks_read: int = 0      # payload blocks gathered out of the pool
    kv_evictions: int = 0        # pool slots freed by invalidate_kv
    kv_tokens_appended: int = 0  # single token rows appended into pages
    pages_allocated: int = 0     # free-list pops (decode page churn)
    pages_freed: int = 0         # free-list pushes
    pred_grows: int = 0          # predictor: leases grown (wasted renewal)
    pred_shrinks: int = 0        # predictor: leases shrunk (write hit)
    # per-stack occupancy: token rows appended into each named pool's
    # segment (a full-row append feeds every stack at once)
    kv_pool_tokens: Dict[str, int] = dataclasses.field(default_factory=dict)

    @property
    def wire_bytes(self) -> int:
        return self.flits * protocol.FLIT_BYTES


@dataclasses.dataclass
class ReadResult:
    """Per-block outcome of a batched read/renew, aligned with ``idx``."""
    expired: np.ndarray          # bool: lease had run out (renewal happened)
    renew_ok: np.ndarray         # bool: requester's version matched (no data)
    wts: np.ndarray              # int32 block versions (unchanged by a read)
    rts: np.ndarray              # int32 extended leases
    new_pts: int                 # reader's program ts after consuming blocks


@dataclasses.dataclass
class ReadManyResult:
    """Outcome of a per-wave batched read: one kernel dispatch for G groups.

    ``union_idx`` is the sorted union of the groups' block ids; ``wts`` /
    ``rts`` align with it.  ``expired`` / ``renew_ok`` are (G, len(union))
    per-group flags evaluated against the pre-call table (the wave's shared
    snapshot; False outside a group's own blocks) and ``new_pts`` is the
    (G,) per-group reader timestamp after consuming its readable blocks.
    """
    union_idx: np.ndarray
    expired: np.ndarray
    renew_ok: np.ndarray
    wts: np.ndarray
    rts: np.ndarray
    new_pts: np.ndarray


class LeaseEngine:
    """Timestamp manager for a table of ``n_blocks`` leased blocks.

    ``backend="pallas"`` keeps the tables as device arrays and runs every
    transition through the ``tardis_lease`` kernels (interpret mode anywhere
    a TPU is absent); ``backend="numpy"`` is the bit-identical host mirror
    kept for differential tests.
    """

    def __init__(self, n_blocks: int, lease: int = 64, *,
                 policy: Optional[CoherencePolicy] = None,
                 backend: str = "pallas", ts_bits: int = 30,
                 block_bytes: int = 0, interpret: Optional[bool] = None,
                 kv_block_shape: Optional[Sequence[int]] = None,
                 kv_pools: Optional[Mapping[str, Sequence[int]]] = None,
                 kv_dtype=jnp.bfloat16, alloc_reserve: int = 0,
                 sanitize: Optional[bool] = None):
        if backend not in ("pallas", "numpy"):
            raise ValueError(f"unknown backend {backend!r}")
        # ``policy`` is the one configuration object (CoherencePolicy);
        # the loose ``lease``/``ts_bits`` kwargs remain as the legacy
        # spelling and fold into a static-SC policy when no policy is given.
        if policy is None:
            policy = CoherencePolicy(lease=int(lease), ts_bits=int(ts_bits))
        self.policy = policy
        self.n_blocks = int(n_blocks)
        self.lease = int(policy.lease)
        self.backend = backend
        self.ts_bits = int(policy.ts_bits)
        self.block_bytes = int(block_bytes)
        # Tardis 2.0 per-block predicted leases (ts DELTAS, so a uniform
        # rebase never touches them); with the predictor off the vector
        # stays pinned at the static lease and the scalar fast path runs.
        self._pred_lease = np.full(self.n_blocks, policy.lease, np.int32)
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        self.interpret = bool(interpret)
        if backend == "pallas":
            self._wts = jnp.zeros(self.n_blocks, jnp.int32)
            self._rts = jnp.zeros(self.n_blocks, jnp.int32)
        else:
            self._wts = np.zeros(self.n_blocks, np.int32)
            self._rts = np.zeros(self.n_blocks, np.int32)
        self.ts_shift = 0            # cumulative rebase amount (see above)
        self.stats = LeaseStats()
        # page allocator: block ids in [alloc_reserve, n_blocks) are the
        # allocatable region (decode pages), handed out lowest-id-first;
        # ids below alloc_reserve stay content-addressed (prefix hashing).
        self.alloc_reserve = int(alloc_reserve)
        self._free_pages = list(range(self.n_blocks - 1,
                                      self.alloc_reserve - 1, -1))
        # O(1) membership for the double-free / never-allocated guards
        self._free_set = set(self._free_pages)
        # paged KV payload pool(s): one row per block = ``chunk`` lane-padded
        # TOKEN rows back to back, so a single decoded token's KV is one
        # aligned row in the (n_blocks*chunk, token_row) flat view (the
        # decode kernels' substrate) and a whole block is ``chunk``
        # consecutive rows (the gather kernel's).  With MULTIPLE named
        # pools (one per cache stack) each token row interleaves every
        # stack's lane-padded segment at a static column offset -- one
        # block id owns every stack's payload, one free list pages them,
        # one lease transition covers them all.  The validity bitmap is
        # host metadata (whether a slot holds content for its current tag),
        # NOT protocol state -- it carries no timestamps and never rebases;
        # it is per BLOCK, not per stack: a block's content is published
        # for every stack at once (write_kv) or for none.
        if kv_pools is not None and kv_block_shape is not None:
            raise ValueError("pass kv_block_shape or kv_pools, not both")
        if kv_pools is None and kv_block_shape is not None:
            kv_pools = {"kv": kv_block_shape}
        self.kv_pools: Optional[Dict[str, tuple]] = (
            {str(k): tuple(int(s) for s in v) for k, v in kv_pools.items()}
            if kv_pools else None)
        # single-pool back-compat alias (None when multi-pool)
        self.kv_block_shape = (next(iter(self.kv_pools.values()))
                               if self.kv_pools and len(self.kv_pools) == 1
                               else None)
        if self.kv_pools:
            chunks = {s[0] for s in self.kv_pools.values()}
            if len(chunks) != 1:
                raise ValueError(
                    f"all pools must share the chunk (token) dim, got "
                    f"{self.kv_pools}")
            self.kv_chunk = int(next(iter(chunks)))
            lanes = lease_ops.LANES
            self._pool_meta: Dict[str, Dict[str, int]] = {}
            off = 0
            for name, shape in self.kv_pools.items():
                te = int(np.prod(shape[1:]))
                row = -(-te // lanes) * lanes
                self._pool_meta[name] = {"offset": off, "token_elems": te,
                                         "token_row": row}
                off += row
            self.kv_token_row = off
            self._kv_row = self.kv_chunk * self.kv_token_row
            if backend == "pallas":
                self._kv_pool = jnp.zeros((self.n_blocks, self._kv_row),
                                          kv_dtype)
            else:
                self._kv_pool = np.zeros((self.n_blocks, self._kv_row),
                                         np.dtype(kv_dtype))
            self._kv_valid = np.zeros(self.n_blocks, bool)
            self.stats.kv_pool_tokens = {n: 0 for n in self.kv_pools}
        # runtime lease sanitizer (repro.analysis.sanitize): host-side
        # invariant checks after every transition.  Off by default; one
        # ``is None`` branch per op when disabled.
        if sanitize is None:
            sanitize = os.environ.get("TARDIS_SANITIZE", "0").lower() \
                not in ("", "0", "false", "off")
        self._san = None
        if sanitize:
            from ..analysis.sanitize import LeaseSanitizer
            self._san = LeaseSanitizer(self)

    @property
    def sanitize_checks(self) -> int:
        """Transitions checked by the sanitizer (0 when it is off)."""
        return self._san.checks if self._san is not None else 0

    @property
    def lease_max(self) -> int:
        """Hard upper bound on any lease this engine may grant (== the
        static lease when the predictor is off) -- the sanitizer's cap."""
        return self.policy.lease_max

    @property
    def pred_lease(self) -> np.ndarray:
        """Per-block predicted leases (pinned at ``lease`` when the
        predictor is off).  Values are timestamp DELTAS: rebases never
        touch them."""
        return self._pred_lease

    def set_pred_lease(self, idx, values) -> None:
        """Install predictor state for blocks (page migration / baseline
        sync: the prediction travels with the block)."""
        idx = np.atleast_1d(np.asarray(idx, np.int64))
        vals = np.broadcast_to(np.asarray(values, np.int32), idx.shape)
        self._pred_lease[idx] = np.clip(vals, self.policy.lease_min,
                                        self.policy.lease_max)

    def _lease_arg(self):
        """The lease operand for a lease pass: the per-block predicted
        vector under the predictor, else the static scalar (the kernels
        broadcast either)."""
        if self.policy.predictor:
            return self._pred_lease
        return np.int32(self.lease)

    def set_tables(self, wts, rts) -> None:
        """Verification seam: load externally computed ``(wts, rts)`` tables.

        Used by the analysis bridge (:mod:`repro.analysis.bridge`) to replay
        model-enumerated transitions through this engine, and by tests that
        need a specific table state.  Resets the sanitizer's monotonicity
        baseline -- the loaded state is a new ground truth, not a
        transition.
        """
        wts = np.asarray(wts, np.int32).reshape(self.n_blocks)
        rts = np.asarray(rts, np.int32).reshape(self.n_blocks)
        if (wts > rts).any():
            raise ValueError("set_tables: wts > rts")
        if self.backend == "pallas":
            self._wts = jnp.asarray(wts)
            self._rts = jnp.asarray(rts)
        else:
            self._wts = wts.copy()
            self._rts = rts.copy()
        if self._san is not None:
            self._san.rebaseline(self)

    # -- table views --------------------------------------------------------

    @property
    def wts(self) -> np.ndarray:
        return np.asarray(self._wts)

    @property
    def rts(self) -> np.ndarray:
        return np.asarray(self._rts)

    # -- paged KV pool ------------------------------------------------------

    @property
    def has_kv(self) -> bool:
        return self.kv_pools is not None

    @property
    def pool_names(self) -> List[str]:
        return list(self.kv_pools) if self.kv_pools else []

    def pool_offset(self, pool: str) -> int:
        """Static column offset of a named stack's segment inside the
        interleaved token row (a LANES multiple -- the decode kernels use
        the same layout)."""
        return self._pool_meta[pool]["offset"]

    def pool_token_row(self, pool: str) -> int:
        return self._pool_meta[pool]["token_row"]

    def pool_token_elems(self, pool: str) -> int:
        return self._pool_meta[pool]["token_elems"]

    def _single_pool(self) -> str:
        if len(self.kv_pools) != 1:
            raise ValueError(
                f"engine has pools {self.pool_names}: name one explicitly")
        return next(iter(self.kv_pools))

    def kv_ok(self, bid: int) -> bool:
        """True when the pool slot holds content for the block's current
        tag (set by write_kv, cleared by invalidate_kv).  Per block: every
        stack's segment is published together or not at all."""
        return bool(self.has_kv and self._kv_valid[bid])

    def kv_valid_count(self) -> int:
        return int(self._kv_valid.sum()) if self.has_kv else 0

    def _pack_rows(self, blocks, n: int, xp, pool: str):
        """(n, *pool_shape) payloads -> (n, chunk, row_p) token-padded."""
        meta = self._pool_meta[pool]
        pad = ((0, 0), (0, 0),
               (0, meta["token_row"] - meta["token_elems"]))
        return xp.pad(xp.asarray(blocks).reshape(
            n, self.kv_chunk, meta["token_elems"]), pad)

    def write_kv(self, idx, blocks) -> None:
        """Scatter block payloads into the pool(s) in ONE dispatch.

        ``blocks`` is (n, *kv_block_shape) for a single-pool engine, or a
        mapping ``{pool_name: (n, *pool_shape)}`` naming EVERY pool -- a
        block's content is published for all stacks at once (the validity
        bit is per block), which is what makes a block id lease both
        stacks' payloads in one transition.
        """
        idx = np.atleast_1d(np.asarray(idx, np.int64))
        if not idx.size:
            return
        if not isinstance(blocks, Mapping):
            blocks = {self._single_pool(): blocks}
        if set(blocks) != set(self.kv_pools):
            raise ValueError(f"write_kv needs every pool "
                             f"{self.pool_names}, got {sorted(blocks)}")
        xp = jnp if self.backend == "pallas" else np
        flat = xp.concatenate(
            [self._pack_rows(blocks[name], idx.size, xp, name)
             for name in self.kv_pools], axis=-1
        ).reshape(idx.size, self._kv_row)
        if self.backend == "pallas":
            with warnings.catch_warnings():
                # CPU XLA can't honor the donation; the TPU path does
                warnings.filterwarnings("ignore", message=".*donated.*")
                self._kv_pool = _scatter_rows(self._kv_pool,
                                              jnp.asarray(idx), flat)
        else:
            self._kv_pool[idx] = flat.astype(self._kv_pool.dtype)
        self._kv_valid[idx] = True
        self.stats.kv_blocks_written += int(idx.size)
        if self._san is not None:
            self._san.after(self, "write_kv", blocks=idx)

    def _rows_to_blocks(self, rows, n: int, pool: str):
        """(n, chunk, row_p) padded rows -> (n, *pool_shape) payloads."""
        meta = self._pool_meta[pool]
        return rows[:, :, :meta["token_elems"]].reshape(
            (n,) + self.kv_pools[pool])

    def read_kv(self, idx, pool: Optional[str] = None):
        """Materialize pool payloads for leased block ids via the Pallas
        gather kernel.

        Single-pool engines return (n, *kv_block_shape).  Multi-pool
        engines return ``{pool_name: (n, *pool_shape)}`` from ONE
        full-row gather; ``pool=name`` instead gathers just that stack's
        column window (the kernel's pool-offset index-map dimension) and
        returns its array.
        """
        idx = np.atleast_1d(np.asarray(idx, np.int64))
        dtype = np.asarray(self._kv_pool[:0]).dtype
        if not idx.size:
            if pool is not None or self.kv_block_shape:
                shape = self.kv_pools[pool] if pool else self.kv_block_shape
                return np.zeros((0,) + shape, dtype)
            return {n_: np.zeros((0,) + s, dtype)
                    for n_, s in self.kv_pools.items()}
        if pool is not None:
            meta = self._pool_meta[pool]
            # token-granular gather over the stack's column window
            rows_idx = (idx[:, None] * self.kv_chunk
                        + np.arange(self.kv_chunk)).reshape(-1)
            if self.backend == "pallas":
                rows = lease_ops.gather_blocks(
                    self.kv_rows_view(), jnp.asarray(rows_idx, jnp.int32),
                    col_lo=meta["offset"], width=meta["token_row"],
                    interpret=self.interpret)
            else:
                rows = self._kv_pool.reshape(-1, self.kv_token_row)[
                    rows_idx,
                    meta["offset"]:meta["offset"] + meta["token_row"]]
            self.stats.kv_blocks_read += int(idx.size)
            rows = rows.reshape(idx.size, self.kv_chunk, meta["token_row"])
            return self._rows_to_blocks(rows, idx.size, pool)
        if self.backend == "pallas":
            rows = lease_ops.gather_blocks(
                self._kv_pool, jnp.asarray(idx, jnp.int32),
                interpret=self.interpret)
        else:
            rows = self._kv_pool[idx]
        self.stats.kv_blocks_read += int(idx.size)
        rows = rows.reshape(idx.size, self.kv_chunk, self.kv_token_row)
        out = {}
        for name, meta in self._pool_meta.items():
            seg = rows[:, :, meta["offset"]:meta["offset"]
                       + meta["token_row"]]
            out[name] = self._rows_to_blocks(seg, idx.size, name)
        if self.kv_block_shape:
            return out[self._single_pool()]
        return out

    def invalidate_kv(self, idx) -> None:
        """Free pool slots on collision eviction (re-tag): the content no
        longer matches the slot's tag.  Zero messages -- readers holding
        leases on the old content keep their private copies."""
        idx = np.atleast_1d(np.asarray(idx, np.int64))
        freed = int(self._kv_valid[idx].sum())
        self._kv_valid[idx] = False
        self.stats.kv_evictions += freed
        if self._san is not None:
            self._san.after(self, "invalidate_kv", blocks=idx)

    # -- decode pages: allocator + token-granular append --------------------

    def free_page_count(self) -> int:
        """Pages left in the allocatable region (admission control bound)."""
        return len(self._free_pages)

    def alloc_pages(self, n: int) -> np.ndarray:
        """Pop ``n`` pages off the free list (lowest ids first).  Callers
        gate admission on :meth:`free_page_count`; running dry here is a
        scheduling bug, not back-pressure."""
        if n > len(self._free_pages):
            raise RuntimeError(
                f"page pool exhausted: want {n}, have {len(self._free_pages)}")
        ids = np.asarray([self._free_pages.pop() for _ in range(n)],
                         np.int64)
        self._free_set.difference_update(int(b) for b in ids)
        self.stats.pages_allocated += int(n)
        if self._san is not None:
            self._san.after(self, "alloc_pages", idx=ids)
        return ids

    def free_pages(self, idx) -> None:
        """Return pages to the free list the moment a request finishes;
        their payload slots are invalidated (no messages, like eviction).

        Freeing a page that is already free, was never handed out by
        :meth:`alloc_pages` (the whole allocatable region starts free, so
        any in-region page that is not free IS outstanding), or lies
        outside the allocatable region raises ``ValueError`` before any
        state changes -- a silent accept would put the id on the free list
        twice and hand the same page to two requests.
        """
        idx = np.atleast_1d(np.asarray(idx, np.int64))
        if not idx.size:
            return
        ids = [int(b) for b in idx]
        if len(set(ids)) != len(ids):
            raise ValueError(
                f"free_pages: duplicate page ids in one call: {sorted(ids)}")
        for b in ids:
            if not self.alloc_reserve <= b < self.n_blocks:
                raise ValueError(
                    f"free_pages: page {b} outside the allocatable region "
                    f"[{self.alloc_reserve}, {self.n_blocks})")
            if b in self._free_set:
                raise ValueError(
                    f"free_pages: page {b} is already free (double free, "
                    f"or never allocated) -- freeing again would hand it "
                    f"to two requests")
        if self.has_kv:
            self._kv_valid[idx] = False
        for b in sorted(ids, reverse=True):
            self._free_pages.append(b)
            self._free_set.add(b)
        self.stats.pages_freed += int(idx.size)
        if self._san is not None:
            self._san.after(self, "free_pages", blocks=idx)

    def kv_rows_view(self):
        """The pool as (n_blocks*chunk, token_row) device rows -- the
        substrate the paged decode step reads and appends against."""
        pool = self._kv_pool if self.backend == "pallas" \
            else jnp.asarray(self._kv_pool)
        return pool.reshape(self.n_blocks * self.kv_chunk, self.kv_token_row)

    def set_kv_rows(self, rows, tokens_appended: int = 0) -> None:
        """Write back the (possibly donated) rows view after a jitted
        decode step appended token KV in place.  An appended row spans the
        whole interleaved token row, so it feeds every stack's counter."""
        pool = rows.reshape(self.n_blocks, self._kv_row)
        if self.backend == "pallas":
            self._kv_pool = pool
        else:
            self._kv_pool = np.asarray(pool)
        self.stats.kv_tokens_appended += int(tokens_appended)
        for name in self.kv_pools:
            self.stats.kv_pool_tokens[name] = (
                self.stats.kv_pool_tokens.get(name, 0)
                + int(tokens_appended))
        if self._san is not None:
            self._san.after(self, "set_kv_rows")

    def append_kv(self, rows_idx, token_rows,
                  pool: Optional[str] = None) -> None:
        """Host-side token append: scatter token rows into flat token slots
        ``rows_idx`` (= block_id * chunk + slot) through the ``tardis_lease``
        scatter kernel.

        ``pool=None`` appends FULL token rows: (n, kv_token_row) already in
        the interleaved multi-stack layout (the serving path packs every
        stack's segment -- one scatter covers both cache stacks), or, on a
        single-pool engine, the legacy unpadded (n, token_elems) form.
        Marks the touched blocks' slots as holding content (prefill writing
        a request's own pages).

        ``pool=name`` appends one stack's (n, pool_token_elems) rows into
        its column window only -- neighbors' segments keep their bits, and
        validity is left untouched (publishing a block's content for every
        stack is ``write_kv``'s job).
        """
        rows_idx = np.atleast_1d(np.asarray(rows_idx, np.int64))
        if not rows_idx.size:
            return
        if pool is not None:
            meta = self._pool_meta[pool]
            rows = np.asarray(token_rows).reshape(rows_idx.size,
                                                  meta["token_elems"])
            if self.backend == "pallas":
                with warnings.catch_warnings():
                    warnings.filterwarnings("ignore", message=".*donat.*")
                    self._kv_pool = lease_ops.append_rows(
                        self.kv_rows_view(),
                        jnp.asarray(rows_idx, jnp.int32), jnp.asarray(rows),
                        col_lo=meta["offset"], width=meta["token_row"],
                        interpret=self.interpret,
                    ).reshape(self.n_blocks, self._kv_row)
            else:
                # write the stack's WHOLE lane-padded window (zeros in the
                # padding), exactly like the kernel's LANES-block DMA --
                # touching only token_elems columns would leave the padding
                # bits behind and break kernel/mirror bit-identity
                flat = np.zeros((rows_idx.size, meta["token_row"]),
                                self._kv_pool.dtype)
                flat[:, :meta["token_elems"]] = rows.astype(
                    self._kv_pool.dtype)
                view = self._kv_pool.reshape(-1, self.kv_token_row)
                view[rows_idx,
                     meta["offset"]:meta["offset"] + meta["token_row"]] \
                    = flat
            self.stats.kv_tokens_appended += int(rows_idx.size)
            self.stats.kv_pool_tokens[pool] = (
                self.stats.kv_pool_tokens.get(pool, 0) + int(rows_idx.size))
            if self._san is not None:       # validity untouched on this path
                self._san.after(self, "append_kv",
                                blocks=np.zeros(0, np.int64))
            return
        rows = np.asarray(token_rows).reshape(rows_idx.size, -1)
        if rows.shape[1] != self.kv_token_row:
            # legacy single-pool form: unpadded token_elems rows
            meta = self._pool_meta[self._single_pool()]
            rows = rows.reshape(rows_idx.size, meta["token_elems"])
        if self.backend == "pallas":
            with warnings.catch_warnings():
                warnings.filterwarnings("ignore", message=".*donat.*")
                self._kv_pool = lease_ops.append_rows(
                    self.kv_rows_view(), jnp.asarray(rows_idx, jnp.int32),
                    jnp.asarray(rows), interpret=self.interpret,
                ).reshape(self.n_blocks, self._kv_row)
        else:
            flat = np.zeros((rows_idx.size, self.kv_token_row),
                            self._kv_pool.dtype)
            flat[:, :rows.shape[1]] = rows
            view = self._kv_pool.reshape(-1, self.kv_token_row)
            view[rows_idx] = flat
        blocks = np.unique(rows_idx // self.kv_chunk)
        self._kv_valid[blocks] = True
        self.stats.kv_tokens_appended += int(rows_idx.size)
        for name in self.kv_pools:       # a full row feeds every stack
            self.stats.kv_pool_tokens[name] = (
                self.stats.kv_pool_tokens.get(name, 0) + int(rows_idx.size))
        if self._san is not None:
            self._san.after(self, "append_kv", blocks=blocks)

    # -- protocol transitions ----------------------------------------------

    def read(self, idx, pts: int, req_wts=None) -> ReadResult:
        """Serve loads/renewals for the blocks in ``idx`` at reader ``pts``.

        Every selected block's lease extends to ``max(rts, wts + lease,
        pts + lease)`` (Table III SH_REQ); the reader's program timestamp
        advances over the consumed versions (Table I load).  ``req_wts``
        (aligned with ``idx``) is the requester's cached version per block;
        matches are answered data-less (RENEW_REP).  None or -1 entries mean
        "no cached copy" and always transfer a payload.
        """
        idx = np.atleast_1d(np.asarray(idx, np.int64))
        if idx.size == 0:
            return ReadResult(np.zeros(0, bool), np.zeros(0, bool),
                              np.zeros(0, np.int32), np.zeros(0, np.int32),
                              int(pts))
        mask = np.zeros(self.n_blocks, np.int32)
        mask[idx] = 1
        req = np.full(self.n_blocks, -1, np.int32)
        if req_wts is not None:
            req[idx] = np.asarray([-1 if r is None else r
                                   for r in np.ravel(req_wts)], np.int32)

        if self.backend == "pallas":
            out = lease_ops.masked_lease_check(
                self._wts, self._rts, jnp.asarray(req), jnp.asarray(mask),
                np.int32(pts), self._lease_arg(),
                interpret=self.interpret)
            self._rts = out["new_rts"]
            expired, renew_ok, wts_at, rts_at = (np.asarray(x) for x in
                _gather4(out["expired"], out["renew_ok"], self._wts,
                         self._rts, jnp.asarray(idx)))
            new_pts = int(out["new_pts"])
        else:
            m = mask.astype(bool)
            lv = self._lease_arg()
            expired_f = m & (pts > self._rts)
            renew_f = m & (req == self._wts)
            ext = np.maximum(np.maximum(self._rts, self._wts + lv),
                             np.int32(pts) + lv)
            consumed = np.where(m & (pts <= self._rts), self._wts, 0)
            self._rts = np.where(m, ext, self._rts).astype(np.int32)
            expired = expired_f[idx]
            renew_ok = renew_f[idx]
            wts_at = self._wts[idx]
            rts_at = self._rts[idx]
            new_pts = int(max(pts, consumed.max(initial=0)))

        n = int(idx.size)
        had_copy = (req[idx] >= 0)
        data_less = int(np.sum(renew_ok & had_copy))
        payload = n - data_less
        st = self.stats
        st.read_ops += 1
        st.reads += n
        st.expired += int(np.sum(expired))
        st.renewals += int(np.sum(had_copy))
        st.data_less += data_less
        st.payload_transfers += payload
        st.payload_bytes += payload * self.block_bytes
        st.flits += n * protocol.MESSAGE_FLITS["SH_REQ"]
        st.flits += data_less * protocol.MESSAGE_FLITS["RENEW_REP"]
        # SH_REP: header + timestamp flits, plus the block payload.
        st.flits += payload * (protocol.MESSAGE_FLITS["RENEW_REP"]
                               + protocol.data_flits(self.block_bytes))
        if self.policy.predictor:
            # a data-less renewal from a holder of a cached copy means that
            # requester's lease aged out before the version changed: wasted
            # traffic, grow the block's next lease.  Requesters only renew
            # on local expiry, so no owner-side expiry gate -- with several
            # readers the owner rts is often already extended past the
            # requester's pts by a peer's renewal, yet the message was
            # still sent
            grow = renew_ok & had_copy
            if np.any(grow):
                b = idx[grow]
                self._pred_lease[b] = np.minimum(
                    self.policy.lease_max, self._pred_lease[b] * 2)
                st.pred_grows += int(np.sum(grow))
        if self._san is not None:
            self._san.after(self, "read", pts=int(pts), new_pts=new_pts)
        return ReadResult(expired, renew_ok, wts_at, rts_at, new_pts)

    def read_many(self, groups: Sequence, pts,
                  req_wts: Optional[Union[Dict[int, int], Sequence]] = None
                  ) -> ReadManyResult:
        """Per-wave batched read: G requester groups, ONE kernel dispatch.

        ``groups`` is a list of per-requester block-id sequences (they may
        overlap -- a wave sharing a system prompt names the same blocks G
        times and still costs a single masked-lease pass).  ``pts`` is a
        scalar (the wave's shared program timestamp, the serving case) or a
        (G,) vector.  ``req_wts`` maps block id -> the requesters' cached
        version (a dict, or an array aligned with the sorted union); the
        wave shares one requester-side cache, so it is per-block.

        With a shared ``pts``, the table state and ``max(new_pts)`` are
        bit-identical to issuing the G reads sequentially at that pts (the
        per-group Table III extensions commute); per-group flags are
        evaluated against the pre-call snapshot.
        """
        groups = [np.atleast_1d(np.asarray(g, np.int64)) for g in groups]
        n_groups = len(groups)
        pts_vec = np.broadcast_to(np.asarray(pts, np.int32),
                                  (n_groups,)).copy()
        union = sorted({int(b) for g in groups for b in g})
        if not union:
            return ReadManyResult(
                np.zeros(0, np.int64), np.zeros((n_groups, 0), bool),
                np.zeros((n_groups, 0), bool), np.zeros(0, np.int32),
                np.zeros(0, np.int32), pts_vec)
        union_idx = np.asarray(union, np.int64)
        # the serving hot case is a wave of identical requesters (shared
        # system prompt): collapse duplicate (blocks, pts) rows so the
        # kernel runs one mask row per DISTINCT requester, and per-group
        # results fan back out (also keeps the traced G small and stable).
        row_of, ukeys = [], {}
        for g, idx in enumerate(groups):
            key = (tuple(sorted({int(b) for b in idx})), int(pts_vec[g]))
            row_of.append(ukeys.setdefault(key, len(ukeys)))
        row_of = np.asarray(row_of)
        n_rows = len(ukeys)
        pts_rows = np.asarray([k[1] for k in ukeys], np.int32)
        masks = np.zeros((n_rows, self.n_blocks), np.int32)
        for key, row in ukeys.items():
            masks[row, list(key[0])] = 1
        req = np.full(self.n_blocks, -1, np.int32)
        if req_wts is not None:
            if isinstance(req_wts, dict):
                for bid, w in req_wts.items():
                    req[bid] = -1 if w is None else int(w)
            else:
                req[union_idx] = np.asarray(
                    [-1 if r is None else r for r in np.ravel(req_wts)],
                    np.int32)

        if self.backend == "pallas":
            out = lease_ops.masked_lease_check_many(
                self._wts, self._rts, jnp.asarray(req), jnp.asarray(masks),
                jnp.asarray(pts_rows), self._lease_arg(),
                interpret=self.interpret)
            self._rts = out["new_rts"]
            expired, renew_ok, wts_at, rts_at = (np.asarray(x) for x in
                _gather_many(out["expired"], out["renew_ok"], self._wts,
                             self._rts, jnp.asarray(union_idx)))
            new_pts = np.asarray(out["new_pts"])
        else:
            m = masks.astype(bool)
            lv = self._lease_arg()
            rts0 = self._rts
            expired_f = m & (pts_rows[:, None] > rts0[None, :])
            renew_f = m & (req[None, :] == self._wts[None, :])
            new_rts = rts0
            new_pts = pts_rows.copy()
            for g in range(n_rows):
                ext = np.maximum(
                    np.maximum(rts0, self._wts + lv),
                    np.int32(pts_rows[g]) + lv)
                new_rts = np.where(m[g], np.maximum(new_rts, ext), new_rts)
                consumed = np.where(m[g] & (pts_rows[g] <= rts0),
                                    self._wts, 0)
                new_pts[g] = max(int(pts_rows[g]),
                                 int(consumed.max(initial=0)))
            self._rts = new_rts.astype(np.int32)
            expired = expired_f[:, union_idx]
            renew_ok = renew_f[:, union_idx]
            wts_at = self._wts[union_idx]
            rts_at = self._rts[union_idx]
        expired = expired[row_of]              # fan the distinct-row results
        renew_ok = renew_ok[row_of]            # back out to the G groups
        new_pts = new_pts[row_of]

        n = int(union_idx.size)
        had_copy = (req[union_idx] >= 0)
        renew_u = renew_ok.any(axis=0)
        data_less = int(np.sum(renew_u & had_copy))
        payload = n - data_less
        st = self.stats
        st.read_ops += 1             # the whole wave: one dispatch
        st.reads += n
        st.expired += int(np.sum(expired.any(axis=0)))
        st.renewals += int(np.sum(had_copy))
        st.data_less += data_less
        st.payload_transfers += payload
        st.payload_bytes += payload * self.block_bytes
        st.flits += n * protocol.MESSAGE_FLITS["SH_REQ"]
        st.flits += data_less * protocol.MESSAGE_FLITS["RENEW_REP"]
        st.flits += payload * (protocol.MESSAGE_FLITS["RENEW_REP"]
                               + protocol.data_flits(self.block_bytes))
        if self.policy.predictor:
            # same rule as read(): every data-less renewal of a held copy
            # is waste, however many groups named the block this wave
            grow = renew_u & had_copy
            if np.any(grow):
                b = union_idx[grow]
                self._pred_lease[b] = np.minimum(
                    self.policy.lease_max, self._pred_lease[b] * 2)
                st.pred_grows += int(np.sum(grow))
        if self._san is not None:
            self._san.after(self, "read_many", pts=pts_vec,
                            new_pts=new_pts)
        return ReadManyResult(union_idx, expired, renew_ok, wts_at, rts_at,
                              new_pts)

    def write_many(self, groups: Sequence, pts: int) -> int:
        """Per-wave batched write: the union of the groups' blocks gets ONE
        jump-ahead (one logical tick for the whole wave), replacing G
        full-table dispatch pairs.  Returns the wave's new pts."""
        union = sorted({int(b) for g in groups
                        for b in np.atleast_1d(np.asarray(g, np.int64))})
        if not union:
            return int(pts)
        return self.write(np.asarray(union, np.int64), pts)

    def write(self, idx, pts: int) -> int:
        """Writer jump-ahead over every block in ``idx`` (Table I store).

        The new version's timestamp clears every outstanding read lease:
        ``ts = max(pts, max(rts[idx]) + 1)``; each block gets wts = rts = ts.
        No invalidation is sent to anybody.  Returns the writer's new pts.
        """
        idx = np.atleast_1d(np.asarray(idx, np.int64))
        if idx.size == 0:
            return int(pts)
        mask = np.zeros(self.n_blocks, np.int32)
        mask[idx] = 1

        if self.backend == "pallas":
            self._wts, self._rts, ts = lease_ops.write_advance(
                self._wts, self._rts, jnp.asarray(mask), np.int32(pts),
                interpret=self.interpret)
            ts = int(ts)
        else:
            m = mask.astype(bool)
            top = int(np.where(m, self._rts, -1).max(initial=-1))
            ts = max(int(pts), top + 1)
            self._wts = np.where(m, np.int32(ts), self._wts).astype(np.int32)
            self._rts = np.where(m, np.int32(ts), self._rts).astype(np.int32)

        n = int(idx.size)
        st = self.stats
        st.write_ops += 1
        st.writes += n
        st.payload_bytes += n * self.block_bytes
        # publish: one header flit + payload per block (DRAM_ST_REQ shape).
        st.flits += n * (1 + protocol.data_flits(self.block_bytes))
        if self.policy.predictor:
            # a write had to clear the lease: shrink so the next lease
            # blocks writers for less long (livelock-free -- the write
            # already jumped ahead regardless of the prediction)
            self._pred_lease[idx] = np.maximum(
                self.policy.lease_min, self._pred_lease[idx] // 2)
            st.pred_shrinks += n
        if self._san is not None:
            self._san.after(self, "write", idx=idx, pts=int(pts), ts=ts)
        return ts

    # -- wraparound guard ---------------------------------------------------

    def maybe_rebase(self) -> int:
        """Shift the whole table down when timestamps approach 2**ts_bits.

        Returns the shift applied (0 when none was needed).  Every caller
        holding a program timestamp or cached ``(wts, rts)`` leases must
        subtract the same shift; a cached lease whose rts falls below the
        new base must be dropped (a private Shared line cannot raise its
        rts unilaterally -- see ``timestamps.apply_rebase``).
        """
        if self.backend == "pallas":
            max_ts = int(jnp.max(self._rts)) if self.n_blocks else 0
        else:
            max_ts = int(np.max(self._rts, initial=0))
        if not timestamps.rebase_needed(max_ts, 0, self.ts_bits):
            return 0
        return self.force_rebase(timestamps.rebase_amount(self.ts_bits))

    def force_rebase(self, shift: int) -> int:
        """Apply a given downward shift unconditionally.

        The sharded directory uses this to keep every shard on ONE
        timestamp base: when any shard trips its guard, the coordinator
        applies the same shift to all shards so cross-shard timestamp
        order survives the rebase.  Returns the shift.
        """
        shift = int(shift)
        if shift <= 0:
            return 0
        if self.backend == "pallas":
            self._wts = jnp.maximum(self._wts - shift, 0)
            self._rts = jnp.maximum(self._rts - shift, 0)
        else:
            self._wts = np.maximum(self._wts - shift, 0).astype(np.int32)
            self._rts = np.maximum(self._rts - shift, 0).astype(np.int32)
        self.ts_shift += shift
        self.stats.rebases += 1
        if self._san is not None:
            self._san.after(self, "rebase")
        return shift

    @staticmethod
    def rebase_pts(pts: int, shift: int) -> int:
        """A caller's program timestamp after an engine rebase."""
        return max(0, int(pts) - int(shift))

    # -- reporting ----------------------------------------------------------

    def report(self) -> dict:
        st = self.stats
        per_pool = {}
        if self.has_kv:
            for name in self.kv_pools:
                per_pool[f"kv_pool_tokens_{name}"] = \
                    st.kv_pool_tokens.get(name, 0)
        return {
            **per_pool,
            "blocks_read": st.reads,
            "blocks_written": st.writes,
            "read_ops": st.read_ops,
            "write_ops": st.write_ops,
            "kv_blocks_written": st.kv_blocks_written,
            "kv_blocks_read": st.kv_blocks_read,
            "kv_evictions": st.kv_evictions,
            "kv_tokens_appended": st.kv_tokens_appended,
            "pages_allocated": st.pages_allocated,
            "pages_freed": st.pages_freed,
            "free_pages": self.free_page_count(),
            "expired_leases": st.expired,
            "renewals": st.renewals,
            "data_less_renewals": st.data_less,
            "payload_transfers": st.payload_transfers,
            "payload_bytes": st.payload_bytes,
            "wire_flits": st.flits,
            "wire_bytes": st.wire_bytes,
            "rebases": st.rebases,
            "pred_grows": st.pred_grows,
            "pred_shrinks": st.pred_shrinks,
            "pred_lease_lo": int(self._pred_lease.min(initial=self.lease)),
            "pred_lease_hi": int(self._pred_lease.max(initial=self.lease)),
            "sanitize_checks": self.sanitize_checks,
        }
