"""LeaseEngine: the single device-backed implementation of the lease rules.

The repo used to carry three divergent copies of the paper's Tables I-III --
scalar jnp rules in :mod:`repro.core.protocol`, a numpy ``BlockTable`` mirror
in :mod:`repro.core.store`, and an orphaned Pallas kernel under
``repro.kernels.tardis_lease``.  This module collapses them into one
subsystem:

  * the **Pallas kernel** executes every read/renew/write-jump-ahead
    transition against device-resident int32 ``(wts, rts)`` block tables
    (interpret-mode fallback off-TPU),
  * the scalar :mod:`repro.core.protocol` rules remain the differential-test
    oracle (``kernels/tardis_lease/ref.py``),
  * the numpy mirror survives only behind ``backend="numpy"`` so tests can
    diff the kernel against it bit-for-bit.

Timestamps are int32 logical counters guarded by a ``ts_bits`` wraparound
rebase (paper section IV-B applied manager-side): when any timestamp reaches
``2**ts_bits`` the whole table shifts down by ``2**(ts_bits-1)``
(:func:`repro.core.timestamps.rebase_amount`), clamped at zero -- clamping a
low timestamp up to the new base is the paper's "hypothetical later
write/read of the same value", which never violates SC.  Callers holding a
program timestamp or cached leases apply the same shift (see
:meth:`LeaseEngine.maybe_rebase`).

Traffic is charged in message flits from :data:`repro.core.protocol
.MESSAGE_FLITS` so the engine's ledger matches the simulator's accounting:
a read is SH_REQ per block, answered by RENEW_REP (data-less, the common
case once a reader holds the right version) or SH_REP headers plus payload
flits for ``block_bytes``; a write publishes header + payload flits.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import protocol, timestamps
from ..kernels.tardis_lease import ops as lease_ops


@jax.jit
def _gather4(a, b, c, d, idx):
    """One dispatch to slice the per-idx results out of full-table arrays
    (ship len(idx) entries to host, not the whole block table)."""
    return a[idx], b[idx], c[idx], d[idx]


@dataclasses.dataclass
class LeaseStats:
    reads: int = 0               # blocks served through read()/renew
    writes: int = 0              # blocks written through write()
    read_ops: int = 0
    write_ops: int = 0
    expired: int = 0             # blocks whose lease had run out at read
    renewals: int = 0            # reads where the requester held a copy
    data_less: int = 0           # renewals answered RENEW_REP (no payload)
    payload_transfers: int = 0   # blocks answered SH_REP with data
    payload_bytes: int = 0
    flits: int = 0               # total message flits incl. headers
    rebases: int = 0

    @property
    def wire_bytes(self) -> int:
        return self.flits * protocol.FLIT_BYTES


@dataclasses.dataclass
class ReadResult:
    """Per-block outcome of a batched read/renew, aligned with ``idx``."""
    expired: np.ndarray          # bool: lease had run out (renewal happened)
    renew_ok: np.ndarray         # bool: requester's version matched (no data)
    wts: np.ndarray              # int32 block versions (unchanged by a read)
    rts: np.ndarray              # int32 extended leases
    new_pts: int                 # reader's program ts after consuming blocks


class LeaseEngine:
    """Timestamp manager for a table of ``n_blocks`` leased blocks.

    ``backend="pallas"`` keeps the tables as device arrays and runs every
    transition through the ``tardis_lease`` kernels (interpret mode anywhere
    a TPU is absent); ``backend="numpy"`` is the bit-identical host mirror
    kept for differential tests.
    """

    def __init__(self, n_blocks: int, lease: int = 64, *,
                 backend: str = "pallas", ts_bits: int = 30,
                 block_bytes: int = 0, interpret: Optional[bool] = None):
        if backend not in ("pallas", "numpy"):
            raise ValueError(f"unknown backend {backend!r}")
        self.n_blocks = int(n_blocks)
        self.lease = int(lease)
        self.backend = backend
        self.ts_bits = int(ts_bits)
        self.block_bytes = int(block_bytes)
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        self.interpret = bool(interpret)
        if backend == "pallas":
            self._wts = jnp.zeros(self.n_blocks, jnp.int32)
            self._rts = jnp.zeros(self.n_blocks, jnp.int32)
        else:
            self._wts = np.zeros(self.n_blocks, np.int32)
            self._rts = np.zeros(self.n_blocks, np.int32)
        self.ts_shift = 0            # cumulative rebase amount (see above)
        self.stats = LeaseStats()

    # -- table views --------------------------------------------------------

    @property
    def wts(self) -> np.ndarray:
        return np.asarray(self._wts)

    @property
    def rts(self) -> np.ndarray:
        return np.asarray(self._rts)

    # -- protocol transitions ----------------------------------------------

    def read(self, idx, pts: int, req_wts=None) -> ReadResult:
        """Serve loads/renewals for the blocks in ``idx`` at reader ``pts``.

        Every selected block's lease extends to ``max(rts, wts + lease,
        pts + lease)`` (Table III SH_REQ); the reader's program timestamp
        advances over the consumed versions (Table I load).  ``req_wts``
        (aligned with ``idx``) is the requester's cached version per block;
        matches are answered data-less (RENEW_REP).  None or -1 entries mean
        "no cached copy" and always transfer a payload.
        """
        idx = np.atleast_1d(np.asarray(idx, np.int64))
        if idx.size == 0:
            return ReadResult(np.zeros(0, bool), np.zeros(0, bool),
                              np.zeros(0, np.int32), np.zeros(0, np.int32),
                              int(pts))
        mask = np.zeros(self.n_blocks, np.int32)
        mask[idx] = 1
        req = np.full(self.n_blocks, -1, np.int32)
        if req_wts is not None:
            req[idx] = np.asarray([-1 if r is None else r
                                   for r in np.ravel(req_wts)], np.int32)

        if self.backend == "pallas":
            out = lease_ops.masked_lease_check(
                self._wts, self._rts, jnp.asarray(req), jnp.asarray(mask),
                np.int32(pts), np.int32(self.lease),
                interpret=self.interpret)
            self._rts = out["new_rts"]
            expired, renew_ok, wts_at, rts_at = (np.asarray(x) for x in
                _gather4(out["expired"], out["renew_ok"], self._wts,
                         self._rts, jnp.asarray(idx)))
            new_pts = int(out["new_pts"])
        else:
            m = mask.astype(bool)
            expired_f = m & (pts > self._rts)
            renew_f = m & (req == self._wts)
            ext = np.maximum(np.maximum(self._rts, self._wts + self.lease),
                             np.int32(pts + self.lease))
            consumed = np.where(m & (pts <= self._rts), self._wts, 0)
            self._rts = np.where(m, ext, self._rts).astype(np.int32)
            expired = expired_f[idx]
            renew_ok = renew_f[idx]
            wts_at = self._wts[idx]
            rts_at = self._rts[idx]
            new_pts = int(max(pts, consumed.max(initial=0)))

        n = int(idx.size)
        had_copy = (req[idx] >= 0)
        data_less = int(np.sum(renew_ok & had_copy))
        payload = n - data_less
        st = self.stats
        st.read_ops += 1
        st.reads += n
        st.expired += int(np.sum(expired))
        st.renewals += int(np.sum(had_copy))
        st.data_less += data_less
        st.payload_transfers += payload
        st.payload_bytes += payload * self.block_bytes
        st.flits += n * protocol.MESSAGE_FLITS["SH_REQ"]
        st.flits += data_less * protocol.MESSAGE_FLITS["RENEW_REP"]
        # SH_REP: header + timestamp flits, plus the block payload.
        st.flits += payload * (protocol.MESSAGE_FLITS["RENEW_REP"]
                               + protocol.data_flits(self.block_bytes))
        return ReadResult(expired, renew_ok, wts_at, rts_at, new_pts)

    def write(self, idx, pts: int) -> int:
        """Writer jump-ahead over every block in ``idx`` (Table I store).

        The new version's timestamp clears every outstanding read lease:
        ``ts = max(pts, max(rts[idx]) + 1)``; each block gets wts = rts = ts.
        No invalidation is sent to anybody.  Returns the writer's new pts.
        """
        idx = np.atleast_1d(np.asarray(idx, np.int64))
        if idx.size == 0:
            return int(pts)
        mask = np.zeros(self.n_blocks, np.int32)
        mask[idx] = 1

        if self.backend == "pallas":
            self._wts, self._rts, ts = lease_ops.write_advance(
                self._wts, self._rts, jnp.asarray(mask), np.int32(pts),
                interpret=self.interpret)
            ts = int(ts)
        else:
            m = mask.astype(bool)
            top = int(np.where(m, self._rts, -1).max(initial=-1))
            ts = max(int(pts), top + 1)
            self._wts = np.where(m, np.int32(ts), self._wts).astype(np.int32)
            self._rts = np.where(m, np.int32(ts), self._rts).astype(np.int32)

        n = int(idx.size)
        st = self.stats
        st.write_ops += 1
        st.writes += n
        st.payload_bytes += n * self.block_bytes
        # publish: one header flit + payload per block (DRAM_ST_REQ shape).
        st.flits += n * (1 + protocol.data_flits(self.block_bytes))
        return ts

    # -- wraparound guard ---------------------------------------------------

    def maybe_rebase(self) -> int:
        """Shift the whole table down when timestamps approach 2**ts_bits.

        Returns the shift applied (0 when none was needed).  Every caller
        holding a program timestamp or cached ``(wts, rts)`` leases must
        subtract the same shift; a cached lease whose rts falls below the
        new base must be dropped (a private Shared line cannot raise its
        rts unilaterally -- see ``timestamps.apply_rebase``).
        """
        if self.backend == "pallas":
            max_ts = int(jnp.max(self._rts)) if self.n_blocks else 0
        else:
            max_ts = int(np.max(self._rts, initial=0))
        if not timestamps.rebase_needed(max_ts, 0, self.ts_bits):
            return 0
        shift = timestamps.rebase_amount(self.ts_bits)
        if self.backend == "pallas":
            self._wts = jnp.maximum(self._wts - shift, 0)
            self._rts = jnp.maximum(self._rts - shift, 0)
        else:
            self._wts = np.maximum(self._wts - shift, 0).astype(np.int32)
            self._rts = np.maximum(self._rts - shift, 0).astype(np.int32)
        self.ts_shift += shift
        self.stats.rebases += 1
        return shift

    @staticmethod
    def rebase_pts(pts: int, shift: int) -> int:
        """A caller's program timestamp after an engine rebase."""
        return max(0, int(pts) - int(shift))

    # -- reporting ----------------------------------------------------------

    def report(self) -> dict:
        st = self.stats
        return {
            "blocks_read": st.reads,
            "blocks_written": st.writes,
            "expired_leases": st.expired,
            "renewals": st.renewals,
            "data_less_renewals": st.data_less,
            "payload_transfers": st.payload_transfers,
            "payload_bytes": st.payload_bytes,
            "wire_flits": st.flits,
            "wire_bytes": st.wire_bytes,
            "rebases": st.rebases,
        }
