"""Full-map MSI and Ackwise-style limited directory baselines.

Same event-level transaction model as :func:`repro.core.simulator.tardis_mem`,
with physical-time coherence: stores invalidate every sharer (and wait for
acknowledgements -- latency is the farthest sharer's round trip, traffic is
per-sharer), loads downgrade exclusive owners, and L1 evictions notify the
directory (PUTS/PUTX) so the sharer list stays precise.

``ackwise_k > 0`` switches the *cost model* to a limited directory with k
sharer pointers: once a line has more than k sharers, invalidations are
broadcast to every core (all N cores ack), as in ATAC/Ackwise.  Semantics are
tracked with a precise bitmask either way; only traffic/latency differ.

The directory's logical timestamp for SC checking is simply the global commit
sequence (physical order) -- directory coherence *is* physical-time order.
"""
from __future__ import annotations

import jax.numpy as jnp

from . import protocol as P
from .geometry import (Geometry, addr_bank, addr_l1_set, addr_llc_set,
                       hop_dist, pick_llc_victim, pick_way)
from .simulator import _bump

I32 = jnp.int32


def _inv_cost(geom: Geometry, cfg, bank, mask, limited_bcast):
    """(latency, traffic, n_msgs) of invalidating the cores in ``mask``.

    Directed mode: INV + ACK per sharer, latency = farthest sharer.
    Broadcast mode (Ackwise overflow): INV to all N cores, every core acks.
    """
    cores = jnp.arange(geom.n_cores, dtype=I32)
    d = hop_dist(geom, bank, cores)
    hop = cfg["hop"]
    any_inv = mask.any()
    lat_directed = jnp.where(any_inv, 2 * hop * jnp.max(jnp.where(mask, d, 0)) + 1, 0)
    traf_directed = jnp.sum(jnp.where(mask, 2 * d, 0))
    n_directed = 2 * jnp.sum(mask)
    lat_bcast = jnp.where(any_inv, 2 * hop * jnp.max(d) + 1, 0)
    traf_bcast = jnp.where(any_inv, jnp.sum(2 * d), 0)
    n_bcast = jnp.where(any_inv, 2 * geom.n_cores, 0)
    lat = jnp.where(limited_bcast, lat_bcast, lat_directed)
    traf = jnp.where(limited_bcast, traf_bcast, traf_directed)
    n = jnp.where(limited_bcast, n_bcast, n_directed)
    return lat, traf, n


def directory_mem(geom: Geometry, st, i, addr, is_store, active):
    """One load/store transaction under (full-map | Ackwise) MSI."""
    cfg = st["cfg"]
    now = st["lru_clock"]
    is_load = ~is_store
    k = cfg["ackwise_k"]

    # ---- L1 lookup -------------------------------------------------------
    set1 = addr_l1_set(geom, addr)
    tags1 = st["l1_tag"][i, set1]
    sts1 = st["l1_st"][i, set1]
    hit1, way1 = pick_way(tags1, sts1, st["l1_lru"][i, set1], addr)
    line_st = sts1[way1]
    line_ver = st["l1_ver"][i, set1, way1]
    l1_ok = jnp.where(is_store, hit1 & (line_st == P.EXCLUSIVE),
                      hit1 & (line_st != P.INVALID))
    needs_llc = active & ~l1_ok
    upgrade = needs_llc & is_store & hit1 & (line_st == P.SHARED)

    # ---- LLC / directory lookup ------------------------------------------
    bank = addr_bank(geom, addr)
    gset = addr_llc_set(geom, addr)
    tagsL = st["llc_tag"][gset]
    stsL = st["llc_st"][gset]
    lrusL = st["llc_lru"][gset]
    ownersL = st["llc_owner"][gset]
    hitL, wayL_hit = pick_way(tagsL, stsL, lrusL, addr)
    victimL = pick_llc_victim(tagsL, stsL, lrusL, ownersL, i)
    wayL = jnp.where(hitL, wayL_hit, victimL)
    L_st = stsL[wayL]
    L_ver = st["llc_ver"][gset, wayL]
    L_dirty = st["llc_dirty"][gset, wayL]
    L_tag = tagsL[wayL]
    L_sharers = st["sharers"][gset, wayL]
    owned = hitL & (L_st == P.EXCLUSIVE)
    owner = ownersL[wayL]
    missL = needs_llc & ~hitL

    # ---- LLC victim eviction ----------------------------------------------
    v_valid = missL & (L_st != P.INVALID)
    v_owned = v_valid & (L_st == P.EXCLUSIVE)
    v_owner = jnp.where(v_owned, owner, 0)
    vset1 = addr_l1_set(geom, L_tag)
    vo_hit, vo_way = pick_way(st["l1_tag"][v_owner, vset1],
                              st["l1_st"][v_owner, vset1],
                              st["l1_lru"][v_owner, vset1], L_tag)
    vo_flush = v_owned & vo_hit
    vo_ver = st["l1_ver"][v_owner, vset1, vo_way]
    vo_dirty = st["l1_dirty"][v_owner, vset1, vo_way]
    # invalidate every sharer of the victim line (directory must)
    v_mask = jnp.where(v_valid & ~v_owned, L_sharers,
                       jnp.zeros_like(L_sharers))
    v_tag_match = st["l1_tag"][:, vset1, :] == L_tag           # (N, W1)
    v_kill = v_mask[:, None] & v_tag_match
    l1_st_a = st["l1_st"].at[:, vset1, :].set(
        jnp.where(v_kill, P.INVALID, st["l1_st"][:, vset1, :]))
    l1_st_a = l1_st_a.at[v_owner, vset1, vo_way].set(
        jnp.where(vo_flush, P.INVALID, l1_st_a[v_owner, vset1, vo_way]))
    victim_ver = jnp.where(vo_flush, vo_ver, L_ver)
    victim_dirty = jnp.where(vo_flush, vo_dirty | L_dirty, L_dirty)
    vaddr = jnp.where(v_valid, L_tag, 0)
    mem_ver = st["mem_ver"].at[vaddr].set(
        jnp.where(v_valid & victim_dirty, victim_ver, st["mem_ver"][vaddr]))
    v_bcast = (k > 0) & (jnp.sum(v_mask) > k)
    v_inv_lat, v_inv_traf, v_inv_msgs = _inv_cost(geom, cfg, bank, v_mask, v_bcast)

    # ---- owner downgrade / flush for the requested line --------------------
    o_hit, o_way = pick_way(st["l1_tag"][owner, set1],
                            st["l1_st"][owner, set1],
                            st["l1_lru"][owner, set1], addr)
    o_act = needs_llc & owned & o_hit
    o_ver = st["l1_ver"][owner, set1, o_way]
    o_new_st = jnp.where(is_store, P.INVALID, P.SHARED)
    l1_st_a = l1_st_a.at[owner, set1, o_way].set(
        jnp.where(o_act, o_new_st, l1_st_a[owner, set1, o_way]))

    # ---- invalidate sharers on GETX ----------------------------------------
    others = L_sharers.at[i].set(False)
    s_mask = jnp.where(needs_llc & is_store & hitL & ~owned, others,
                       jnp.zeros_like(others))
    s_tag_match = st["l1_tag"][:, set1, :] == addr
    s_kill = s_mask[:, None] & s_tag_match
    l1_st_a = l1_st_a.at[:, set1, :].set(
        jnp.where(s_kill, P.INVALID, l1_st_a[:, set1, :]))
    s_bcast = (k > 0) & (jnp.sum(s_mask) > k)
    inv_lat, inv_traf, inv_msgs = _inv_cost(geom, cfg, bank, s_mask, s_bcast)

    # ---- grant -------------------------------------------------------------
    g_ver = jnp.where(owned, o_ver, jnp.where(hitL, L_ver, st["mem_ver"][addr]))
    new_ver = st["store_count"][addr] + 1

    # ---- directory entry update --------------------------------------------
    upd = needs_llc
    new_sharers = jnp.where(
        is_store,
        jnp.zeros_like(L_sharers),
        jnp.where(missL, jnp.zeros_like(L_sharers),
                  jnp.where(owned, jnp.zeros_like(L_sharers).at[owner].set(True),
                            L_sharers)).at[i].set(True))
    new_sharers = jnp.where(is_load & missL,
                            jnp.zeros_like(L_sharers).at[i].set(True),
                            new_sharers)
    sharers = st["sharers"].at[gset, wayL].set(
        jnp.where(upd, new_sharers, L_sharers))
    llc_tag = st["llc_tag"].at[gset, wayL].set(jnp.where(upd, addr, L_tag))
    llc_st = st["llc_st"].at[gset, wayL].set(
        jnp.where(upd, jnp.where(is_store, P.EXCLUSIVE, P.SHARED), L_st))
    llc_owner = st["llc_owner"].at[gset, wayL].set(
        jnp.where(upd & is_store, i, jnp.where(upd, -1, ownersL[wayL])))
    llc_ver = st["llc_ver"].at[gset, wayL].set(jnp.where(upd, g_ver, L_ver))
    llc_dirty = st["llc_dirty"].at[gset, wayL].set(
        jnp.where(upd, jnp.where(owned, True, hitL & L_dirty) & is_load, L_dirty))
    llc_lru = st["llc_lru"].at[gset, wayL].set(jnp.where(upd, now, lrusL[wayL]))

    # ---- L1 victim (PUTS / PUTX) -------------------------------------------
    fill = needs_llc & ~hit1
    v1_tag = tags1[way1]
    v1_st = sts1[way1]
    v1_valid = fill & (v1_st != P.INVALID)
    v1_excl = v1_valid & (v1_st == P.EXCLUSIVE)
    v1_shared = v1_valid & (v1_st == P.SHARED)
    v1_ver = st["l1_ver"][i, set1, way1]
    gsetv1 = addr_llc_set(geom, v1_tag)
    bankv1 = addr_bank(geom, v1_tag)
    hv1, wv1 = pick_way(llc_tag[gsetv1], llc_st[gsetv1], llc_lru[gsetv1], v1_tag)
    v1_hit = v1_valid & hv1
    # PUTS: drop my sharer bit; PUTX: write data back, line becomes unowned
    old_sh_v1 = sharers[gsetv1, wv1]
    sharers = sharers.at[gsetv1, wv1, i].set(
        jnp.where(v1_hit & v1_shared, False, old_sh_v1[i]))
    llc_st = llc_st.at[gsetv1, wv1].set(
        jnp.where(v1_hit & v1_excl, P.SHARED, llc_st[gsetv1, wv1]))
    llc_ver = llc_ver.at[gsetv1, wv1].set(
        jnp.where(v1_hit & v1_excl, v1_ver, llc_ver[gsetv1, wv1]))
    llc_dirty = llc_dirty.at[gsetv1, wv1].set(
        jnp.where(v1_hit & v1_excl, True, llc_dirty[gsetv1, wv1]))
    sharers = sharers.at[gsetv1, wv1].set(
        jnp.where(v1_hit & v1_excl, jnp.zeros_like(old_sh_v1),
                  sharers[gsetv1, wv1]))
    mem_ver = mem_ver.at[jnp.where(v1_excl & ~hv1, v1_tag, 0)].set(
        jnp.where(v1_excl & ~hv1, v1_ver,
                  mem_ver[jnp.where(v1_excl & ~hv1, v1_tag, 0)]))

    # ---- requester L1 -------------------------------------------------------
    sel = active
    f_st = jnp.where(is_store, P.EXCLUSIVE, jnp.where(l1_ok, line_st, P.SHARED))
    f_ver = jnp.where(is_store, new_ver, jnp.where(l1_ok, line_ver, g_ver))
    f_dirty = jnp.where(is_store, True,
                        jnp.where(l1_ok, st["l1_dirty"][i, set1, way1], False))
    l1_tag = st["l1_tag"].at[i, set1, way1].set(jnp.where(sel, addr, tags1[way1]))
    l1_st_a = l1_st_a.at[i, set1, way1].set(
        jnp.where(sel, f_st, l1_st_a[i, set1, way1]))
    l1_ver = st["l1_ver"].at[i, set1, way1].set(
        jnp.where(sel, f_ver, st["l1_ver"][i, set1, way1]))
    l1_dirty = st["l1_dirty"].at[i, set1, way1].set(
        jnp.where(sel, f_dirty, st["l1_dirty"][i, set1, way1]))
    l1_lru = st["l1_lru"].at[i, set1, way1].set(
        jnp.where(sel, now, st["l1_lru"][i, set1, way1]))
    store_count = st["store_count"].at[addr].set(
        jnp.where(sel & is_store, new_ver, st["store_count"][addr]))
    ver_obs = jnp.where(is_store, new_ver, jnp.where(l1_ok, line_ver, g_ver))

    # ---- latency & traffic --------------------------------------------------
    hop = cfg["hop"]
    d_ib = hop_dist(geom, i, bank)
    d_bo = hop_dist(geom, bank, owner)
    d_bvo = hop_dist(geom, bank, v_owner)
    d_ibv1 = hop_dist(geom, i, bankv1)
    llc_leg = 2 * hop * d_ib + cfg["llc_lat"]
    owner_leg = jnp.where(o_act, 2 * hop * d_bo + 1, 0)
    vflush_leg = jnp.where(vo_flush, 2 * hop * d_bvo + 1, 0)
    dram_leg = jnp.where(missL, cfg["dram_lat"] + vflush_leg + v_inv_lat, 0)
    lat_full = llc_leg + owner_leg + dram_leg + inv_lat
    lat = jnp.where(needs_llc, jnp.maximum(1, lat_full - cfg["ooo_hide"]), 1)

    reply_flits = jnp.where(upgrade & ~owned, 1, 5)
    traffic = jnp.where(needs_llc, (1 + reply_flits) * d_ib, 0)
    traffic += jnp.where(o_act, (1 + 5) * d_bo, 0)
    traffic += inv_traf + v_inv_traf
    traffic += jnp.where(missL, 1 + 5, 0)
    traffic += jnp.where(v_valid & victim_dirty, 5, 0)
    traffic += jnp.where(vo_flush, (1 + 5) * d_bvo, 0)
    traffic += jnp.where(v1_hit & v1_shared, 1 * d_ibv1, 0)     # PUTS
    traffic += jnp.where(v1_excl, 5 * d_ibv1, 0)                # PUTX
    msgs = (jnp.where(needs_llc, 2, 0) + jnp.where(o_act, 2, 0)
            + jnp.where(missL, 2, 0) + jnp.where(vo_flush, 2, 0)
            + jnp.where(v1_valid, 1, 0) + inv_msgs + v_inv_msgs)

    stats = _bump(
        st["stats"],
        traffic=jnp.where(active, traffic, 0),
        msgs=jnp.where(active, msgs, 0),
        n_llc_req=needs_llc, n_dram=missL,
        n_inv_msgs=inv_msgs + v_inv_msgs,
        n_l1_miss=needs_llc,
        n_evict_msgs=jnp.where(v1_valid, 1, 0),
    )

    new_st = dict(st, l1_tag=l1_tag, l1_st=l1_st_a, l1_ver=l1_ver,
                  l1_dirty=l1_dirty, l1_lru=l1_lru, llc_tag=llc_tag,
                  llc_st=llc_st, llc_owner=llc_owner, llc_ver=llc_ver,
                  llc_dirty=llc_dirty, llc_lru=llc_lru, sharers=sharers,
                  mem_ver=mem_ver, store_count=store_count, stats=stats)
    # directory "timestamp" for SC logging = commit sequence number
    op_ts = st["steps"]
    return new_st, lat, op_ts, ver_obs
