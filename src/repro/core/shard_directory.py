"""ShardedLeaseDirectory: ONE logical lease table + KV pool across N hosts.

The single-host :class:`~repro.core.lease_engine.LeaseEngine` resolves a
serving wave's lease traffic in one batched dispatch; this module extends
that batching across the host boundary.  Block ids hash to an **owner
shard** (``owner(gid) = gid % n_shards``, ``slot(gid) = gid // n_shards``)
and each shard is a private ``LeaseEngine`` holding its slice of the
``(wts, rts)`` tables plus the *home* copy of its blocks' KV pool pages.
Hosts keep private caches of remotely-owned payloads; coherence between
them is pure Tardis -- leases expire by timestamp comparison, writers jump
ahead, and **nobody ever sends an invalidation or multicast**.

The unit of communication is the **wave**: a host's lease traffic for one
scheduling tick -- reads/renewals, tag re-writes, payload fetches, and any
write-behind publishes it has queued -- is partitioned by owner shard and
exchanged as AT MOST one request + one response message per contacted
shard (shards the host itself owns are local and free).  Inside a shard
the wave applies writes first, then pending publishes, then reads, then
fetches, so a same-wave re-tag drops a stale queued publish and a fetch
always rides a fresh read lease.

Payload movement is **timestamp-ordered page migration**: the owner
returns a ``(wts, rts, version)``-tagged page whose lease was extended by
the same wave's read, so the borrower installs it under exactly the lease
it will serve from (and its ``ts_bits`` rebase guard keeps working --
:meth:`maybe_rebase` applies one uniform shift to every shard so
cross-shard timestamp order survives).  Writers publish **write-behind**:
a write re-tags the directory and invalidates the home slot immediately
(metadata only), while the payload rides a later wave's request message
(:meth:`defer_publish` / :meth:`flush_deferred`); a publish whose tag or
version no longer matches the directory is silently dropped -- the content
is dead, coherence never depended on it.

Traffic is flit-charged (:data:`repro.core.protocol.FLIT_BYTES`) so
``report()`` gives real cross-host message/byte counts next to the hard
zeros (``xhost_multicasts``, ``xhost_invalidation_msgs``) that are the
paper's pitch, and :meth:`broadcast_baseline` prices the counterfactual
O(sharers) invalidation multicast a conventional directory would have
sent.  On device the per-shard exchange is the tiled all-to-all in
:mod:`repro.dist.collectives`; :class:`NumpyTransport` routes every wave's
per-shard flit counts through the deterministic ``np_all_to_all`` mirror
so CPU tests exercise the same transpose-of-shards data path.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from . import protocol, timestamps
from .lease_engine import LeaseEngine
from .policy import CoherencePolicy
from ..dist import collectives


@dataclasses.dataclass
class DirStats:
    """Cross-host ledger.  Local-shard operations charge nothing here."""
    waves: int = 0
    req_msgs: int = 0
    rep_msgs: int = 0
    flits: int = 0
    migrations: int = 0          # payload pages moved host-to-host
    publishes: int = 0           # write-behind payloads installed at home
    publishes_dropped: int = 0   # stale (re-tagged before the flush landed)
    watches: int = 0             # publish-then-notify subscriptions taken
    notifies: int = 0            # landed-page notifications delivered
    multicasts: int = 0          # stays 0: Tardis sends none
    invalidation_msgs: int = 0   # stays 0: expiry is a timestamp compare

    @property
    def msgs(self) -> int:
        return self.req_msgs + self.rep_msgs

    @property
    def wire_bytes(self) -> int:
        return self.flits * protocol.FLIT_BYTES


@dataclasses.dataclass
class FetchedPage:
    """A migrated page: payload + the exact lease/content tags it carries."""
    gid: int
    wts: int
    rts: int
    tag: int
    wver: int
    blocks: Mapping[str, np.ndarray]   # {pool: (1, *pool_shape)}
    pred_lease: int = 0                # owner's predicted lease travels too


@dataclasses.dataclass
class DirWaveResult:
    new_pts: int                         # max over reads + writes
    group_pts: np.ndarray                # (G,) per-read-group new pts
    leases: Dict[int, Tuple[int, int]]   # gid -> (wts, rts) post-extension
    renew_ok: Dict[int, bool]            # requester's cached wts still current
    expired: Dict[int, bool]             # pts > rts at wave entry
    write_ts: Dict[int, int]             # gid -> jump-ahead ts from this wave
    fetched: Dict[int, FetchedPage]      # gid -> migrated page
    msgs: int                            # cross-host messages this wave
    shards_contacted: int                # remote owner shards exchanged with


class NumpyTransport:
    """Deterministic host mirror of the device shard exchange.

    Every wave's per-destination-host flit counts are routed through
    :func:`repro.dist.collectives.np_all_to_all` exactly as the device
    path would ride ``lax.all_to_all`` over the ``data``/``pod`` axes:
    only the source host's row block is populated, the transpose lands
    block ``src`` of destination ``dst`` on host ``dst``, and the
    round-trip is asserted bit-for-bit before the wave proceeds.
    """

    def __init__(self, n_hosts: int):
        self.n_hosts = int(n_hosts)
        self.routes = 0

    def exchange(self, src: int, sizes: np.ndarray) -> np.ndarray:
        """Route ``sizes`` ((n_hosts, k) int64, row = payload for that
        destination host) from host ``src``; returns what ``src`` would
        see after the response leg (its own row of the transpose)."""
        n = self.n_hosts
        sizes = np.asarray(sizes, np.int64).reshape(n, -1)
        per_host = [np.zeros_like(sizes) for _ in range(n)]
        per_host[src] = sizes
        out = collectives.np_all_to_all(per_host)
        for dst in range(n):
            got = out[dst].reshape(n, -1)
            if not np.array_equal(got[src], sizes[dst]):
                raise AssertionError(
                    f"transport route {src}->{dst} corrupted: "
                    f"{got[src]} != {sizes[dst]}")
            rest = np.delete(got, src, axis=0)
            if rest.any():
                raise AssertionError(
                    f"transport leaked data onto host {dst} from a host "
                    f"that sent nothing")
        self.routes += int((sizes != 0).any(axis=1).sum())
        return out[src].reshape(n, -1)


class ShardedLeaseDirectory:
    """N-shard lease directory over one global block-id space.

    ``n_hosts`` defaults to ``n_shards`` (shard ``s`` lives on host
    ``s % n_hosts``).  ``backend``/``kv_pools``/``block_bytes`` configure
    each shard's :class:`LeaseEngine` (home pools are directory-managed:
    the per-shard free list is empty, slots are addressed by ownership).
    """

    def __init__(self, n_blocks: int, n_shards: int, *,
                 policy: Optional[CoherencePolicy] = None,
                 n_hosts: Optional[int] = None, lease: int = 64,
                 backend: str = "numpy", ts_bits: int = 30,
                 block_bytes: int = 0, interpret: Optional[bool] = None,
                 kv_pools: Optional[Mapping[str, Sequence[int]]] = None,
                 kv_dtype=jnp.bfloat16, sanitize: Optional[bool] = None,
                 transport: Optional[NumpyTransport] = None):
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        if policy is None:
            policy = CoherencePolicy(lease=int(lease), ts_bits=int(ts_bits))
        self.policy = policy
        self.n_blocks = int(n_blocks)
        self.n_shards = int(n_shards)
        self.n_hosts = int(n_hosts) if n_hosts is not None else self.n_shards
        self.lease = int(policy.lease)
        self.ts_bits = int(policy.ts_bits)
        self.block_bytes = int(block_bytes)
        self.n_slots = -(-self.n_blocks // self.n_shards)
        # each shard engine carries its slots' predictor state, so a
        # prediction lives at (and travels with) the block's owner
        self.shards: List[LeaseEngine] = [
            LeaseEngine(self.n_slots, policy=policy, backend=backend,
                        block_bytes=block_bytes,
                        interpret=interpret, kv_pools=kv_pools,
                        kv_dtype=kv_dtype, alloc_reserve=self.n_slots,
                        sanitize=sanitize)
            for _ in range(self.n_shards)]
        # content truth: directory-global tag + monotone version per block
        self.tags = np.full(self.n_blocks, -1, np.int64)
        self.wver = np.zeros(self.n_blocks, np.int64)
        self.ts_shift = 0
        self.rebases = 0
        self.stats = DirStats()
        self.wave_log: List[dict] = []
        # write-behind queues: host -> shard -> [(gid, blocks, tag, wver)]
        self._pending: Dict[int, Dict[int, list]] = {}
        # publish-then-notify: gid -> {watcher host: expected tag or None};
        # a successful home install delivers a notification message (one
        # pair per (owner shard, watcher host) per wave) so a decode pod
        # learns a page landed without polling the directory
        self._watch: Dict[int, Dict[int, Optional[int]]] = {}
        self._notify_ready: Dict[int, List[int]] = {}
        self.transport = transport if transport is not None else \
            NumpyTransport(self.n_hosts)
        if sanitize is None:
            sanitize = os.environ.get("TARDIS_SANITIZE", "0").lower() \
                not in ("", "0", "false", "off")
        self._msan = None
        if sanitize:
            from ..analysis.sanitize import MigrationSanitizer
            self._msan = MigrationSanitizer()

    # -- id space ------------------------------------------------------------

    def owner(self, gid: int) -> int:
        return int(gid) % self.n_shards

    def slot(self, gid: int) -> int:
        return int(gid) // self.n_shards

    def shard_host(self, shard: int) -> int:
        return int(shard) % self.n_hosts

    def gid_of(self, shard: int, slot: int) -> int:
        return int(slot) * self.n_shards + int(shard)

    @property
    def wts(self) -> np.ndarray:
        """Reassembled global wts table (verification view)."""
        out = np.zeros(self.n_blocks, np.int32)
        for s, eng in enumerate(self.shards):
            gids = np.arange(s, self.n_blocks, self.n_shards)
            out[gids] = eng.wts[:gids.size]
        return out

    @property
    def rts(self) -> np.ndarray:
        out = np.zeros(self.n_blocks, np.int32)
        for s, eng in enumerate(self.shards):
            gids = np.arange(s, self.n_blocks, self.n_shards)
            out[gids] = eng.rts[:gids.size]
        return out

    @property
    def pred_lease(self) -> np.ndarray:
        """Reassembled global predicted-lease view (owner-side state)."""
        out = np.full(self.n_blocks, self.lease, np.int32)
        for s, eng in enumerate(self.shards):
            gids = np.arange(s, self.n_blocks, self.n_shards)
            out[gids] = eng.pred_lease[:gids.size]
        return out

    def home_ok(self, gid: int) -> bool:
        """Does the owner shard hold valid home content for ``gid``'s
        current tag?  (False between a re-tag and its publish flush.)"""
        return self.shards[self.owner(gid)].kv_ok(self.slot(gid))

    @property
    def sanitize_checks(self) -> int:
        eng = sum(e.sanitize_checks for e in self.shards)
        return eng + (self._msan.checks if self._msan is not None else 0)

    # -- write-behind publishes ---------------------------------------------

    def defer_publish(self, host: int, gid: int, blocks,
                      tag: Optional[int] = None,
                      wver: Optional[int] = None) -> None:
        """Queue ``gid``'s new payload for its home shard; it rides the
        next wave ``host`` sends (or :meth:`flush_deferred`).  ``tag`` /
        ``wver`` default to the directory's current values -- the writer
        publishes the content it just re-tagged."""
        gid = int(gid)
        tag = int(self.tags[gid]) if tag is None else int(tag)
        wver = int(self.wver[gid]) if wver is None else int(wver)
        if self._msan is not None:
            self._msan.on_defer(host, gid, tag, wver)
        shard = self.owner(gid)
        self._pending.setdefault(int(host), {}).setdefault(
            shard, []).append((gid, blocks, tag, wver))

    def _apply_pends(self, host: int, shard: int) -> int:
        """Install this host's queued publishes at ``shard``; returns the
        number of payload blocks that rode the request message.  Installed
        blocks with watchers trigger a publish-then-notify exchange."""
        pends = self._pending.get(int(host), {}).pop(shard, [])
        eng = self.shards[shard]
        landed: List[int] = []
        for gid, blocks, tag, wver in pends:
            if self._msan is not None:
                self._msan.on_flush(host, gid, tag, wver)
            if self.tags[gid] != tag or self.wver[gid] != wver:
                self.stats.publishes_dropped += 1   # re-tagged underneath
                continue
            eng.write_kv(np.asarray([self.slot(gid)], np.int64), blocks)
            self.stats.publishes += 1
            landed.append(gid)
        if landed:
            self._emit_notifies(shard, landed)
        return len(pends)

    # -- publish-then-notify --------------------------------------------------

    def subscribe(self, host: int, gids: Sequence,
                  tags: Optional[Sequence] = None) -> List[int]:
        """Register ``host`` to be told when each gid's home payload lands
        (the disaggregated hand-off: a decode pod subscribes to the pages a
        prefill pod will publish, instead of polling the directory).

        Returns the gids that are ALREADY home (under the expected ``tags``
        when given) -- no watch is taken for those.  The remaining watches
        ride one request + one ack message per contacted remote owner shard
        (the same <=1-message-pair-per-shard budget every wave obeys); the
        matching notification is delivered by :meth:`_apply_pends` when the
        publish installs, and drained with :meth:`pop_notifications`.
        """
        host = int(host)
        gids = list(gids)
        if tags is not None and len(tags) != len(gids):
            raise ValueError("tags must align with gids")
        want: Dict[int, Optional[int]] = {}
        for i, g in enumerate(gids):
            want.setdefault(int(g), None if tags is None else int(tags[i]))
        landed, by_shard = [], {}
        for g, tag in want.items():
            if self.home_ok(g) and (tag is None or int(self.tags[g]) == tag):
                landed.append(g)
                continue
            self._watch.setdefault(g, {})[host] = tag
            by_shard.setdefault(self.owner(g), []).append(g)
            self.stats.watches += 1
        if not by_shard:
            return landed
        sizes = np.zeros((self.n_hosts, 2), np.int64)
        log = {"host": host, "kind": "watch", "shards": sorted(by_shard),
               "msgs": 0, "flits": 0}
        for s, watched in sorted(by_shard.items()):
            if self.shard_host(s) == host:
                continue                            # local shard: free
            req = 1 + protocol.data_flits(4 * len(watched))
            rep = 1                                 # bare ack
            self.stats.req_msgs += 1
            self.stats.rep_msgs += 1
            self.stats.flits += req + rep
            sizes[self.shard_host(s)] += (req, rep)
            log["msgs"] += 2
            log["flits"] += req + rep
        if self.transport is not None and sizes.any():
            self.transport.exchange(host % self.n_hosts, sizes)
        self.wave_log.append(log)
        return landed

    def _emit_notifies(self, shard: int, gids: Sequence[int]) -> None:
        """A publish landed at ``shard`` for ``gids``: deliver one
        notification message pair per watcher host (all of a watcher's
        landed gids in this wave batch into ONE pair, so the notify kind
        stays inside the per-shard-per-wave message budget)."""
        by_watcher: Dict[int, List[int]] = {}
        for g in gids:
            for w, tag in self._watch.pop(int(g), {}).items():
                if tag is not None and int(self.tags[g]) != tag:
                    continue            # landed under a different content
                by_watcher.setdefault(w, []).append(int(g))
        src = self.shard_host(shard)
        for w, got in sorted(by_watcher.items()):
            self._notify_ready.setdefault(w, []).extend(got)
            self.stats.notifies += len(got)
            if w == src:
                continue                            # watcher is home: free
            req = 1 + protocol.data_flits(4 * len(got))
            rep = 1                                 # bare ack
            self.stats.req_msgs += 1
            self.stats.rep_msgs += 1
            self.stats.flits += req + rep
            sizes = np.zeros((self.n_hosts, 2), np.int64)
            sizes[w] = (req, rep)
            if self.transport is not None:
                self.transport.exchange(src, sizes)
            self.wave_log.append(
                {"host": src, "kind": "notify", "shards": [shard],
                 "watcher": w, "gids": list(got), "msgs": 2,
                 "flits": req + rep})

    def pop_notifications(self, host: int) -> List[int]:
        """Drain the landed-page notifications delivered to ``host``."""
        return self._notify_ready.pop(int(host), [])

    def flush_deferred(self, host: Optional[int] = None) -> int:
        """Drain write-behind queues (end of run / host drain) as
        publish-only waves: one request message per (host, owner shard)
        still holding payloads.  Returns the number of flush messages."""
        hosts = [int(host)] if host is not None else \
            sorted(self._pending.keys())
        sent = 0
        for h in hosts:
            shards = sorted(self._pending.get(h, {}).keys())
            if not shards:
                continue
            sizes = np.zeros((self.n_hosts, 2), np.int64)
            log = {"host": h, "kind": "flush", "shards": shards, "msgs": 0,
                   "flits": 0}
            for s in shards:
                n_pend = self._apply_pends(h, s)
                if self.shard_host(s) == h:
                    continue                        # local: free
                req = 1 + n_pend * protocol.data_flits(self.block_bytes)
                rep = 1                             # bare ack
                self.stats.req_msgs += 1
                self.stats.rep_msgs += 1
                self.stats.flits += req + rep
                log["msgs"] += 2
                log["flits"] += req + rep
                sizes[self.shard_host(s)] += (req, rep)
                sent += 1
            if self.transport is not None and sizes.any():
                self.transport.exchange(h % self.n_hosts, sizes)
            self.wave_log.append(log)
        return sent

    # -- the wave ------------------------------------------------------------

    def wave(self, host: int, pts: int, read_groups: Sequence = (),
             req_wts: Optional[Mapping[int, int]] = None,
             write_bids: Sequence = (), write_tags: Sequence = (),
             fetch_bids: Sequence = (),
             tag_writes_with_ts: bool = False) -> DirWaveResult:
        """Resolve one host's lease traffic for a tick.

        ``read_groups`` holds per-requester global block-id lists (the
        serving wave: one group per request).  ``write_bids`` get the
        jump-ahead plus a directory re-tag to the aligned ``write_tags``
        (or to the jump-ahead ts itself with ``tag_writes_with_ts`` -- the
        litmus stores, whose value IS the timestamp).  ``fetch_bids`` ask
        for page migration; each is implicitly read too, so the page
        carries the lease this wave just extended.  Pending publishes for
        every contacted shard ride the request message; shards holding
        only pends are contacted too (the flush may not wait for organic
        traffic that -- on a lease hit -- never materializes).
        """
        host = int(host)
        pts = int(pts)
        groups = [list(dict.fromkeys(int(b) for b in g))
                  for g in read_groups]
        write_bids = [int(b) for b in write_bids]
        fetch_bids = list(dict.fromkeys(int(b) for b in fetch_bids))
        if not tag_writes_with_ts and len(write_bids) != len(write_tags):
            raise ValueError("write_tags must align with write_bids")
        read_union = {b for g in groups for b in g}
        orphan_fetches = [b for b in fetch_bids if b not in read_union]
        if orphan_fetches:       # a migrated page always rides a fresh read
            groups.append(orphan_fetches)
        n_groups = len(groups)

        by_shard: Dict[int, dict] = {}

        def shard_entry(s: int) -> dict:
            return by_shard.setdefault(
                s, {"groups": [[] for _ in range(n_groups)], "writes": [],
                    "tags": [], "fetches": []})

        for g, bids in enumerate(groups):
            for b in bids:
                shard_entry(self.owner(b))["groups"][g].append(b)
        for i, b in enumerate(write_bids):
            e = shard_entry(self.owner(b))
            e["writes"].append(b)
            if not tag_writes_with_ts:
                e["tags"].append(int(write_tags[i]))
        for b in fetch_bids:
            shard_entry(self.owner(b))["fetches"].append(b)
        for s in self._pending.get(host, {}):
            shard_entry(s)
        contacted = sorted(by_shard)

        leases: Dict[int, Tuple[int, int]] = {}
        renew_ok: Dict[int, bool] = {}
        expired: Dict[int, bool] = {}
        write_ts: Dict[int, int] = {}
        fetched: Dict[int, FetchedPage] = {}
        group_pts = np.full(n_groups, pts, np.int64)
        new_pts = pts
        sizes = np.zeros((self.n_hosts, 2), np.int64)
        log = {"host": host, "kind": "wave", "shards": contacted,
               "remote_shards": 0, "msgs": 0, "flits": 0}

        for s in contacted:
            e = by_shard[s]
            eng = self.shards[s]
            n_ids = (len({b for g in e["groups"] for b in g})
                     + len(e["writes"]) + len(e["fetches"]))

            # 1) writes: jump-ahead + re-tag; home content is now stale
            if e["writes"]:
                slots = np.asarray([self.slot(b) for b in e["writes"]],
                                   np.int64)
                ts = eng.write(slots, pts)
                new_pts = max(new_pts, ts)
                for i, b in enumerate(e["writes"]):
                    write_ts[b] = ts
                    self.tags[b] = ts if tag_writes_with_ts \
                        else e["tags"][i]
                    self.wver[b] += 1
                if eng.has_kv:
                    eng.invalidate_kv(slots)

            # 2) pending publishes (after writes: a same-wave re-tag
            #    drops the stale payload instead of installing it)
            n_pend = self._apply_pends(host, s)

            # 3) reads/renewals: one batched read_many per shard
            slot_groups = [[self.slot(b) for b in g] for g in e["groups"]]
            have_reads = any(slot_groups)
            if have_reads:
                req = None
                if req_wts:
                    req = {self.slot(b): w for b, w in req_wts.items()
                           if self.owner(b) == s and w is not None}
                rm = eng.read_many(slot_groups, pts, req_wts=req or None)
                gids = np.asarray(
                    [self.gid_of(s, sl) for sl in rm.union_idx], np.int64)
                for j, b in enumerate(gids):
                    b = int(b)
                    leases[b] = (int(rm.wts[j]), int(rm.rts[j]))
                    renew_ok[b] = bool(rm.renew_ok[:, j].any())
                    expired[b] = bool(rm.expired[:, j].any())
                for g in range(n_groups):
                    group_pts[g] = max(group_pts[g], int(rm.new_pts[g]))
                    new_pts = max(new_pts, int(rm.new_pts[g]))

            # 4) fetches: migrate home pages under the lease just taken
            for b in e["fetches"]:
                sl = self.slot(b)
                if not eng.kv_ok(sl):
                    continue                      # no home copy: repair
                blocks = eng.read_kv(np.asarray([sl], np.int64))
                if not isinstance(blocks, Mapping):
                    blocks = {eng._single_pool(): blocks}
                w, r = leases[b]
                fetched[b] = FetchedPage(
                    gid=b, wts=w, rts=r, tag=int(self.tags[b]),
                    wver=int(self.wver[b]),
                    blocks={k: np.asarray(v) for k, v in blocks.items()},
                    pred_lease=int(eng.pred_lease[sl]))
                self.stats.migrations += 1

            # 5) charge the exchange (remote shards only)
            if self.shard_host(s) == host:
                continue
            n_read = sum(len(set(g)) for g in slot_groups if g) \
                if have_reads else 0
            n_fetch = sum(1 for b in e["fetches"] if b in fetched)
            # the predicted lease piggybacks on the existing reply (4 more
            # bytes per read entry); the static path charges as before
            read_entry = 12 if self.policy.predictor else 8
            req_flits = (1 + protocol.data_flits(4 * n_ids + 8)
                         + n_pend * protocol.data_flits(self.block_bytes))
            rep_flits = (1 + protocol.data_flits(read_entry * n_read + 8)
                         + n_fetch
                         * (1 + protocol.data_flits(self.block_bytes)))
            self.stats.req_msgs += 1
            self.stats.rep_msgs += 1
            self.stats.flits += req_flits + rep_flits
            sizes[self.shard_host(s)] += (req_flits, rep_flits)
            log["remote_shards"] += 1
            log["msgs"] += 2
            log["flits"] += req_flits + rep_flits

        if self._msan is not None:
            for b, page in fetched.items():
                self._msan.check_carried(page, leases[b],
                                         int(self.tags[b]))
        if self.transport is not None and sizes.any():
            self.transport.exchange(host % self.n_hosts, sizes)
        self.stats.waves += 1
        self.wave_log.append(log)
        return DirWaveResult(
            new_pts=new_pts, group_pts=group_pts[:len(read_groups)]
            if len(read_groups) else group_pts,
            leases=leases, renew_ok=renew_ok, expired=expired,
            write_ts=write_ts, fetched=fetched, msgs=log["msgs"],
            shards_contacted=log["remote_shards"])

    def publish_barrier(self) -> None:
        """A weight publish swept the fleet: every home payload was
        computed under the OLD weights.  Invalidate every home slot (a
        manager-side bitmap clear per shard -- zero messages, tags and
        lease metadata stay) and bump every content version so queued
        write-behind publishes of old-weight payloads drop at flush."""
        for eng in self.shards:
            if eng.has_kv:
                eng.invalidate_kv(np.arange(eng.n_blocks))
        self.wver += 1

    # -- wraparound guard ----------------------------------------------------

    def maybe_rebase(self) -> int:
        """One uniform shift for every shard: cross-shard timestamp order
        is protocol state, so shards never rebase independently."""
        max_ts = max((int(np.max(e.rts, initial=0)) for e in self.shards),
                     default=0)
        if not timestamps.rebase_needed(max_ts, 0, self.ts_bits):
            return 0
        shift = timestamps.rebase_amount(self.ts_bits)
        for eng in self.shards:
            eng.force_rebase(shift)
        self.ts_shift += shift
        self.rebases += 1
        return shift

    # -- reporting -----------------------------------------------------------

    def max_msgs_per_wave(self) -> int:
        return max((w["msgs"] for w in self.wave_log), default=0)

    def report(self) -> dict:
        st = self.stats
        waves = [w for w in self.wave_log if w["kind"] == "wave"]
        return {
            "xhost_shards": self.n_shards,
            "xhost_hosts": self.n_hosts,
            "xhost_waves": st.waves,
            "xhost_msgs": st.msgs,
            "xhost_req_msgs": st.req_msgs,
            "xhost_rep_msgs": st.rep_msgs,
            "xhost_flits": st.flits,
            "xhost_bytes": st.wire_bytes,
            "xhost_migrations": st.migrations,
            "xhost_publishes": st.publishes,
            "xhost_publishes_dropped": st.publishes_dropped,
            "xhost_watches": st.watches,
            "xhost_notifies": st.notifies,
            "xhost_multicasts": st.multicasts,
            "xhost_invalidation_msgs": st.invalidation_msgs,
            "xhost_max_msgs_per_wave": self.max_msgs_per_wave(),
            "xhost_max_shards_per_wave": max(
                (w["remote_shards"] for w in waves), default=0),
            "xhost_transport_routes": (self.transport.routes
                                       if self.transport else 0),
            "xhost_rebases": self.rebases,
            "xhost_pred_grows": sum(e.stats.pred_grows
                                    for e in self.shards),
            "xhost_pred_shrinks": sum(e.stats.pred_shrinks
                                      for e in self.shards),
            "xhost_sanitize_checks": self.sanitize_checks,
        }

    def broadcast_baseline(self, n_hosts: Optional[int] = None) -> dict:
        """Counterfactual: a conventional full-map directory multicasting
        INV to every sharer on each write and collecting INV_ACKs.  Every
        re-tag in this run would have been an O(sharers) fan-out; price it
        with every other host a sharer (the shared-prefix serving case --
        that is the point of sharing)."""
        n_hosts = self.n_hosts if n_hosts is None else int(n_hosts)
        writes = sum(e.stats.writes for e in self.shards)
        sharers = max(0, n_hosts - 1)
        inv = writes * sharers
        flits = inv * (protocol.MESSAGE_FLITS["INV"]
                       + protocol.MESSAGE_FLITS["INV_ACK"])
        return {
            "hosts": n_hosts,
            "writes": writes,
            "bcast_inv_msgs": inv * 2,           # INV out + INV_ACK back
            "bcast_inv_flits": flits,
            "bcast_inv_bytes": flits * protocol.FLIT_BYTES,
            "tardis_inv_msgs": 0,
            "tardis_msgs": self.stats.msgs,
            "tardis_flits": self.stats.flits,
        }
