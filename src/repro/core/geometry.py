"""Cache / NoC geometry shared by the Tardis and directory simulators.

The simulated machine mirrors the paper's Table V at reduced cache sizes
(traces are scaled down accordingly): per-core private L1, an address-
interleaved shared-LLC slice per core ("bank"), a 2-D mesh NoC with XY
routing, and per-bank memory controllers.
"""
from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp

INT_MAX = jnp.iinfo(jnp.int32).max


@dataclasses.dataclass(frozen=True)
class Geometry:
    """Static (compile-time) machine shape."""
    n_cores: int = 64
    l1_sets: int = 32
    l1_ways: int = 4
    llc_sets: int = 64          # sets per bank; one bank per core
    llc_ways: int = 4
    n_addr: int = 1 << 16       # DRAM image size (lines)
    trace_len: int = 0          # filled from the trace
    log_size: int = 0           # 0 = logging disabled

    @property
    def grid(self) -> int:
        return int(math.ceil(math.sqrt(self.n_cores)))

    @property
    def llc_sets_total(self) -> int:
        return self.n_cores * self.llc_sets


def core_xy(geom: Geometry, i):
    g = geom.grid
    return i % g, i // g


def hop_dist(geom: Geometry, a, b):
    """Manhattan distance between tiles a and b on the mesh."""
    ax, ay = core_xy(geom, a)
    bx, by = core_xy(geom, b)
    return jnp.abs(ax - bx) + jnp.abs(ay - by)


def addr_bank(geom: Geometry, addr):
    """Home LLC slice (== home timestamp manager) of an address."""
    return addr % geom.n_cores


def addr_llc_set(geom: Geometry, addr):
    """Global LLC set index: bank-major so one bank is a contiguous slab."""
    bank = addr_bank(geom, addr)
    return bank * geom.llc_sets + (addr // geom.n_cores) % geom.llc_sets


def addr_l1_set(geom: Geometry, addr):
    return addr % geom.l1_sets


def pick_way(tags, states, lrus, addr):
    """(hit, way) selection for one cache set.

    Returns the matching way on a hit, otherwise the fill victim:
    invalid ways first, then least-recently-used.  ``states`` is only used
    for validity (INVALID == 0).
    """
    valid = states != 0
    match = valid & (tags == addr)
    hit = match.any()
    hit_way = jnp.argmax(match)
    inv_way = jnp.argmax(~valid)
    has_inv = (~valid).any()
    lru_way = jnp.argmin(jnp.where(valid, lrus, INT_MAX))
    fill_way = jnp.where(has_inv, inv_way, lru_way)
    return hit, jnp.where(hit, hit_way, fill_way)


def pick_llc_victim(tags, states, lrus, owners, requester):
    """LLC fill-victim choice: invalid > shared-LRU > exclusive-LRU, and
    never a line exclusively owned by the requester mid-transaction."""
    valid = states != 0
    has_inv = (~valid).any()
    inv_way = jnp.argmax(~valid)
    # penalize exclusive lines, forbid requester-owned ones
    penalty = jnp.where(states == 2, 1 << 20, 0)
    penalty = jnp.where((states == 2) & (owners == requester), 1 << 29, penalty)
    score = jnp.where(valid, lrus + penalty, INT_MAX)
    lru_way = jnp.argmin(score)
    return jnp.where(has_inv, inv_way, lru_way)
