"""Vectorized multi-core coherence simulator (Tardis + directory baselines).

Execution model
---------------
An event-level model of the paper's Graphite setup: cores execute their trace
in program order; the global interleaving is produced by always stepping the
core with the smallest local clock (ties to the lowest id).  Each memory
operation is an *atomic transaction* against the cache hierarchy -- the
protocol transition, its latency, and its NoC traffic are computed in one
simulator step.  This keeps every protocol rule exact (timestamps, leases,
renewals, sharer sets, ...) while approximating only intra-transaction
concurrency, which affects both protocols identically.

The whole simulation is a single ``lax.while_loop`` over a dict-of-arrays
state, so it jit-compiles once per (geometry, protocol) and every paper knob
(lease, self-increment period, speculation, delta-ts width, Ackwise k, ...)
is a *traced* scalar -- parameter sweeps reuse the compiled step.

Approximations (documented in EXPERIMENTS.md):
  * spin loops poll with exponential backoff (1..backoff_cap cycles) purely to
    bound simulation steps; polls still count as cache accesses (self-inc),
  * speculation/OoO are modeled through effective latency (success hides the
    renewal round trip; failure pays round trip + flush penalty),
  * base-delta compression is an *accounting* model: arrays keep absolute
    timestamps, rebases charge their cost and invalidate long-expired
    private Shared lines exactly as the clamping rule would.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from . import protocol as P
from .geometry import (Geometry, INT_MAX, addr_bank, addr_l1_set,
                       addr_llc_set, hop_dist, pick_llc_victim, pick_way)
from .traces import BARRIER, END, LOAD, SPIN, STORE, Trace

I32 = jnp.int32
F32 = jnp.float32


@dataclasses.dataclass
class SimConfig:
    """Dynamic (traced) simulation parameters.  Defaults = paper Table V."""
    lease: int = 10
    selfinc_period: int = 100
    speculate: bool = True
    ooo_hide: int = 0            # >0 models an OoO window hiding miss latency
    private_write_opt: bool = True
    ts_bits: int = 20            # 0 disables compression accounting (64-bit)
    rebase_l1: int = 128         # cycles (128 ns @ 1 GHz)
    rebase_l2: int = 1024
    hop_cycles: int = 2
    llc_lat: int = 8
    dram_lat: int = 100
    flush_penalty: int = 8       # misspeculation rollback
    ackwise_k: int = 0           # directory only: 0 = full-map MSI
    estate: bool = False         # paper section IV-D: E-state extension
    spin_backoff_cap: int = 32
    barrier_cost: int = 4
    max_steps: int = 2_000_000

    def as_jnp(self) -> Dict[str, jnp.ndarray]:
        return {
            "lease": I32(self.lease),
            "period": I32(self.selfinc_period),
            "spec": I32(1 if self.speculate else 0),
            "ooo_hide": I32(self.ooo_hide),
            "pw_opt": I32(1 if self.private_write_opt else 0),
            "ts_bits": I32(self.ts_bits),
            "rebase_l1": I32(self.rebase_l1),
            "rebase_l2": I32(self.rebase_l2),
            "hop": I32(self.hop_cycles),
            "llc_lat": I32(self.llc_lat),
            "dram_lat": I32(self.dram_lat),
            "flush_pen": I32(self.flush_penalty),
            "ackwise_k": I32(self.ackwise_k),
            "estate": I32(1 if self.estate else 0),
            "backoff_cap": I32(self.spin_backoff_cap),
            "barrier_cost": I32(self.barrier_cost),
            "max_steps": I32(self.max_steps),
        }


STAT_KEYS = (
    "ops_done", "traffic", "msgs", "n_renew", "n_renew_ok", "n_misspec",
    "n_upgrade_ok", "n_llc_req", "n_dram", "n_ts_incr", "n_selfinc",
    "n_rebase_l1", "n_rebase_l2", "n_rebase_inval", "n_inv_msgs",
    "n_spin_polls", "n_l1_miss", "n_evict_msgs", "n_egrant",
)


def init_state(geom: Geometry, trace: Trace, cfg: Dict[str, jnp.ndarray],
               directory: bool):
    n, s1, w1 = geom.n_cores, geom.l1_sets, geom.l1_ways
    s2, w2 = geom.llc_sets_total, geom.llc_ways
    def zeros(*sh):
        return jnp.zeros(sh, I32)

    st = {
        "cfg": cfg,
        # core state
        "clock": zeros(n), "pts": jnp.ones((n,), I32), "idx": zeros(n),
        "done": jnp.zeros((n,), bool), "blocked": jnp.zeros((n,), bool),
        "arrived": jnp.zeros((n,), bool), "acc": zeros(n),
        "spin_iter": zeros(n),
        # private L1
        "l1_tag": jnp.full((n, s1, w1), -1, I32), "l1_st": zeros(n, s1, w1),
        "l1_wts": zeros(n, s1, w1), "l1_rts": zeros(n, s1, w1),
        "l1_ver": zeros(n, s1, w1), "l1_dirty": jnp.zeros((n, s1, w1), bool),
        "l1_lru": zeros(n, s1, w1),
        # shared LLC (banked)
        "llc_tag": jnp.full((s2, w2), -1, I32), "llc_st": zeros(s2, w2),
        "llc_wts": zeros(s2, w2), "llc_rts": zeros(s2, w2),
        "llc_owner": jnp.full((s2, w2), -1, I32), "llc_ver": zeros(s2, w2),
        "llc_dirty": jnp.zeros((s2, w2), bool), "llc_lru": zeros(s2, w2),
        "llc_acc": jnp.zeros((s2, w2), bool),   # accessed-since-fill (E ext.)
        # DRAM image + per-bank memory timestamp + global store counters
        "mem_ver": zeros(geom.n_addr), "mts": jnp.ones((n,), I32),
        "store_count": zeros(geom.n_addr),
        # timestamp-compression accounting
        "bts_l1": zeros(n), "bts_llc": zeros(n),
        "maxts_l1": zeros(n), "maxts_llc": zeros(n),
        # traces
        "op_type": jnp.asarray(trace.op_type), "op_addr": jnp.asarray(trace.op_addr),
        "op_aux": jnp.asarray(trace.op_aux), "op_think": jnp.asarray(trace.op_think),
        "lru_clock": I32(0), "steps": I32(0), "aborted": jnp.zeros((), bool),
        "stats": {k: F32(0.0) for k in STAT_KEYS},
    }
    if directory:
        st["sharers"] = jnp.zeros((s2, w2, n), bool)
    if geom.log_size:
        def z():
            return jnp.zeros((geom.log_size,), I32)

        st["log"] = {"core": z(), "kind": z(), "addr": z(), "ts": z(),
                     "ver": z(), "n": I32(0)}
    return st


def _bump(stats, **deltas):
    out = dict(stats)
    for k, v in deltas.items():
        out[k] = stats[k] + F32(0) + jnp.asarray(v, F32)
    return out


# ---------------------------------------------------------------------------
# Tardis memory transaction (Tables II & III)
# ---------------------------------------------------------------------------

def tardis_mem(geom: Geometry, st, i, addr, is_store, active):
    """One load/store transaction under Tardis.

    Returns (new_state, latency, op_ts, observed_version).
    All state updates are masked by ``active``.
    """
    cfg = st["cfg"]
    lease, spec = cfg["lease"], cfg["spec"]
    now = st["lru_clock"]
    pts = st["pts"][i]
    is_load = ~is_store

    # ---- L1 lookup -------------------------------------------------------
    set1 = addr_l1_set(geom, addr)
    tags1 = st["l1_tag"][i, set1]
    sts1 = st["l1_st"][i, set1]
    lrus1 = st["l1_lru"][i, set1]
    hit1, way1 = pick_way(tags1, sts1, lrus1, addr)
    line_st = sts1[way1]
    line_wts = st["l1_wts"][i, set1, way1]
    line_rts = st["l1_rts"][i, set1, way1]
    line_ver = st["l1_ver"][i, set1, way1]
    line_dirty = st["l1_dirty"][i, set1, way1]

    expired = hit1 & (line_st == P.SHARED) & (pts > line_rts)
    l1_ok = jnp.where(
        is_store,
        hit1 & (line_st == P.EXCLUSIVE),
        hit1 & ((line_st == P.EXCLUSIVE)
                | ((line_st == P.SHARED) & (pts <= line_rts))))
    needs_llc = active & ~l1_ok
    renewal = needs_llc & is_load & expired

    # ---- LLC lookup ------------------------------------------------------
    bank = addr_bank(geom, addr)
    gset = addr_llc_set(geom, addr)
    tagsL = st["llc_tag"][gset]
    stsL = st["llc_st"][gset]
    lrusL = st["llc_lru"][gset]
    ownersL = st["llc_owner"][gset]
    hitL, wayL_hit = pick_way(tagsL, stsL, lrusL, addr)
    victimL = pick_llc_victim(tagsL, stsL, lrusL, ownersL, i)
    wayL = jnp.where(hitL, wayL_hit, victimL)
    L_st = stsL[wayL]
    L_wts = st["llc_wts"][gset, wayL]
    L_rts = st["llc_rts"][gset, wayL]
    L_ver = st["llc_ver"][gset, wayL]
    L_dirty = st["llc_dirty"][gset, wayL]
    L_acc = st["llc_acc"][gset, wayL]
    L_tag = tagsL[wayL]
    owned = hitL & (L_st == P.EXCLUSIVE)
    owner = ownersL[wayL]
    missL = needs_llc & ~hitL
    # E-state extension (paper IV-D): a load on a line nobody has touched
    # since it entered the LLC is granted exclusively -- it will never
    # expire or renew while private.
    grant_e = (needs_llc & is_load & (cfg["estate"] == 1)
               & (missL | (hitL & (L_st == P.SHARED) & ~L_acc)))

    # ---- LLC victim eviction (fill path only) ----------------------------
    v_valid = missL & (L_st != P.INVALID)          # wayL is the victim slot
    v_owned = v_valid & (L_st == P.EXCLUSIVE)
    v_owner = jnp.where(v_owned, owner, 0)
    vset1 = addr_l1_set(geom, L_tag)
    vo_tags = st["l1_tag"][v_owner, vset1]
    vo_sts = st["l1_st"][v_owner, vset1]
    vo_hit, vo_way = pick_way(vo_tags, vo_sts,
                              st["l1_lru"][v_owner, vset1], L_tag)
    vo_flush = v_owned & vo_hit
    vo_rts = st["l1_rts"][v_owner, vset1, vo_way]
    vo_ver = st["l1_ver"][v_owner, vset1, vo_way]
    vo_dirty = st["l1_dirty"][v_owner, vset1, vo_way]
    victim_rts = jnp.where(vo_flush, vo_rts, L_rts)
    victim_ver = jnp.where(vo_flush, vo_ver, L_ver)
    victim_dirty = jnp.where(vo_flush, vo_dirty | L_dirty, L_dirty)
    # flush the victim-owner's L1 copy
    l1_st_a = st["l1_st"].at[v_owner, vset1, vo_way].set(
        jnp.where(vo_flush, P.INVALID, st["l1_st"][v_owner, vset1, vo_way]))
    # DRAM writeback + mts fold
    vaddr = jnp.where(v_valid, L_tag, 0)
    mem_ver = st["mem_ver"].at[vaddr].set(
        jnp.where(v_valid & victim_dirty, victim_ver, st["mem_ver"][vaddr]))
    mts = st["mts"].at[bank].set(
        jnp.where(v_valid, jnp.maximum(st["mts"][bank], victim_rts),
                  st["mts"][bank]))
    mts_bank = mts[bank]

    # ---- owner write-back / flush for the requested line ------------------
    o_tags = st["l1_tag"][owner, set1]
    o_sts = st["l1_st"][owner, set1]
    o_hit, o_way = pick_way(o_tags, o_sts, st["l1_lru"][owner, set1], addr)
    o_act = needs_llc & owned & o_hit              # invariant: holds when owned
    o_wts = st["l1_wts"][owner, set1, o_way]
    o_rts = st["l1_rts"][owner, set1, o_way]
    o_ver = st["l1_ver"][owner, set1, o_way]
    wb_rts = P.writeback_rts(o_wts, o_rts, pts, lease)
    # load -> WB_REQ: owner downgrades to Shared with extended rts
    # store -> FLUSH_REQ: owner invalidates
    o_new_st = jnp.where(is_store, P.INVALID, P.SHARED)
    l1_st_a = l1_st_a.at[owner, set1, o_way].set(
        jnp.where(o_act, o_new_st, l1_st_a[owner, set1, o_way]))
    l1_rts_a = st["l1_rts"].at[owner, set1, o_way].set(
        jnp.where(o_act & is_load, wb_rts, o_rts))

    # ---- grant values the manager serves ----------------------------------
    g_wts = jnp.where(owned, o_wts, jnp.where(hitL, L_wts, mts_bank))
    g_rts_raw = jnp.where(owned, jnp.where(is_load, wb_rts, o_rts),
                          jnp.where(hitL, L_rts, mts_bank))
    g_ver = jnp.where(owned, o_ver, jnp.where(hitL, L_ver, st["mem_ver"][addr]))
    g_dirty = jnp.where(owned, True, jnp.where(hitL, L_dirty, False))
    new_llc_rts = P.lease_extend(g_wts, g_rts_raw, pts, lease)
    renew_ok = renewal & (line_wts == g_wts)
    upgrade_ok = needs_llc & is_store & hit1 & (line_wts == g_wts) & ~owned & hitL

    # ---- LLC line update ---------------------------------------------------
    upd = needs_llc
    excl_grant = is_store | grant_e
    llc_tag = st["llc_tag"].at[gset, wayL].set(jnp.where(upd, addr, L_tag))
    llc_st = st["llc_st"].at[gset, wayL].set(
        jnp.where(upd, jnp.where(excl_grant, P.EXCLUSIVE, P.SHARED), L_st))
    llc_wts = st["llc_wts"].at[gset, wayL].set(jnp.where(upd, g_wts, L_wts))
    llc_rts = st["llc_rts"].at[gset, wayL].set(
        jnp.where(upd, jnp.where(is_load, new_llc_rts, g_rts_raw), L_rts))
    llc_owner = st["llc_owner"].at[gset, wayL].set(
        jnp.where(upd & excl_grant, i, jnp.where(upd, -1, ownersL[wayL])))
    llc_acc = st["llc_acc"].at[gset, wayL].set(
        jnp.where(upd, True, L_acc))
    llc_ver = st["llc_ver"].at[gset, wayL].set(jnp.where(upd, g_ver, L_ver))
    llc_dirty = st["llc_dirty"].at[gset, wayL].set(
        jnp.where(upd, g_dirty & is_load, L_dirty))
    llc_lru = st["llc_lru"].at[gset, wayL].set(jnp.where(upd, now, lrusL[wayL]))

    # ---- L1 victim write-back (Exclusive lines flush to their LLC slot) ---
    fill = needs_llc & ~hit1
    v1_tag = tags1[way1]
    v1_st = sts1[way1]
    v1_valid = fill & (v1_st != P.INVALID)
    v1_excl = v1_valid & (v1_st == P.EXCLUSIVE)
    v1_wts = st["l1_wts"][i, set1, way1]
    v1_rts = st["l1_rts"][i, set1, way1]
    v1_ver = st["l1_ver"][i, set1, way1]
    gsetv1 = addr_llc_set(geom, v1_tag)
    bankv1 = addr_bank(geom, v1_tag)
    tv1 = llc_tag[gsetv1]
    sv1 = llc_st[gsetv1]
    hv1, wv1 = pick_way(tv1, sv1, llc_lru[gsetv1], v1_tag)
    v1_to_llc = v1_excl & hv1
    v1_to_dram = v1_excl & ~hv1
    llc_st = llc_st.at[gsetv1, wv1].set(
        jnp.where(v1_to_llc, P.SHARED, llc_st[gsetv1, wv1]))
    llc_wts = llc_wts.at[gsetv1, wv1].set(
        jnp.where(v1_to_llc, v1_wts, llc_wts[gsetv1, wv1]))
    llc_rts = llc_rts.at[gsetv1, wv1].set(
        jnp.where(v1_to_llc, v1_rts, llc_rts[gsetv1, wv1]))
    llc_ver = llc_ver.at[gsetv1, wv1].set(
        jnp.where(v1_to_llc, v1_ver, llc_ver[gsetv1, wv1]))
    llc_dirty = llc_dirty.at[gsetv1, wv1].set(
        jnp.where(v1_to_llc, True, llc_dirty[gsetv1, wv1]))
    # a written-back line has no sharers left: next toucher may take it E
    llc_acc = llc_acc.at[gsetv1, wv1].set(
        jnp.where(v1_to_llc, False, llc_acc[gsetv1, wv1]))
    mem_ver = mem_ver.at[jnp.where(v1_to_dram, v1_tag, 0)].set(
        jnp.where(v1_to_dram, v1_ver, mem_ver[jnp.where(v1_to_dram, v1_tag, 0)]))
    mts = mts.at[bankv1].set(
        jnp.where(v1_to_dram, jnp.maximum(mts[bankv1], v1_rts), mts[bankv1]))

    # ---- requester L1 + timestamps ----------------------------------------
    new_ver = st["store_count"][addr] + 1
    pw = (cfg["pw_opt"] == 1) & line_dirty
    ts_hitE = jnp.where(pw, jnp.maximum(pts, line_rts),
                        jnp.maximum(pts, line_rts + 1))
    ts_fill = jnp.maximum(pts, g_rts_raw + 1)
    store_ts = jnp.where(l1_ok, ts_hitE, ts_fill)
    obs_wts = jnp.where(l1_ok | renew_ok, line_wts, g_wts)
    load_pts = jnp.maximum(pts, obs_wts)
    new_pts = jnp.where(active, jnp.where(is_store, store_ts, load_pts), pts)
    op_ts = new_pts

    # final L1 line (requester)
    f_st = jnp.where(is_store | grant_e, P.EXCLUSIVE,
                     jnp.where(l1_ok, line_st, P.SHARED))
    f_wts = jnp.where(is_store, store_ts, jnp.where(l1_ok, line_wts, g_wts))
    # loads: E-hit tracks own last read; S keeps lease / takes the new lease
    rts_ehit = jnp.maximum(load_pts, line_rts)
    f_rts_load = jnp.where(
        l1_ok & (line_st == P.EXCLUSIVE), rts_ehit,
        jnp.where(l1_ok, line_rts,
                  jnp.where(grant_e, jnp.maximum(load_pts, g_rts_raw),
                            new_llc_rts)))
    f_rts = jnp.where(is_store, store_ts, f_rts_load)
    f_ver = jnp.where(is_store, new_ver,
                      jnp.where(l1_ok | renew_ok, line_ver, g_ver))
    f_dirty = jnp.where(is_store, True,
                        jnp.where(l1_ok | renew_ok, line_dirty, False))
    sel = active
    l1_tag = st["l1_tag"].at[i, set1, way1].set(jnp.where(sel, addr, tags1[way1]))
    l1_st_a = l1_st_a.at[i, set1, way1].set(
        jnp.where(sel, f_st, l1_st_a[i, set1, way1]))
    l1_wts = st["l1_wts"].at[i, set1, way1].set(
        jnp.where(sel, f_wts, st["l1_wts"][i, set1, way1]))
    l1_rts_a = l1_rts_a.at[i, set1, way1].set(
        jnp.where(sel, f_rts, l1_rts_a[i, set1, way1]))
    l1_ver = st["l1_ver"].at[i, set1, way1].set(
        jnp.where(sel, f_ver, st["l1_ver"][i, set1, way1]))
    l1_dirty = st["l1_dirty"].at[i, set1, way1].set(
        jnp.where(sel, f_dirty, st["l1_dirty"][i, set1, way1]))
    l1_lru = st["l1_lru"].at[i, set1, way1].set(
        jnp.where(sel, now, st["l1_lru"][i, set1, way1]))
    store_count = st["store_count"].at[addr].set(
        jnp.where(sel & is_store, new_ver, st["store_count"][addr]))
    ver_obs = jnp.where(is_store, new_ver,
                        jnp.where(l1_ok | renew_ok, line_ver, g_ver))

    # ---- latency & traffic -------------------------------------------------
    hop = cfg["hop"]
    d_ib = hop_dist(geom, i, bank)
    d_bo = hop_dist(geom, bank, owner)
    d_bvo = hop_dist(geom, bank, v_owner)
    d_ibv1 = hop_dist(geom, i, bankv1)
    llc_leg = 2 * hop * d_ib + cfg["llc_lat"]
    owner_leg = jnp.where(owned, 2 * hop * d_bo + 1, 0)
    vflush_leg = jnp.where(vo_flush, 2 * hop * d_bvo + 1, 0)
    dram_leg = jnp.where(missL, cfg["dram_lat"] + vflush_leg, 0)
    lat_full = llc_leg + owner_leg + dram_leg
    lat_exposed = jnp.maximum(1, lat_full - cfg["ooo_hide"])
    lat = jnp.where(
        ~needs_llc, 1,
        jnp.where(renewal & renew_ok & (spec == 1), 1,
                  jnp.where(renewal & ~renew_ok,
                            lat_exposed + spec * cfg["flush_pen"],
                            lat_exposed)))

    # paper section VI-B-2: a successful renewal is a single-flit message
    reply_flits = jnp.where(is_load,
                            jnp.where(renew_ok, 1, 6),
                            jnp.where(upgrade_ok, 1, 6))
    traffic = jnp.where(needs_llc, (2 + reply_flits) * d_ib, 0)
    traffic += jnp.where(o_act,
                         jnp.where(is_load, (2 + 6) * d_bo, (1 + 6) * d_bo), 0)
    traffic += jnp.where(missL, 1 + 5, 0)                       # DRAM ld
    traffic += jnp.where(v_valid & victim_dirty, 5, 0)          # DRAM st
    traffic += jnp.where(vo_flush, (1 + 6) * d_bvo, 0)
    traffic += jnp.where(v1_to_llc, 6 * d_ibv1, 0)
    traffic += jnp.where(v1_to_dram, 6 * d_ibv1 + 5, 0)
    msgs = (jnp.where(needs_llc, 2, 0) + jnp.where(o_act, 2, 0)
            + jnp.where(missL, 2, 0) + jnp.where(vo_flush, 2, 0)
            + jnp.where(v1_excl, 1, 0) + jnp.where(v_valid & victim_dirty, 1, 0))

    # ---- timestamp-compression accounting ----------------------------------
    use_comp = cfg["ts_bits"] > 0
    thr = jnp.int32(1) << jnp.minimum(cfg["ts_bits"], 30)
    maxts_l1 = st["maxts_l1"].at[i].max(
        jnp.where(sel, jnp.maximum(f_wts, f_rts), 0))
    maxts_llc = st["maxts_llc"].at[bank].max(
        jnp.where(upd, jnp.maximum(g_wts, new_llc_rts), 0))
    reb1 = use_comp & sel & ((maxts_l1[i] - st["bts_l1"][i]) >= thr)
    reb2 = use_comp & upd & ((maxts_llc[bank] - st["bts_llc"][bank]) >= thr)
    half = thr // 2
    new_bts1 = st["bts_l1"][i] + half
    bts_l1 = st["bts_l1"].at[i].set(jnp.where(reb1, new_bts1, st["bts_l1"][i]))
    bts_llc = st["bts_llc"].at[bank].set(
        jnp.where(reb2, st["bts_llc"][bank] + half, st["bts_llc"][bank]))
    # invalidate long-expired private Shared lines (delta would go negative)
    kill = (reb1 & (l1_st_a[i] == P.SHARED) & (l1_rts_a[i] < new_bts1))
    l1_st_a = l1_st_a.at[i].set(jnp.where(kill, P.INVALID, l1_st_a[i]))
    lat = lat + jnp.where(reb1, cfg["rebase_l1"], 0) \
              + jnp.where(reb2, cfg["rebase_l2"], 0)

    stats = _bump(
        st["stats"],
        traffic=jnp.where(active, traffic, 0),
        msgs=jnp.where(active, msgs, 0),
        n_renew=renewal, n_renew_ok=renew_ok,
        n_misspec=renewal & ~renew_ok & (spec == 1),
        n_upgrade_ok=upgrade_ok,
        n_llc_req=needs_llc, n_dram=missL,
        n_ts_incr=jnp.where(active, new_pts - pts, 0),
        n_rebase_l1=reb1, n_rebase_l2=reb2,
        n_rebase_inval=jnp.where(reb1, jnp.sum(kill), 0),
        n_l1_miss=needs_llc & ~renewal,
        n_egrant=grant_e,
    )

    new_st = dict(st, l1_tag=l1_tag, l1_st=l1_st_a, l1_wts=l1_wts,
                  l1_rts=l1_rts_a, l1_ver=l1_ver, l1_dirty=l1_dirty,
                  l1_lru=l1_lru, llc_tag=llc_tag, llc_st=llc_st,
                  llc_wts=llc_wts, llc_rts=llc_rts, llc_owner=llc_owner,
                  llc_ver=llc_ver, llc_dirty=llc_dirty, llc_lru=llc_lru,
                  llc_acc=llc_acc, mem_ver=mem_ver, mts=mts,
                  store_count=store_count, bts_l1=bts_l1, bts_llc=bts_llc,
                  maxts_l1=maxts_l1, maxts_llc=maxts_llc, stats=stats)
    new_st["pts"] = st["pts"].at[i].set(new_pts)
    return new_st, lat, op_ts, ver_obs


# ---------------------------------------------------------------------------
# Scheduler harness: min-clock interleaving, barriers, spins, self-increment
# ---------------------------------------------------------------------------

def _make_step(geom: Geometry, mem_fn):
    trace_last = geom.trace_len - 1

    def step(st):
        cfg = st["cfg"]
        runnable = ~st["done"] & ~st["blocked"]
        none_runnable = ~runnable.any()
        i = jnp.argmin(jnp.where(runnable, st["clock"], INT_MAX))
        j = jnp.clip(st["idx"][i], 0, trace_last)
        ty = st["op_type"][i, j]
        addr = jnp.clip(st["op_addr"][i, j], 0, geom.n_addr - 1)
        aux = st["op_aux"][i, j]
        think = st["op_think"][i, j]

        is_end = (ty == END) | none_runnable
        is_barrier = (ty == BARRIER) & ~none_runnable
        is_spin = (ty == SPIN) & ~none_runnable
        is_store = (ty == STORE) & ~none_runnable
        is_mem = ((ty == LOAD) | is_store | is_spin) & ~none_runnable

        st = dict(st, lru_clock=st["lru_clock"] + 1)
        st2, lat, op_ts, ver_obs = mem_fn(geom, st, i, addr, is_store, is_mem)

        # ---- spin resolution with exponential poll backoff ----------------
        spin_ok = ver_obs >= aux
        spin_fail = is_spin & ~spin_ok
        backoff = jnp.minimum(
            cfg["backoff_cap"],
            jnp.int32(1) << jnp.minimum(st["spin_iter"][i], 8))
        spin_iter = st["spin_iter"].at[i].set(
            jnp.where(spin_fail, st["spin_iter"][i] + 1, 0))

        # ---- self-increment (livelock avoidance, paper III-E) -------------
        # A backed-off poll stands in for `backoff` single-cycle polls that
        # real hardware would have issued, so credit the access counter
        # accordingly (keeps the self-increment *rate per cycle* faithful).
        credit = jnp.where(is_mem, 1, 0) + jnp.where(spin_fail, backoff, 0)
        acc1 = st2["acc"][i] + credit
        n_inc = acc1 // jnp.maximum(cfg["period"], 1)
        selfinc = is_mem & (n_inc > 0)
        acc = st2["acc"].at[i].set(
            jnp.where(selfinc, acc1 % jnp.maximum(cfg["period"], 1), acc1))
        pts = st2["pts"].at[i].add(jnp.where(selfinc, n_inc, 0))

        # ---- clock / idx advance -------------------------------------------
        new_clock_i = (st["clock"][i] + think
                       + jnp.where(is_mem, lat, 0)
                       + jnp.where(spin_fail, backoff, 0))
        clock = st2["clock"].at[i].set(new_clock_i)
        advance = (is_mem & ~spin_fail) | is_end
        idx = st2["idx"].at[i].add(jnp.where(advance & ~none_runnable, 1, 0))
        done = st2["done"].at[i].set(st2["done"][i] | (is_end & ~none_runnable))
        done = jnp.where(none_runnable, jnp.ones_like(done), done)

        # ---- barrier ---------------------------------------------------------
        arrived = st2["arrived"].at[i].set(st2["arrived"][i] | is_barrier)
        blocked = st2["blocked"].at[i].set(st2["blocked"][i] | is_barrier)
        all_arr = jnp.all(arrived | done)
        release = is_barrier & all_arr
        rel_clock = jnp.max(jnp.where(arrived, clock, 0)) + cfg["barrier_cost"]
        clock = jnp.where(release & arrived, rel_clock, clock)
        idx = jnp.where(release & arrived, idx + 1, idx)
        blocked = jnp.where(release, jnp.zeros_like(blocked), blocked)
        arrived = jnp.where(release, jnp.zeros_like(arrived), arrived)

        stats = _bump(st2["stats"],
                      ops_done=advance & is_mem,
                      n_selfinc=jnp.where(selfinc, n_inc, 0),
                      n_ts_incr=jnp.where(selfinc, n_inc, 0),
                      n_spin_polls=is_spin)
        out = dict(st2, clock=clock, pts=pts, idx=idx, done=done,
                   blocked=blocked, arrived=arrived, acc=acc,
                   spin_iter=spin_iter, stats=stats,
                   steps=st["steps"] + 1,
                   aborted=st["aborted"] | none_runnable)

        if geom.log_size:
            log = st2["log"]
            n = log["n"]
            w = jnp.clip(n, 0, geom.log_size - 1)
            ok = is_mem & (n < geom.log_size)
            def upd(a, v):
                return a.at[w].set(jnp.where(ok, v, a[w]))

            out["log"] = {
                "core": upd(log["core"], i),
                "kind": upd(log["kind"], jnp.where(is_store, 1, 0)),
                "addr": upd(log["addr"], addr),
                "ts": upd(log["ts"], op_ts),
                "ver": upd(log["ver"], ver_obs),
                "n": n + jnp.where(ok, 1, 0),
            }
        return out

    return step


_RUNNERS = {}


def _get_runner(geom: Geometry, proto: str):
    key = (geom, proto)
    if key not in _RUNNERS:
        if proto == "tardis":
            mem_fn = tardis_mem
        elif proto == "directory":
            from .directory import directory_mem
            mem_fn = directory_mem
        else:
            raise ValueError(f"unknown protocol {proto!r}")
        step = _make_step(geom, mem_fn)

        def run(st0):
            def cond(st):
                return (~jnp.all(st["done"])) & (st["steps"] < st["cfg"]["max_steps"])
            return jax.lax.while_loop(cond, step, st0)

        _RUNNERS[key] = jax.jit(run)
    return _RUNNERS[key]


@dataclasses.dataclass
class SimResult:
    stats: Dict[str, float]
    cycles: int
    ops: int
    aborted: bool
    pts: np.ndarray
    log: Dict[str, np.ndarray] | None

    @property
    def throughput(self) -> float:
        return self.ops / max(1, self.cycles)

    @property
    def traffic(self) -> float:
        return self.stats["traffic"]


def simulate(trace: Trace, proto: str = "tardis",
             config: SimConfig | None = None,
             geom: Geometry | None = None,
             log: bool = False) -> SimResult:
    """Run one trace under one protocol; returns stats (+ optional op log)."""
    config = config or SimConfig()
    if geom is None:
        geom = Geometry(n_cores=trace.n_cores)
    log_size = 0
    if log:
        # spin polls can multiply the op count; leave generous headroom
        log_size = int(min(config.max_steps, trace.total_ops() * 8 + 4096))
    geom = dataclasses.replace(
        geom, n_cores=trace.n_cores, trace_len=trace.length,
        n_addr=max(geom.n_addr, int(trace.n_addr)), log_size=log_size)
    cfg = config.as_jnp()
    st0 = init_state(geom, trace, cfg, directory=(proto == "directory"))
    out = _get_runner(geom, proto)(st0)
    out = jax.device_get(out)
    stats = {k: float(v) for k, v in out["stats"].items()}
    active = np.asarray(out["idx"]) > 0
    cycles = int(np.max(np.where(active, np.asarray(out["clock"]), 0)))
    res_log = None
    if log:
        n = int(out["log"]["n"])
        res_log = {k: np.asarray(v[:n]) for k, v in out["log"].items()
                   if k != "n"}
    return SimResult(stats=stats, cycles=cycles,
                     ops=int(stats["ops_done"]), aborted=bool(out["aborted"]),
                     pts=np.asarray(out["pts"]), log=res_log)
