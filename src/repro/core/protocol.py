"""Tardis protocol rules (paper Tables I-III) as pure, branchless JAX functions.

Every rule here is a direct transcription of the timestamp-management tables in
the paper.  They are shared by three consumers:

  * ``repro.core.simulator``  -- the multi-core cache-hierarchy simulator,
  * ``repro.core.store``      -- the host-level TardisStore (params / KV blocks),
  * ``repro.kernels.tardis_lease`` -- the batched Pallas metadata kernel
    (``ref.py`` calls straight into these functions as the oracle).

All functions are scalar-shaped jnp expressions; they vmap/vectorize freely.
Timestamps are int32 logical counters (the *compressed* on-chip representation
is handled by :mod:`repro.core.timestamps`).
"""
from __future__ import annotations

import jax.numpy as jnp

# Cache-line / block states (shared by private cache and timestamp manager).
INVALID = 0
SHARED = 1
EXCLUSIVE = 2  # paper's Exclusive == owned/modified (MSI "M" merged)

# ---------------------------------------------------------------------------
# Table I -- Tardis without private memory
# ---------------------------------------------------------------------------

def load_no_cache(pts, wts, rts):
    """Load served directly by the timestamp manager (Table I, column 1).

    Returns (new_pts, new_rts).  ``pts <- max(pts, wts)``; the line's read
    timestamp records the latest read: ``rts <- max(pts, rts)``.
    """
    new_pts = jnp.maximum(pts, wts)
    new_rts = jnp.maximum(new_pts, rts)
    return new_pts, new_rts


def store_no_cache(pts, wts, rts):
    """Store served directly by the timestamp manager (Table I, column 2).

    The writer jumps ahead of every read lease: ``pts <- max(pts, rts + 1)``,
    and the new version is valid exactly from that instant (wts = rts = pts).
    Returns (new_pts, new_wts, new_rts).
    """
    new_pts = jnp.maximum(pts, rts + 1)
    return new_pts, new_pts, new_pts


# ---------------------------------------------------------------------------
# Table II -- private-cache transitions
# ---------------------------------------------------------------------------

def load_hit_shared(pts, wts):
    """L1 load hit on an unexpired Shared line: pts <- max(pts, wts)."""
    return jnp.maximum(pts, wts)


def load_hit_exclusive(pts, wts, rts):
    """L1 load hit on an Exclusive line.

    ``pts <- max(pts, wts)``; ``rts <- max(pts, rts)`` (the owner tracks its
    own last read).  Returns (new_pts, new_rts).
    """
    new_pts = jnp.maximum(pts, wts)
    new_rts = jnp.maximum(new_pts, rts)
    return new_pts, new_rts


def store_hit_exclusive(pts, rts):
    """L1 store hit on an Exclusive line (Table II, store column).

    The write must be ordered after the last read of the old version:
    ``ts = max(pts, rts + 1)``; wts = rts = ts.  Returns (new_pts, new_wts,
    new_rts).
    """
    ts = jnp.maximum(pts, rts + 1)
    return ts, ts, ts


def store_hit_private(pts, rts):
    """Private-write optimization (paper section IV-C).

    If the line's *modified* bit is already set (this core wrote it before and
    nobody else observed it), repeated stores need not advance logical time:
    ``ts = max(pts, rts)`` -- physical time orders them implicitly.
    """
    ts = jnp.maximum(pts, rts)
    return ts, ts, ts


def shared_expired(pts, rts):
    """True when a Shared line's lease has run out for this core (pts > rts)."""
    return pts > rts


def writeback_rts(line_wts, line_rts, req_pts, lease):
    """Owner-side rts update on WB_REQ (Table II, last column).

    The timestamp manager asks for ``reqM.rts = req_pts + lease``; the owner
    extends to ``max(D.rts, D.wts + lease, reqM.rts)`` and downgrades to
    Shared, keeping the line readable locally until the new lease expires.
    """
    return jnp.maximum(jnp.maximum(line_rts, line_wts + lease),
                       req_pts + lease)


# ---------------------------------------------------------------------------
# Table III -- timestamp-manager transitions
# ---------------------------------------------------------------------------

def lease_extend(llc_wts, llc_rts, req_pts, lease):
    """SH_REQ on a Shared LLC line: new end-of-lease timestamp.

    ``D.rts <- max(D.rts, D.wts + lease, reqM.pts + lease)``.
    """
    return jnp.maximum(jnp.maximum(llc_rts, llc_wts + lease),
                       req_pts + lease)


def renewable(req_wts, llc_wts):
    """A renewal succeeds without a data payload iff the requester's cached
    version matches the manager's (RENEW_REP / UPGRADE_REP path)."""
    return req_wts == llc_wts


def dram_fill_ts(mts):
    """Line loaded from DRAM: wts = rts = mts (Table III, DRAM_REP column)."""
    return mts, mts


def evict_mts(mts, line_rts):
    """LLC eviction folds the line's read lease into the per-manager mts."""
    return jnp.maximum(mts, line_rts)


# ---------------------------------------------------------------------------
# Derived helpers used by the batched store / kernel paths
# ---------------------------------------------------------------------------

def batched_read_check(pts, wts, rts):
    """Vectorized lease check for a block table.

    Given a reader's ``pts`` (scalar or broadcastable) and per-block (wts,
    rts), returns (readable, new_pts) where ``readable`` marks blocks whose
    lease covers ``pts`` and ``new_pts`` is the reader's program timestamp
    after consuming every readable block (max over their wts).
    """
    readable = pts <= rts
    consumed = jnp.where(readable, wts, 0)
    new_pts = jnp.maximum(pts, jnp.max(consumed, initial=0))
    return readable, new_pts


def batched_write_advance(pts, rts, mask):
    """Vectorized jump-ahead for a set of blocks being written.

    The writer's new pts clears every masked block's read lease:
    ``pts' = max(pts, max_i(rts_i) + 1)``; each written block gets
    wts = rts = pts'.  Returns (new_pts, new_wts, new_rts) with the
    timestamps broadcast over the mask.
    """
    top = jnp.max(jnp.where(mask, rts, -1), initial=-1)
    new_pts = jnp.maximum(pts, top + 1)
    new_wts = jnp.where(mask, new_pts, 0)
    new_rts = jnp.where(mask, new_pts, 0)
    return new_pts, new_wts, new_rts


# 128-bit network flits (the simulator's unit of traffic accounting).
FLIT_BYTES = 16


def data_flits(nbytes: int) -> int:
    """Payload flits for an arbitrary-size object (a 64B line is 4 flits;
    multi-MB parameter shards round up the same way)."""
    return -(-int(nbytes) // FLIT_BYTES)


MESSAGE_FLITS = {
    # message type: header flits + timestamp flits + data flits
    # (128-bit flits; 64B line = 4 flits; one flit carries two 64b timestamps)
    "SH_REQ": 2,        # header + (pts, wts)
    "EX_REQ": 2,        # header + wts
    "FLUSH_REQ": 1,
    "WB_REQ": 2,        # header + rts
    "SH_REP": 6,        # header + (wts, rts) + data
    "EX_REP": 6,
    "UPGRADE_REP": 2,   # header + rts, no data
    "RENEW_REP": 2,     # header + rts, no data
    "FLUSH_REP": 6,
    "WB_REP": 6,
    "DRAM_ST_REQ": 5,
    "DRAM_LD_REQ": 1,
    "DRAM_LD_REP": 5,
    # directory-protocol messages
    "GETS": 1,
    "GETX": 1,
    "PUTS": 1,
    "PUTX": 5,
    "INV": 1,
    "INV_ACK": 1,
    "DOWNGRADE": 1,
    "DATA": 5,
    "UPGRADE": 1,
    "ACK": 1,
}
