"""CoherencePolicy: one object for the whole coherence configuration.

Tardis 2.0 (arXiv 1511.08774) adds two orthogonal knobs to the base
timestamp protocol -- per-block lease self-tuning and relaxed consistency
models that drop renewals the memory model does not require.  Both used to
arrive as loose ``kv_lease`` / ``ts_bits`` kwargs scattered across
:class:`~repro.core.lease_engine.LeaseEngine`,
:class:`~repro.core.shard_directory.ShardedLeaseDirectory` and the serving
clusters; this dataclass is the single source of truth they all accept as
``policy=``.

Consistency models (which renewals a decode pod may skip):

  * ``sc``  -- sequential consistency: every expired lease renews (the
    paper's baseline; Table III verbatim).
  * ``tso`` -- total store order: a load may order BEFORE program-earlier
    stores/ticks of its own core (the classic store->load relaxation), so
    a tag-checked read-only block whose lease merely aged out under the
    core's own pts advance is served without a renewal round-trip.
  * ``rc``  -- release consistency: additionally loads may reorder with
    program-earlier loads; the serving layer treats it like ``tso`` (the
    decode access pattern has no load->load ordering to relax further).

Lease prediction (``predictor=True``): each block self-tunes its lease
inside ``[lease_min, lease_max]`` -- grow on a data-less renewal from a
holder of a cached copy (that requester's lease aged out before the
version changed: the message was wasted traffic), shrink on a write (the
lease blocked the writer).  MRSW livelock-freedom: writes always jump ahead of
the granted rts regardless of the predicted lease, so a reader can never
starve a writer; the bounds cap how far prediction may stretch either way.
"""
from __future__ import annotations

from dataclasses import dataclass, replace

CONSISTENCY_MODELS = ("sc", "tso", "rc")


@dataclass(frozen=True)
class CoherencePolicy:
    """Consistency model + lease bounds + predictor settings + ts_bits.

    ``lease`` is the base (and initial predicted) lease.  With the
    predictor off the bounds collapse to ``lease`` exactly, so every
    engine stays bit-identical to the static protocol.  With the
    predictor on the bounds default to ``[max(1, lease // 4), lease * 8]``
    unless given explicitly.
    """

    consistency: str = "sc"
    lease: int = 64
    lease_min: int | None = None
    lease_max: int | None = None
    predictor: bool = False
    ts_bits: int = 30

    def __post_init__(self):
        if self.consistency not in CONSISTENCY_MODELS:
            raise ValueError(
                f"consistency must be one of {CONSISTENCY_MODELS}, "
                f"got {self.consistency!r}")
        if self.lease < 1:
            raise ValueError(f"lease must be >= 1, got {self.lease}")
        lo = self.lease_min
        hi = self.lease_max
        if lo is None:
            lo = max(1, self.lease // 4) if self.predictor else self.lease
        if hi is None:
            hi = self.lease * 8 if self.predictor else self.lease
        object.__setattr__(self, "lease_min", int(lo))
        object.__setattr__(self, "lease_max", int(hi))
        if not (1 <= self.lease_min <= self.lease <= self.lease_max):
            raise ValueError(
                f"need 1 <= lease_min <= lease <= lease_max, got "
                f"[{self.lease_min}, {self.lease}, {self.lease_max}]")
        if self.ts_bits < 2:
            raise ValueError(f"ts_bits must be >= 2, got {self.ts_bits}")

    # -- predictor step rules (shared by engine, directory and oracles so
    #    adaptive leases stay bit-identical everywhere) ------------------

    def grow(self, cur: int) -> int:
        """Next lease after a wasted (data-less) renewal."""
        return min(self.lease_max, int(cur) * 2)

    def shrink(self, cur: int) -> int:
        """Next lease after a write hit the block (lease blocked it)."""
        return max(self.lease_min, int(cur) // 2)

    def skip_expired_renewal(self) -> bool:
        """True when the model lets decode serve a tag-checked read-only
        block past its lease end without a renewal message."""
        return self.consistency != "sc"

    def with_(self, **kw) -> "CoherencePolicy":
        return replace(self, **kw)

    @classmethod
    def from_legacy(cls, lease: int = 64, ts_bits: int = 30,
                    **kw) -> "CoherencePolicy":
        """Build from the pre-policy kwarg spelling (``kv_lease``/``lease``
        + ``ts_bits``)."""
        return cls(lease=lease, ts_bits=ts_bits, **kw)


def resolve_policy(policy: "CoherencePolicy | None", *, lease=None,
                   ts_bits=None, default_lease: int = 64,
                   default_ts_bits: int = 30) -> "CoherencePolicy":
    """Fold legacy ``lease``/``ts_bits`` kwargs and an optional ``policy``
    into one CoherencePolicy (explicit legacy kwargs win over defaults;
    a given policy wins over everything)."""
    if policy is not None:
        return policy
    return CoherencePolicy(
        lease=default_lease if lease is None else int(lease),
        ts_bits=default_ts_bits if ts_bits is None else int(ts_bits))
