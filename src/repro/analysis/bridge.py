"""Cross-validation bridge: the model checks the *shipped* rules.

Every transition the enumerator takes records (a) the protocol-scalar
calls it was built from and (b) the manager-table operation it corresponds
to.  The bridge replays each **distinct** one:

  * protocol calls go through the real ``core.protocol`` jnp scalars --
    the model's pure-int transcription must match bit-for-bit,
  * manager-table ops (``read`` / ``write`` / ``rebase``) go through a
    small ``LeaseEngine(backend="numpy")`` loaded with the transition's
    pre-state via :meth:`LeaseEngine.set_tables` -- the resulting
    ``(wts, rts)`` and program timestamps must be identical ints.

Replays are memoized on the operand tuple, so the cost is bounded by the
number of distinct rule applications (a few thousand for the bounded
configs), not the number of transitions (hundreds of thousands).
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from ..core import protocol
from ..core.lease_engine import LeaseEngine
from .model import TransitionInfo


def _ints(x):
    """Flatten a scalar / tuple of jnp or python scalars to a tuple of
    python ints (bools stay bools)."""
    if isinstance(x, (tuple, list)):
        return tuple(_ints(v) for v in x)
    v = np.asarray(x).item()
    return bool(v) if isinstance(v, (bool, np.bool_)) else int(v)


class Bridge:
    """Memoized replay of model transitions against the shipped code."""

    def __init__(self, lease: int):
        self.lease = int(lease)
        self._seen = set()
        self.counts: Dict[str, int] = {}

    # -- protocol scalars ---------------------------------------------------

    def _check_call(self, fname, args, expect) -> List[str]:
        got = _ints(getattr(protocol, fname)(*args))
        want = _ints(expect)
        if got != want:
            return [f"protocol.{fname}{tuple(args)} = {got}, model "
                    f"computed {want}"]
        return []

    # -- manager-table ops through the numpy LeaseEngine --------------------

    def _engine(self, n_blocks: int, ts_bits: int = 30) -> LeaseEngine:
        return LeaseEngine(n_blocks, self.lease, backend="numpy",
                           ts_bits=ts_bits)

    def _check_read(self, wts, rts, pts, req, exp_rts, exp_pts):
        eng = self._engine(1)
        eng.set_tables([wts], [rts])
        r = eng.read([0], pts, req_wts=[req])
        errs = []
        if int(r.rts[0]) != exp_rts or int(r.new_pts) != exp_pts:
            errs.append(
                f"engine.read(wts={wts}, rts={rts}, pts={pts}) -> "
                f"rts {int(r.rts[0])}, pts {int(r.new_pts)}; model "
                f"computed rts {exp_rts}, pts {exp_pts}")
        if int(r.wts[0]) != wts:
            errs.append(f"engine.read moved wts {wts} -> {int(r.wts[0])}")
        exp_expired = bool(np.asarray(
            protocol.shared_expired(pts, rts)).item())
        exp_renew = bool(np.asarray(
            protocol.renewable(req, wts)).item())
        if bool(r.expired[0]) != exp_expired \
                or bool(r.renew_ok[0]) != exp_renew:
            errs.append(
                f"engine.read flags (expired {bool(r.expired[0])}, renew "
                f"{bool(r.renew_ok[0])}) disagree with protocol scalars "
                f"({exp_expired}, {exp_renew})")
        return errs

    def _check_write(self, wts, rts, pts, exp_ts):
        eng = self._engine(1)
        eng.set_tables([wts], [rts])
        ts = eng.write([0], pts)
        errs = []
        if int(ts) != exp_ts:
            errs.append(f"engine.write(rts={rts}, pts={pts}) -> ts {ts}; "
                        f"model computed {exp_ts}")
        if int(eng.wts[0]) != exp_ts or int(eng.rts[0]) != exp_ts:
            errs.append(f"engine.write left (wts, rts) = "
                        f"({int(eng.wts[0])}, {int(eng.rts[0])}), "
                        f"expected ({exp_ts}, {exp_ts})")
        return errs

    def _check_rebase(self, table, ts_bits, expect):
        eng = self._engine(len(table), ts_bits=ts_bits)
        eng.set_tables([w for w, _ in table], [r for _, r in table])
        shift = eng.maybe_rebase()
        errs = []
        if shift != 1 << (ts_bits - 1):
            errs.append(f"engine.maybe_rebase applied shift {shift}, "
                        f"model expected {1 << (ts_bits - 1)}")
        got = tuple((int(w), int(r)) for w, r in zip(eng.wts, eng.rts))
        if got != tuple(expect):
            errs.append(f"engine rebase left tables {got}, model computed "
                        f"{tuple(expect)}")
        return errs

    # -- entry point --------------------------------------------------------

    def validate(self, info: TransitionInfo) -> List[str]:
        """Replay the transition's recorded calls; returns mismatches."""
        errs = []
        for fname, args, expect in info.calls:
            key = (fname, args)
            if key in self._seen:
                continue
            self._seen.add(key)
            self.counts[fname] = self.counts.get(fname, 0) + 1
            errs += self._check_call(fname, args, expect)
        if info.engine_op is not None:
            key = info.engine_op
            if key not in self._seen:
                self._seen.add(key)
                op = key[0]
                self.counts[f"engine.{op}"] = \
                    self.counts.get(f"engine.{op}", 0) + 1
                if op == "read":
                    _, w, r, p, q, er, ep = key
                    errs += self._check_read(w, r, p, q, er, ep)
                elif op == "write":
                    _, w, r, p, ts = key
                    errs += self._check_write(w, r, p, ts)
                elif op == "rebase":
                    _, table, bits, expect = key
                    errs += self._check_rebase(table, bits, expect)
        return errs
