"""Runtime lease sanitizer: vectorized invariants after every engine op.

Enabled with ``TARDIS_SANITIZE=1`` or ``LeaseEngine(sanitize=True)``.  The
engine calls :meth:`LeaseSanitizer.after` at the end of every mutating
transition; the sanitizer keeps a host-side shadow of the previous table
state and asserts, in numpy (one vectorized pass, no per-block Python):

  * tables stay int32, non-negative, and ``wts <= rts`` everywhere,
  * table monotonicity: timestamps never move backwards except under a
    rebase, which must be exactly ``max(prev - shift, 0)`` on every block
    (the uniform shift+clamp preserves relative order by construction --
    anything else is flagged),
  * a reader's program timestamp never decreases,
  * a read's lease extension never grants past ``max(wts, pts) +
    lease_max`` (the Tardis 2.0 predictor's hard cap -- an over-predicting
    predictor trips here),
  * a write stamps ``wts = rts = ts`` with the exact Table I jump-ahead
    ``ts = max(pts, max(masked rts) + 1)``,
  * the KV validity bitmap equals the shadow of published-minus-evicted
    blocks (so validity never leaks onto blocks that were neither leased
    nor written),
  * the free list holds no duplicates, only ids from the allocatable
    region, and no freed page still holds valid KV content (use-after-free
    / double-free guards on top of the engine's own raising checks),
  * the interleaved pool layout keeps every stack's column window
    LANES-aligned and disjoint (checked once at attach).

When off the engine pays a single ``is None`` branch per op.  Failures
raise :class:`SanitizeError` (an ``AssertionError`` subclass) with the op
name and the offending block ids.
"""
from __future__ import annotations

import numpy as np

from ..kernels.tardis_lease.ops import LANES


class SanitizeError(AssertionError):
    """A lease-engine invariant was violated at runtime."""


class LeaseSanitizer:
    """Shadow-state checker attached to one :class:`LeaseEngine`."""

    def __init__(self, engine):
        self.checks = 0
        self._check_layout(engine)
        self.rebaseline(engine)

    # -- baselines ----------------------------------------------------------

    def rebaseline(self, engine) -> None:
        """Reset the monotonicity shadow (engine init and ``set_tables``)."""
        self.prev_wts = np.array(engine.wts, copy=True)
        self.prev_rts = np.array(engine.rts, copy=True)
        self.prev_shift = int(engine.ts_shift)
        self.freed = set()            # pages freed and not re-allocated
        if engine.has_kv:
            self.written = np.array(engine._kv_valid, copy=True)
        else:
            self.written = None

    def _check_layout(self, engine) -> None:
        if not engine.has_kv:
            return
        windows = sorted((m["offset"], m["token_row"], name)
                         for name, m in engine._pool_meta.items())
        end = 0
        for off, width, name in windows:
            if off % LANES or width % LANES:
                self._fail("layout", f"pool {name!r} window [{off}, "
                           f"{off + width}) is not LANES-aligned")
            if off < end:
                self._fail("layout", f"pool {name!r} window [{off}, "
                           f"{off + width}) overlaps the previous stack "
                           f"(ends at {end})")
            end = off + width
        if end != engine.kv_token_row:
            self._fail("layout", f"pool windows end at {end} but the token "
                       f"row is {engine.kv_token_row} wide")

    # -- the per-op check ---------------------------------------------------

    def after(self, engine, op: str, **info) -> None:
        self.checks += 1
        wts = np.asarray(engine.wts)
        rts = np.asarray(engine.rts)
        if wts.dtype != np.int32 or rts.dtype != np.int32:
            self._fail(op, f"tables left int32: {wts.dtype}/{rts.dtype}")
        bad = np.flatnonzero(wts > rts)
        if bad.size:
            self._fail(op, f"wts > rts at blocks {bad[:8].tolist()}")
        if (wts < 0).any() or (rts < 0).any():
            self._fail(op, "negative timestamp in the table")

        shift = int(engine.ts_shift) - self.prev_shift
        if shift == 0:
            bad = np.flatnonzero((wts < self.prev_wts)
                                 | (rts < self.prev_rts))
            if bad.size:
                self._fail(op, f"timestamp moved backwards without a "
                           f"rebase at blocks {bad[:8].tolist()}")
        else:
            if shift < 0:
                self._fail(op, f"ts_shift decreased by {-shift}")
            want_w = np.maximum(self.prev_wts - shift, 0)
            want_r = np.maximum(self.prev_rts - shift, 0)
            bad = np.flatnonzero((wts != want_w) | (rts != want_r))
            if bad.size:
                self._fail(op, f"rebase by {shift} is not the uniform "
                           f"shift+clamp at blocks {bad[:8].tolist()} "
                           f"(relative order not preserved)")

        if op in ("read", "read_many"):
            pts = np.asarray(info["pts"])
            new_pts = np.asarray(info["new_pts"])
            if (new_pts < pts).any():
                self._fail(op, f"reader pts decreased: {pts} -> {new_pts}")
            if (wts != self.prev_wts).any():
                self._fail(op, "a read moved wts")
            # Tardis 2.0 lease cap: no extension (predicted or static) may
            # grant past max(wts, pts) + lease_max -- an over-predicted
            # lease would let stale reads linger arbitrarily long
            cap = int(getattr(engine, "lease_max", engine.lease))
            bound = np.maximum(self.prev_rts,
                               np.maximum(wts, int(pts.max())) + cap)
            bad = np.flatnonzero(rts > bound)
            if bad.size:
                self._fail(op, f"over-predicted lease: rts exceeds "
                           f"max(prev_rts, max(wts, pts) + lease_max "
                           f"= {cap}) at blocks {bad[:8].tolist()}")
        elif op == "write":
            idx = np.asarray(info["idx"])
            ts = int(info["ts"])
            want = max(int(info["pts"]),
                       int(self.prev_rts[idx].max(initial=-1)) + 1)
            if ts != want:
                self._fail(op, f"jump-ahead ts {ts} != max(pts, "
                           f"max(rts)+1) = {want}")
            if (wts[idx] != ts).any() or (rts[idx] != ts).any():
                self._fail(op, f"written blocks not stamped wts=rts={ts}")

        self._check_pages(engine, op, info)
        self._check_validity(engine, op, info)
        self.prev_wts = wts.copy()
        self.prev_rts = rts.copy()
        self.prev_shift = int(engine.ts_shift)

    # -- page allocator -----------------------------------------------------

    def _check_pages(self, engine, op, info) -> None:
        free = engine._free_pages
        if len(set(free)) != len(free):
            self._fail(op, "free list holds duplicate page ids")
        if free and not all(engine.alloc_reserve <= b < engine.n_blocks
                            for b in free):
            self._fail(op, "free list holds ids outside the allocatable "
                       "region")
        if op == "alloc_pages":
            ids = set(int(b) for b in np.asarray(info["idx"]).ravel())
            if ids & set(free):
                self._fail(op, f"allocated pages still on the free list: "
                           f"{sorted(ids & set(free))}")
            self.freed.difference_update(ids)
        elif op == "free_pages":
            self.freed.update(int(b)
                              for b in np.asarray(info["blocks"]).ravel())
        # use-after-free: a page that went through free_pages (and was not
        # re-allocated) must never regain valid KV content.  Blocks that
        # were simply never allocated are fair game -- with alloc_reserve=0
        # the whole table sits on the free list while callers address it
        # content-wise.
        if engine.has_kv and self.freed:
            stale = sorted(b for b in self.freed if engine._kv_valid[b])
            if stale:
                self._fail(op, f"freed pages regained valid KV content "
                           f"(use-after-free): {stale[:8]}")

    # -- KV validity bitmap -------------------------------------------------

    def _check_validity(self, engine, op, info) -> None:
        if not engine.has_kv:
            return
        # mirror the ops that publish / retract content
        if op in ("write_kv", "append_kv"):
            self.written[np.asarray(info["blocks"], np.int64)] = True
        elif op in ("invalidate_kv", "free_pages"):
            self.written[np.asarray(info["blocks"], np.int64)] = False
        valid = np.asarray(engine._kv_valid)
        extra = np.flatnonzero(valid & ~self.written)
        if extra.size:
            self._fail(op, f"validity bitmap marks blocks that were never "
                       f"written (or were evicted): {extra[:8].tolist()}")
        lost = np.flatnonzero(self.written & ~valid)
        if lost.size:
            self._fail(op, f"published blocks lost their validity bit "
                       f"outside invalidate/free: {lost[:8].tolist()}")

    def _fail(self, op, message):
        raise SanitizeError(f"TARDIS_SANITIZE[{op}]: {message}")


class MigrationSanitizer:
    """Invariant checks for cross-host page migration and write-behind
    publishing (:class:`repro.core.shard_directory.ShardedLeaseDirectory`).

    Three classes of bug it turns into hard failures:

      * **double publish** -- the same host queues the identical
        ``(gid, tag, version)`` payload twice without a flush in between
        (two hosts racing to repair the same block is NOT a bug: the
        owner installs the first and drops the second by version),
      * **tampered carry** -- a migrated page must arrive under exactly
        the ``(wts, rts)`` lease the same wave's read extended and the
        directory's current content tag; anything else means the borrower
        would serve payload under a lease it does not hold,
      * **use-after-migrate** -- a borrower serving a locally installed
        migrated page after the block was re-tagged underneath it.
    """

    def __init__(self):
        self.checks = 0
        self._pending = set()       # (host, gid, tag, wver) queued un-flushed
        self._installed = {}        # (host, gid) -> tag installed locally

    # -- write-behind publishes ---------------------------------------------

    def on_defer(self, host: int, gid: int, tag: int, wver: int) -> None:
        key = (int(host), int(gid), int(tag), int(wver))
        if key in self._pending:
            raise SanitizeError(
                f"TARDIS_SANITIZE[migrate]: double publish: host {host} "
                f"queued gid {gid} (tag {tag}, version {wver}) twice "
                f"without a flush")
        self._pending.add(key)
        self.checks += 1

    def on_flush(self, host: int, gid: int, tag: int, wver: int) -> None:
        self._pending.discard((int(host), int(gid), int(tag), int(wver)))
        self.checks += 1

    # -- migration carries ---------------------------------------------------

    def check_carried(self, page, lease, dir_tag: int) -> None:
        """``page`` is a FetchedPage; ``lease`` the (wts, rts) this wave's
        read returned for the gid; ``dir_tag`` the directory's current
        content tag."""
        w, r = int(lease[0]), int(lease[1])
        if (int(page.wts), int(page.rts)) != (w, r):
            raise SanitizeError(
                f"TARDIS_SANITIZE[migrate]: gid {page.gid} migrated under "
                f"(wts={page.wts}, rts={page.rts}) but the wave's lease is "
                f"({w}, {r})")
        if int(page.tag) != int(dir_tag):
            raise SanitizeError(
                f"TARDIS_SANITIZE[migrate]: gid {page.gid} migrated with "
                f"content tag {page.tag} != directory tag {dir_tag}")
        self.checks += 1

    # -- borrower-side installed copies --------------------------------------

    def mark_installed(self, host: int, gid: int, tag: int) -> None:
        self._installed[(int(host), int(gid))] = int(tag)
        self.checks += 1

    def on_invalidate(self, host: int, gid: int) -> None:
        self._installed.pop((int(host), int(gid)), None)
        self.checks += 1

    def on_use(self, host: int, gid: int, dir_tag: int) -> None:
        """A host is about to serve from its installed migrated copy."""
        got = self._installed.get((int(host), int(gid)))
        if got is None:
            raise SanitizeError(
                f"TARDIS_SANITIZE[migrate]: host {host} used gid {gid} "
                f"which was never installed (or already invalidated)")
        if got != int(dir_tag):
            raise SanitizeError(
                f"TARDIS_SANITIZE[migrate]: use-after-migrate: host {host} "
                f"serving gid {gid} tagged {got} but the directory moved "
                f"to {dir_tag}")
        self.checks += 1
