"""Exhaustive BFS enumeration of the guarded-action Tardis model.

Walks every reachable state of a bounded :class:`~repro.analysis.model.
Config`, checking the proof's invariants on each state and each transition:

  * ``wts <= rts`` on every valid line (private and LLC),
  * a single exclusive owner, consistent with the manager's owner field,
  * value--timestamp consistency: a load at ``pts`` returns the version
    whose ``[wts, rts]`` validity interval contains it,
  * per-core ``pts`` monotonicity on every non-rebase transition,
  * no-deadlock: at least one rule is enabled in every reachable state.

Violations come back with a *witness trace* -- the rule sequence from the
initial state -- reconstructed from BFS parent pointers.  When a
:class:`~repro.analysis.bridge.Bridge` is supplied, every distinct
protocol-scalar call and manager-table operation recorded on a transition
is replayed against ``core.protocol`` and the numpy ``LeaseEngine`` and
must match bit-for-bit.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from .model import TardisModel


@dataclass
class Violation:
    kind: str                 # "state" | "transition" | "deadlock" | "cap"
    message: str
    state_repr: str
    trace: List[str]          # rule names from the initial state

    def __str__(self):
        path = " -> ".join(self.trace) if self.trace else "<initial>"
        return f"[{self.kind}] {self.message}\n  at {self.state_repr}\n" \
               f"  via {path}"


@dataclass
class ExploreResult:
    closed: bool              # frontier exhausted (not capped)
    n_states: int
    n_transitions: int
    max_depth: int
    wall_time: float
    violations: List[Violation] = field(default_factory=list)
    rule_counts: Dict[str, int] = field(default_factory=dict)
    bridge_counts: Dict[str, int] = field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return self.closed and not self.violations


def explore(model: TardisModel, bridge=None, max_states: int = 2_000_000,
            max_violations: int = 16) -> ExploreResult:
    """BFS from the initial state until the frontier closes.

    Stops early once ``max_states`` distinct states have been expanded
    (``closed=False``) or ``max_violations`` have been collected.
    """
    if bridge is not None and model.is_mutant:
        raise ValueError(
            "cross-validation bridge requires the default rule set -- a "
            "mutant would fail transcription checks before its semantic "
            "bug ever reached the invariant checker")
    t0 = time.perf_counter()
    init = model.initial_state()
    # state -> (parent_state or None, rule_name, depth)
    seen: Dict[tuple, Tuple[Optional[tuple], str, int]] = {
        init: (None, "", 0)}
    frontier = deque([init])
    res = ExploreResult(closed=True, n_states=0, n_transitions=0,
                        max_depth=0, wall_time=0.0)

    def trace_of(state) -> List[str]:
        rules = []
        cur = state
        while True:
            parent, rule, _ = seen[cur]
            if parent is None:
                break
            rules.append(rule)
            cur = parent
        rules.reverse()
        return rules

    def add_violation(kind, message, state):
        res.violations.append(Violation(
            kind, message, model.describe(state), trace_of(state)))

    for bad in model.check_state(init):
        add_violation("state", bad, init)

    while frontier:
        if res.n_states >= max_states:
            res.closed = False
            break
        if len(res.violations) >= max_violations:
            res.closed = False
            break
        state = frontier.popleft()
        depth = seen[state][2]
        res.n_states += 1
        res.max_depth = max(res.max_depth, depth)
        n_succ = 0
        for nxt, info in model.successors(state):
            n_succ += 1
            res.n_transitions += 1
            res.rule_counts[info.rule] = res.rule_counts.get(info.rule,
                                                            0) + 1
            fresh = nxt not in seen
            if fresh:
                seen[nxt] = (state, info.rule, depth + 1)
            for bad in info.violations:
                add_violation("transition", bad,
                              nxt if fresh else state)
            if bridge is not None:
                for bad in bridge.validate(info):
                    add_violation("transition", f"bridge: {bad}",
                                  nxt if fresh else state)
            if fresh:
                if len(res.violations) < max_violations:
                    for bad in model.check_state(nxt):
                        add_violation("state", bad, nxt)
                frontier.append(nxt)
        if n_succ == 0:
            add_violation("deadlock", "no rule enabled", state)

    res.wall_time = time.perf_counter() - t0
    if bridge is not None:
        res.bridge_counts = dict(bridge.counts)
    return res
