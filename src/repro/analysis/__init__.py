"""Static protocol analysis: guarded-action model checking + sanitizers.

``model``    -- Tables I-III as explicit guarded transitions over bounded
                state tuples (per-core pts, private lines, LLC, mts).
``explore``  -- BFS exhaustive enumerator with invariant checking and
                counterexample witness traces.
``bridge``   -- cross-validation of every enumerated transition against the
                shipped ``core.protocol`` scalars and the ``LeaseEngine``
                numpy mirror (bit-identical wts/rts or it fails).
``sanitize`` -- the runtime lease sanitizer behind ``TARDIS_SANITIZE=1`` /
                ``LeaseEngine(sanitize=True)``.
"""
from .model import Config, Rules, TardisModel  # noqa: F401
from .explore import ExploreResult, Violation, explore  # noqa: F401
from .bridge import Bridge  # noqa: F401
from .sanitize import LeaseSanitizer, SanitizeError  # noqa: F401
