"""Guarded-action model of the Tardis protocol (paper Tables I-III).

The model is an explicit-state transition system over bounded configurations
(2-3 cores, 1-2 blocks).  A state is a nested tuple of small ints::

    state = (pts, lines, llc, mts, dram, vers)

      pts   : per-core program timestamps, tuple (C,)
      lines : per-core private cache, tuple (C, B) of (st, wts, rts, ver)
              with st in {INVALID, SHARED, EXCLUSIVE}; invalid lines are
              normalized to (0, 0, 0, 0)
      llc   : per-block manager line, tuple (B,) of (st, wts, rts, owner,
              ver) with st in {LLC_DRAM, LLC_S, LLC_E}; when owned the
              owner's copy is authoritative, so wts/rts/ver are normalized
              to 0; when in DRAM the timestamps live in ``mts``
      mts   : the manager's memory timestamp (LLC evictions fold rts in)
      dram  : per-block version id held by DRAM (-1 while the LLC holds
              the block -- DRAM content is dead until the next eviction
              rewrites it)
      vers  : per-block tuple of version-creation write timestamps; cache
              line / LLC ``ver`` fields index into it.  Values stand in
              for versions: "the load returned version v" is the whole
              observable behavior, so value--timestamp consistency checks
              reduce to interval checks against ``vers``.

Each transition is one rule of Tables I-III (plus the private-write
optimization of section IV-C and the ``ts_bits`` rebase the shipped
``LeaseEngine`` performs).  The timestamp math lives in :class:`Rules` as
pure-int static methods that transcribe ``core.protocol``; the bridge
(:mod:`repro.analysis.bridge`) replays every recorded call against the real
jnp scalars and the numpy ``LeaseEngine`` so the enumeration checks the
*shipped* rules, not this transcription.  Mutant rule sets (for the
seeded-mutation sensitivity tests) subclass :class:`Rules`.

The state space closes because timestamps are drawn from the bounded domain
[0, 2**ts_bits + lease]: whenever any timestamp reaches 2**ts_bits the
*rebase* rule becomes urgent (it is the only enabled transition) and shifts
every timestamp down by 2**(ts_bits-1), exactly like
``LeaseEngine.maybe_rebase`` / ``timestamps.apply_rebase`` -- including the
engine's drop rule for private Shared lines whose lease lies entirely below
the shift.  Gap-capping canonicalizations are deliberately *not* used: the
protocol guards are max-plus expressions and capping adjacent timestamp
gaps can flip a ``pts + lease >= rts`` comparison, so the only sound finite
abstraction is the one the shipped system itself implements.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from itertools import permutations
from typing import Iterator, List, Optional, Tuple

INVALID, SHARED, EXCLUSIVE = 0, 1, 2
LLC_DRAM, LLC_S, LLC_E = 0, 1, 2

_ST_NAME = {0: "I", 1: "S", 2: "E"}
_LLC_NAME = {0: "DRAM", 1: "S", 2: "E"}


@dataclass(frozen=True)
class Config:
    """Bounded model configuration.

    ``consistency`` picks the forbidden-outcome predicates evaluated over
    the SAME enumerated state graph (transitions never change):

      * ``sc``  -- all three load checks (value-ts lower bound, no stale
        value inside a newer version's interval, never past the lease end),
      * ``tso`` -- the store->load relaxation: a load may act as if ordered
        at a timestamp inside its lease even when the core's own
        program-earlier stores/ticks pushed pts past the lease end, so the
        "beyond the serving lease end" check is waived,
      * ``rc``  -- additionally waives the stale-inside-newer-interval
        check (loads may reorder with program-earlier loads); only the
        per-location value-ts lower bound remains.
    """
    n_cores: int = 2
    n_blocks: int = 1
    lease: int = 2
    ts_bits: int = 3          # rebase threshold 2**ts_bits, shift 2**(bits-1)
    self_inc: bool = True     # cores may advance pts spontaneously
    pw_opt: bool = True       # section IV-C private-write optimization
    symmetry: bool = True     # quotient by core/block permutations
    consistency: str = "sc"   # sc | tso | rc (see above)

    @property
    def threshold(self) -> int:
        return 1 << self.ts_bits

    @property
    def shift(self) -> int:
        return 1 << (self.ts_bits - 1)


class Rules:
    """Pure-int transcription of the ``core.protocol`` scalars.

    Every method mirrors the protocol function of the same name; the bridge
    cross-validates each distinct call bit-for-bit.  Seeded mutants for the
    sensitivity tests override single methods.
    """

    @staticmethod
    def load_no_cache(pts, wts, rts):
        new_pts = max(pts, wts)
        return new_pts, max(new_pts, rts)

    @staticmethod
    def store_no_cache(pts, wts, rts):
        ts = max(pts, rts + 1)
        return ts, ts, ts

    @staticmethod
    def load_hit_shared(pts, wts):
        return max(pts, wts)

    @staticmethod
    def load_hit_exclusive(pts, wts, rts):
        new_pts = max(pts, wts)
        return new_pts, max(new_pts, rts)

    @staticmethod
    def store_hit_exclusive(pts, rts):
        ts = max(pts, rts + 1)
        return ts, ts, ts

    @staticmethod
    def store_hit_private(pts, rts):
        ts = max(pts, rts)
        return ts, ts, ts

    @staticmethod
    def shared_expired(pts, rts):
        return pts > rts

    @staticmethod
    def writeback_rts(line_wts, line_rts, req_pts, lease):
        return max(line_rts, line_wts + lease, req_pts + lease)

    @staticmethod
    def lease_extend(llc_wts, llc_rts, req_pts, lease):
        return max(llc_rts, llc_wts + lease, req_pts + lease)

    @staticmethod
    def renewable(req_wts, llc_wts):
        return req_wts == llc_wts

    @staticmethod
    def dram_fill_ts(mts):
        return mts, mts

    @staticmethod
    def evict_mts(mts, line_rts):
        return max(mts, line_rts)


@dataclass
class TransitionInfo:
    """Everything the enumerator and the bridge need about one transition."""
    rule: str
    core: Optional[int] = None
    block: Optional[int] = None
    pts_before: Optional[int] = None
    pts_after: Optional[int] = None
    # (protocol_fn_name, args, expected_result) -- bridge replays these
    calls: List[Tuple[str, tuple, object]] = field(default_factory=list)
    # manager-table op replayed through the numpy LeaseEngine, or None
    engine_op: Optional[tuple] = None
    is_rebase: bool = False
    # invariant violations detected while applying (value-ts containment,
    # pts monotonicity, version ordering)
    violations: List[str] = field(default_factory=list)


def _line(st=INVALID, wts=0, rts=0, ver=0):
    return (st, wts, rts, ver)


class TardisModel:
    """Tables I-III as guarded transitions over bounded explicit states."""

    def __init__(self, cfg: Config, rules: Optional[Rules] = None):
        self.cfg = cfg
        self.rules = rules if rules is not None else Rules()
        # A non-default rule set is a seeded mutant: the bridge would flag
        # the transcription mismatch before the invariant checker got to
        # show the *semantic* failure, so explore() refuses the combination.
        self.is_mutant = type(self.rules) is not Rules

    # -- state constructors -------------------------------------------------

    def initial_state(self):
        cfg = self.cfg
        pts = (0,) * cfg.n_cores
        lines = tuple(tuple(_line() for _ in range(cfg.n_blocks))
                      for _ in range(cfg.n_cores))
        llc = tuple((LLC_DRAM, 0, 0, -1, 0) for _ in range(cfg.n_blocks))
        dram = (0,) * cfg.n_blocks        # DRAM holds version 0 everywhere
        vers = ((0,),) * cfg.n_blocks     # version 0 written at ts 0
        return self.canon((pts, lines, llc, 0, dram, vers))

    # -- canonicalization ---------------------------------------------------

    def canon(self, state):
        """Normalize hidden fields, GC version prefixes, pick the symmetry
        representative.

        Idempotent.  Invalid lines and owned/DRAM LLC entries carry no
        information, so their fields are zeroed; per block, versions below
        the oldest still-referenced one are dropped and ids renumbered.
        Rules treat all cores and all blocks identically, so states that
        differ only by a core/block relabeling are the same protocol
        situation -- with ``cfg.symmetry`` the lexicographically least
        relabeling represents the orbit.
        """
        state = self._canon_base(state)
        if not self.cfg.symmetry:
            return state
        best = state
        for cp in permutations(range(self.cfg.n_cores)):
            for bp in permutations(range(self.cfg.n_blocks)):
                cand = self._permute(state, cp, bp)
                if cand < best:
                    best = cand
        return best

    def _permute(self, state, cp, bp):
        """Relabel cores by ``cp`` and blocks by ``bp`` (new -> old)."""
        pts, lines, llc, mts, dram, vers = state
        inv = {old: new for new, old in enumerate(cp)}
        pts2 = tuple(pts[c] for c in cp)
        lines2 = tuple(tuple(lines[c][b] for b in bp) for c in cp)
        llc2 = tuple(
            (st, w, r, inv[o] if o >= 0 else -1, v)
            for st, w, r, o, v in (llc[b] for b in bp))
        dram2 = tuple(dram[b] for b in bp)
        vers2 = tuple(vers[b] for b in bp)
        return (pts2, lines2, llc2, mts, dram2, vers2)

    def _canon_base(self, state):
        pts, lines, llc, mts, dram, vers = state
        B = self.cfg.n_blocks
        lo = [0] * B
        new_vers = []
        for a in range(B):
            refs = [lines[i][a][3] for i in range(self.cfg.n_cores)
                    if lines[i][a][0] != INVALID]
            if llc[a][0] == LLC_S:
                refs.append(llc[a][4])
            elif llc[a][0] == LLC_DRAM:
                refs.append(dram[a])
            # llc E: the owner's private line (already counted) is latest
            lo[a] = min(refs) if refs else len(vers[a]) - 1
            new_vers.append(tuple(vers[a][lo[a]:]))
        new_lines = tuple(
            tuple(_line() if ln[0] == INVALID else
                  (ln[0], ln[1], ln[2], ln[3] - lo[a])
                  for a, ln in enumerate(row))
            for row in lines)
        new_llc = []
        new_dram = []
        for a in range(B):
            st, w, r, o, v = llc[a]
            if st == LLC_DRAM:
                new_llc.append((LLC_DRAM, 0, 0, -1, 0))
                new_dram.append(dram[a] - lo[a])
            elif st == LLC_E:
                new_llc.append((LLC_E, 0, 0, o, 0))
                new_dram.append(-1)
            else:
                new_llc.append((LLC_S, w, r, -1, v - lo[a]))
                new_dram.append(-1)
        return (pts, new_lines, tuple(new_llc), mts, tuple(new_dram),
                tuple(new_vers))

    # -- helpers ------------------------------------------------------------

    def max_ts(self, state) -> int:
        pts, lines, llc, mts, dram, vers = state
        m = max(max(pts), mts)
        for row in lines:
            for st, w, r, _ in row:
                if st != INVALID:
                    m = max(m, r)       # wts <= rts on valid lines
        for st, w, r, _, _ in llc:
            if st == LLC_S:
                m = max(m, r)
        for vs in vers:
            m = max(m, vs[-1])
        return m

    def describe(self, state) -> str:
        pts, lines, llc, mts, dram, vers = state
        parts = [f"pts={list(pts)} mts={mts}"]
        for i, row in enumerate(lines):
            cells = [f"{_ST_NAME[st]}(w{w},r{r},v{v})" if st else "I"
                     for st, w, r, v in row]
            parts.append(f"c{i}=[{' '.join(cells)}]")
        cells = []
        for a, (st, w, r, o, v) in enumerate(llc):
            if st == LLC_S:
                cells.append(f"S(w{w},r{r},v{v})")
            elif st == LLC_E:
                cells.append(f"E(own{o})")
            else:
                cells.append(f"DRAM(v{dram[a]})")
        parts.append(f"llc=[{' '.join(cells)}] vers={list(vers)}")
        return " ".join(parts)

    # -- value-timestamp consistency for one observed load ------------------

    def _check_load(self, info: TransitionInfo, vers_a, ver, new_pts,
                    serve_rts):
        """A load at pts must return the version whose [wts, rts] interval
        contains it: the serving version's creation stamp is <= new_pts and,
        if a newer version exists, its creation stamp is strictly above.

        ``cfg.consistency`` waives the checks the weaker memory model does
        not require (the relaxed forbidden-outcome predicates over the same
        enumerated graph; see :class:`Config`).
        """
        model = getattr(self.cfg, "consistency", "sc")
        if not (0 <= ver < len(vers_a)):
            info.violations.append(
                f"{info.rule}: served version id {ver} out of range")
            return
        if vers_a[ver] > new_pts:
            info.violations.append(
                f"{info.rule}: load observed pts {new_pts} below the served "
                f"version's creation wts {vers_a[ver]} (value-ts)")
        if model in ("sc", "tso") and ver + 1 < len(vers_a) \
                and new_pts >= vers_a[ver + 1]:
            info.violations.append(
                f"{info.rule}: load at pts {new_pts} returned a version "
                f"superseded at wts {vers_a[ver + 1]} (value-ts: stale value "
                f"served inside a newer version's validity interval)")
        if model == "sc" and new_pts > serve_rts:
            info.violations.append(
                f"{info.rule}: load consumed pts {new_pts} beyond the "
                f"serving lease end rts {serve_rts}")

    # -- transitions --------------------------------------------------------

    def successors(self, state) -> Iterator[Tuple[object, TransitionInfo]]:
        """Yield (canonical_successor, info) for every enabled rule.

        The rebase rule is *urgent*: once any timestamp reaches the
        2**ts_bits threshold it is the only enabled transition, mirroring
        ``LeaseEngine.maybe_rebase`` running before the next protocol op.
        """
        cfg = self.cfg
        if self.max_ts(state) >= cfg.threshold:
            yield self._rebase(state)
            return
        pts, lines, llc, mts, dram, vers = state
        R = self.rules
        for i in range(cfg.n_cores):
            for a in range(cfg.n_blocks):
                yield from self._core_block_rules(state, i, a)
            if cfg.self_inc:
                info = TransitionInfo("self_inc", core=i,
                                      pts_before=pts[i],
                                      pts_after=pts[i] + 1)
                np_ = pts[:i] + (pts[i] + 1,) + pts[i + 1:]
                yield (self.canon((np_, lines, llc, mts, dram, vers)), info)
        for a in range(cfg.n_blocks):
            st = llc[a][0]
            if st == LLC_S:
                m2 = R.evict_mts(mts, llc[a][2])
                info = TransitionInfo("llc_evict", block=a)
                info.calls.append(("evict_mts", (mts, llc[a][2]), m2))
                llc2 = _replace(llc, a, (LLC_DRAM, 0, 0, -1, 0))
                dram2 = _replace(dram, a, llc[a][4])
                yield (self.canon((pts, lines, llc2, m2, dram2, vers)), info)
            elif st == LLC_E:
                # evicting an owned LLC line flushes the owner first
                j = llc[a][3]
                ost, ow, orr, ov = lines[j][a]
                m2 = R.evict_mts(mts, orr)
                info = TransitionInfo("llc_evict_owned", block=a, core=j)
                info.calls.append(("evict_mts", (mts, orr), m2))
                lines2 = _set_line(lines, j, a, _line())
                llc2 = _replace(llc, a, (LLC_DRAM, 0, 0, -1, 0))
                dram2 = _replace(dram, a, ov)
                yield (self.canon((pts, lines2, llc2, m2, dram2, vers)),
                       info)

    def _core_block_rules(self, state, i, a):
        cfg, R = self.cfg, self.rules
        pts, lines, llc, mts, dram, vers = state
        p = pts[i]
        lst, lw, lr, lv = lines[i][a]
        mst = llc[a][0]
        V = vers[a]

        def out(name, p2, lines2, llc2=llc, mts2=mts, dram2=dram,
                vers2=vers, info=None):
            info = info or TransitionInfo(name)
            info.rule, info.core, info.block = name, i, a
            info.pts_before, info.pts_after = p, p2
            if p2 < p and not info.is_rebase:
                info.violations.append(
                    f"{name}: core {i} pts decreased {p} -> {p2}")
            np_ = pts[:i] + (p2,) + pts[i + 1:]
            return (self.canon((np_, lines2, llc2, mts2, dram2, vers2)),
                    info)

        # ---- Table II: private-cache load hits ----
        if lst == SHARED and not R.shared_expired(p, lr):
            p2 = R.load_hit_shared(p, lw)
            info = TransitionInfo("load_hit_s")
            info.calls.append(("load_hit_shared", (p, lw), p2))
            info.calls.append(("shared_expired", (p, lr), False))
            self._check_load(info, V, lv, p2, lr)
            yield out("load_hit_s", p2, lines, info=info)
        if lst == EXCLUSIVE:
            p2, r2 = R.load_hit_exclusive(p, lw, lr)
            info = TransitionInfo("load_hit_e")
            info.calls.append(("load_hit_exclusive", (p, lw, lr), (p2, r2)))
            self._check_load(info, V, lv, p2, r2)
            lines2 = _set_line(lines, i, a, (EXCLUSIVE, lw, r2, lv))
            yield out("load_hit_e", p2, lines2, info=info)

        # ---- load misses (invalid line, or Shared line whose lease ran
        # out -> renewal attempt), served by the manager (Table III) ----
        miss_load = (lst == INVALID or
                     (lst == SHARED and R.shared_expired(p, lr)))
        if miss_load:
            req_wts = lw if lst == SHARED else -1
            if lst == SHARED:
                exp_calls = [("shared_expired", (p, lr), True)]
            else:
                exp_calls = []
            if mst == LLC_S:
                _, gw, gr, _, gv = llc[a]
                r2 = R.lease_extend(gw, gr, p, cfg.lease)
                p2, _ = R.load_no_cache(p, gw, gr)
                renew = lst == SHARED and R.renewable(lw, gw)
                served = lv if renew else gv
                info = TransitionInfo("load_llc_s")
                info.calls += exp_calls
                info.calls.append(("lease_extend", (gw, gr, p, cfg.lease),
                                   r2))
                info.calls.append(("load_no_cache", (p, gw, gr),
                                   R.load_no_cache(p, gw, gr)))
                if lst == SHARED:
                    info.calls.append(("renewable", (lw, gw), renew))
                info.engine_op = ("read", gw, gr, p, req_wts, r2, p2)
                self._check_load(info, V, served, p2, r2)
                lines2 = _set_line(lines, i, a, (SHARED, gw, r2, served))
                llc2 = _replace(llc, a, (LLC_S, gw, r2, -1, gv))
                yield out("load_llc_s", p2, lines2, llc2, info=info)
            elif mst == LLC_E:
                # WB_REQ: the owner answers with its timestamps, extends
                # the lease per Table II's last column, and downgrades.
                j = llc[a][3]
                if j != i:      # owner's own access is a hit, handled above
                    ost, ow, orr, ov = lines[j][a]
                    wb = R.writeback_rts(ow, orr, p, cfg.lease)
                    p2, _ = R.load_no_cache(p, ow, wb)
                    info = TransitionInfo("load_wb")
                    info.calls += exp_calls
                    info.calls.append(
                        ("writeback_rts", (ow, orr, p, cfg.lease), wb))
                    info.calls.append(("load_no_cache", (p, ow, wb),
                                       R.load_no_cache(p, ow, wb)))
                    self._check_load(info, V, ov, p2, wb)
                    lines2 = _set_line(lines, j, a, (SHARED, ow, wb, ov))
                    lines2 = _set_line(lines2, i, a, (SHARED, ow, wb, ov))
                    llc2 = _replace(llc, a, (LLC_S, ow, wb, -1, ov))
                    yield out("load_wb", p2, lines2, llc2, info=info)
            else:               # LLC miss: DRAM fill at mts
                w0, r0 = R.dram_fill_ts(mts)
                r2 = R.lease_extend(w0, r0, p, cfg.lease)
                p2, _ = R.load_no_cache(p, w0, r0)
                renew = lst == SHARED and R.renewable(lw, w0)
                served = lv if renew else dram[a]
                info = TransitionInfo("load_dram")
                info.calls += exp_calls
                info.calls.append(("dram_fill_ts", (mts,), (w0, r0)))
                info.calls.append(("lease_extend", (w0, r0, p, cfg.lease),
                                   r2))
                info.calls.append(("load_no_cache", (p, w0, r0),
                                   R.load_no_cache(p, w0, r0)))
                self._check_load(info, V, served, p2, r2)
                lines2 = _set_line(lines, i, a, (SHARED, w0, r2, served))
                llc2 = _replace(llc, a, (LLC_S, w0, r2, -1, dram[a]))
                dram2 = _replace(dram, a, -1)
                yield out("load_dram", p2, lines2, llc2, dram2=dram2,
                          info=info)

        # ---- Table II: store hit on an Exclusive line ----
        if lst == EXCLUSIVE:
            if cfg.pw_opt:
                # modified bit is set (E is only reachable by a store
                # here), so repeated stores reuse the version slot
                ts, _, _ = R.store_hit_private(p, lr)
                info = TransitionInfo("store_hit_pw")
                info.calls.append(("store_hit_private", (p, lr),
                                   (ts, ts, ts)))
                if ts < V[lv]:
                    info.violations.append(
                        f"store_hit_pw: restamp {ts} below version "
                        f"creation {V[lv]}")
                vers2 = _replace(vers, a, V[:lv] + (ts,) + V[lv + 1:])
                lines2 = _set_line(lines, i, a, (EXCLUSIVE, ts, ts, lv))
                yield out("store_hit_pw", ts, lines2, vers2=vers2,
                          info=info)
            else:
                ts, _, _ = R.store_hit_exclusive(p, lr)
                info = TransitionInfo("store_hit_e")
                info.calls.append(("store_hit_exclusive", (p, lr),
                                   (ts, ts, ts)))
                if ts <= V[-1]:
                    info.violations.append(
                        f"store_hit_e: new version ts {ts} not above "
                        f"previous creation {V[-1]}")
                vers2 = _replace(vers, a, V + (ts,))
                lines2 = _set_line(lines, i, a,
                                   (EXCLUSIVE, ts, ts, len(V)))
                yield out("store_hit_e", ts, lines2, vers2=vers2,
                          info=info)

        # ---- store misses: acquire exclusive via the manager ----
        if lst != EXCLUSIVE:
            if mst == LLC_S:
                _, gw, gr, _, _ = llc[a]
                ts, _, _ = R.store_no_cache(p, gw, gr)
                info = TransitionInfo("store_llc_s")
                info.calls.append(("store_no_cache", (p, gw, gr),
                                   (ts, ts, ts)))
                if lst == SHARED:   # UPGRADE_REP vs EX_REP: traffic only
                    info.calls.append(("renewable", (lw, gw),
                                       R.renewable(lw, gw)))
                info.engine_op = ("write", gw, gr, p, ts)
                yield from self._store_fill(state, i, a, ts, info)
            elif mst == LLC_E:
                j = llc[a][3]
                if j != i:
                    ost, ow, orr, ov = lines[j][a]
                    ts, _, _ = R.store_no_cache(p, ow, orr)
                    info = TransitionInfo("store_flush")
                    info.calls.append(("store_no_cache", (p, ow, orr),
                                       (ts, ts, ts)))
                    lines2 = _set_line(lines, j, a, _line())
                    yield from self._store_fill(
                        (pts, lines2, llc, mts, dram, vers), i, a, ts, info)
            else:
                w0, r0 = R.dram_fill_ts(mts)
                ts, _, _ = R.store_no_cache(p, w0, r0)
                info = TransitionInfo("store_dram")
                info.calls.append(("dram_fill_ts", (mts,), (w0, r0)))
                info.calls.append(("store_no_cache", (p, w0, r0),
                                   (ts, ts, ts)))
                dram2 = _replace(dram, a, -1)
                yield from self._store_fill(
                    (pts, lines, llc, mts, dram2, vers), i, a, ts, info)

        # ---- silent / writeback evictions of the private line ----
        if lst == SHARED:
            info = TransitionInfo("evict_s")
            lines2 = _set_line(lines, i, a, _line())
            yield out("evict_s", p, lines2, info=info)
        if lst == EXCLUSIVE:
            # FLUSH_REP back to the LLC: timestamps travel with the data
            info = TransitionInfo("evict_e")
            lines2 = _set_line(lines, i, a, _line())
            llc2 = _replace(llc, a, (LLC_S, lw, lr, -1, lv))
            yield out("evict_e", p, lines2, llc2, info=info)

    def _store_fill(self, state, i, a, ts, info):
        """Complete a store miss: new version at ts, requester takes E."""
        pts, lines, llc, mts, dram, vers = state
        V = vers[a]
        info.rule = info.rule or "store"
        if ts <= V[-1]:
            info.violations.append(
                f"{info.rule}: new version ts {ts} not above previous "
                f"creation {V[-1]} (write did not jump the read lease)")
        vers2 = _replace(vers, a, V + (ts,))
        lines2 = _set_line(lines, i, a, (EXCLUSIVE, ts, ts, len(V)))
        llc2 = _replace(llc, a, (LLC_E, 0, 0, i, 0))
        info.core, info.block = i, a
        info.pts_before, info.pts_after = pts[i], ts
        if ts < pts[i]:
            info.violations.append(
                f"{info.rule}: core {i} pts decreased {pts[i]} -> {ts}")
        np_ = pts[:i] + (ts,) + pts[i + 1:]
        yield (self.canon((np_, lines2, llc2, mts, dram, vers2)), info)

    # -- the urgent rebase rule --------------------------------------------

    def _rebase(self, state):
        """Shift every timestamp down by 2**(ts_bits-1), clamping at 0.

        Mirrors the shipped wraparound handling: ``LeaseEngine.maybe_rebase``
        for the manager table, ``timestamps.apply_rebase`` /
        ``DecodeReplica.rebase_kv`` for private lines -- a private Shared
        line whose lease ends below the shift is invalidated rather than
        clamped (clamping could alias its stale version onto the new base).
        """
        cfg = self.cfg
        shift = cfg.shift
        pts, lines, llc, mts, dram, vers = state

        def c(x):
            return max(0, x - shift)

        info = TransitionInfo("rebase", is_rebase=True)
        pts2 = tuple(c(p) for p in pts)
        new_lines = []
        for row in lines:
            nrow = []
            for st, w, r, v in row:
                if st == SHARED and r < shift:
                    nrow.append(_line())          # dropped, not clamped
                elif st == INVALID:
                    nrow.append(_line())
                else:
                    nrow.append((st, c(w), c(r), v))
            new_lines.append(tuple(nrow))
        new_llc = []
        table = []
        for st, w, r, o, v in llc:
            if st == LLC_S:
                new_llc.append((LLC_S, c(w), c(r), -1, v))
                table.append((w, r))
            else:
                new_llc.append((st, 0, 0, o, 0) if st == LLC_E
                               else (LLC_DRAM, 0, 0, -1, 0))
                table.append((0, 0))
        # the engine rebases when its own table crosses the threshold;
        # replay only when this rebase is visible to the manager table
        if any(r >= cfg.threshold for _, r in table):
            info.engine_op = ("rebase", tuple(table), cfg.ts_bits,
                              tuple((c(w), c(r)) for w, r in table))
        vers2 = tuple(tuple(c(x) for x in vs) for vs in vers)
        st2 = (pts2, tuple(new_lines), tuple(new_llc), c(mts), dram, vers2)
        return self.canon(st2), info

    # -- per-state invariants ----------------------------------------------

    def check_state(self, state) -> List[str]:
        """The proof's invariants, checked on one reachable state."""
        cfg = self.cfg
        pts, lines, llc, mts, dram, vers = state
        bad = []
        bound = cfg.threshold + cfg.lease
        if not all(0 <= p <= bound for p in pts) or not 0 <= mts <= bound:
            bad.append(f"timestamp out of bounds [0, {bound}]")
        # Tardis 2.0 lease-horizon: every granted lease end stays within one
        # lease of the system's progress frontier (mts is in the frontier
        # because LLC eviction folds line rts into it).  An over-predicting
        # lease extension rule breaks this on its first grant.
        horizon = max(max(pts), mts) + cfg.lease
        for a in range(cfg.n_blocks):
            V = vers[a]
            latest = len(V) - 1
            if any(V[k] > V[k + 1] for k in range(latest)):
                bad.append(f"block {a}: version stamps not monotone {V}")
            owners = [i for i in range(cfg.n_cores)
                      if lines[i][a][0] == EXCLUSIVE]
            mst, gw, gr, own, gv = llc[a]
            if mst == LLC_E:
                if owners != [own]:
                    bad.append(f"block {a}: llc owner {own} but exclusive "
                               f"lines at cores {owners}")
                elif lines[own][a][3] != latest:
                    bad.append(f"block {a}: owner holds version "
                               f"{lines[own][a][3]}, latest is {latest}")
            else:
                if owners:
                    bad.append(f"block {a}: exclusive lines at {owners} "
                               f"but llc state {_LLC_NAME[mst]}")
                if mst == LLC_S and gv != latest:
                    bad.append(f"block {a}: llc serves version {gv}, "
                               f"latest is {latest}")
                if mst == LLC_DRAM and dram[a] != latest:
                    bad.append(f"block {a}: dram holds version {dram[a]}, "
                               f"latest is {latest}")
            if mst == LLC_S:
                if not gw <= gr:
                    bad.append(f"block {a}: llc wts {gw} > rts {gr}")
                if not (0 <= gw and gr <= bound):
                    bad.append(f"block {a}: llc ts out of bounds")
                if gr > horizon:
                    bad.append(f"block {a}: llc rts {gr} above the lease "
                               f"horizon {horizon} (over-predicted lease "
                               f"extension)")
            for i in range(cfg.n_cores):
                st, w, r, v = lines[i][a]
                if st == INVALID:
                    continue
                if not w <= r:
                    bad.append(f"core {i} block {a}: wts {w} > rts {r}")
                if not (0 <= w and r <= bound):
                    bad.append(f"core {i} block {a}: ts out of bounds")
                if r > horizon:
                    bad.append(f"core {i} block {a}: line rts {r} above the "
                               f"lease horizon {horizon} (over-predicted "
                               f"lease extension)")
                if not 0 <= v <= latest:
                    bad.append(f"core {i} block {a}: version id {v} "
                               f"out of range")
                    continue
                if V[v] > w:
                    bad.append(f"core {i} block {a}: line wts {w} below "
                               f"its version's creation {V[v]}")
                if v < latest and not r < V[v + 1]:
                    bad.append(f"core {i} block {a}: stale version {v} "
                               f"lease rts {r} reaches into successor "
                               f"wts {V[v + 1]}")
                # the manager's lease dominates every Shared copy it issued
                if st == SHARED and mst == LLC_S and v == gv and r > gr:
                    bad.append(f"core {i} block {a}: private rts {r} "
                               f"above manager rts {gr}")
                if st == SHARED and mst == LLC_DRAM and r > mts:
                    bad.append(f"core {i} block {a}: private rts {r} "
                               f"above mts {mts} after llc eviction")
        return bad


def _replace(tup, idx, val):
    return tup[:idx] + (val,) + tup[idx + 1:]


def _set_line(lines, i, a, val):
    return _replace(lines, i, _replace(lines[i], a, val))
