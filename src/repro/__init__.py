"""Tardis-JAX: timestamp-coherent distributed training/serving framework.

Subpackages (import lazily; this file stays jax-import-free so
``repro.launch.dryrun`` can set XLA_FLAGS first):
  core, models, configs, dist, optim, data, checkpoint, runtime,
  kernels, launch.
"""
__version__ = "1.0.0"
