import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: 512 placeholder
host devices stand in for 2 pods x 256 chips.  For every cell this script
  * builds abstract params / optimizer state / batch / cache
    (ShapeDtypeStruct -- nothing is allocated),
  * attaches NamedShardings from repro.dist.sharding,
  * ``jit(step).lower(...).compile()`` on the production mesh,
  * records memory_analysis / cost_analysis / per-collective bytes parsed
    from the compiled HLO into a JSON artifact consumed by
    ``benchmarks/roofline.py`` and EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --mesh single --out dryrun.json
  PYTHONPATH=src python -m repro.launch.dryrun --mesh multi --archs tinyllama-1.1b
"""
import argparse
import json
import re
import time
import traceback

import jax

from ..configs import (ARCHS, SHAPES, SHAPE_BY_NAME, SUBQUADRATIC_FAMILIES,
                       get_arch)
from ..dist import sharding as shd
from ..models import abstract_params
from ..optim import adamw
from .mesh import make_production_mesh
from .steps import input_specs, make_prefill_step, make_serve_step, \
    make_train_step

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _bytes_of(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo: str):
    """Sum result bytes of every collective op in compiled HLO text.

    Returns (totals, counts, in_loop_totals): collectives that live inside a
    while-loop body (the layer scan) are bucketed separately -- HLO cost
    analysis counts loop bodies ONCE, so the roofline harness multiplies the
    in-loop bucket by the scan trip count (n_layers).
    """
    totals = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    in_loop = {k: 0 for k in _COLLECTIVES}
    cur_comp_is_body = False
    for line in hlo.splitlines():
        ls = line.lstrip()
        if ls.startswith("%") and ("{" in line) and ("= " not in ls[:40]):
            # computation definition header; jax scan bodies lower to
            # %...region_0..._spmd... (region_1 = the loop condition)
            name = ls.split(" ", 1)[0]
            cur_comp_is_body = ("body" in name) or ("region_0" in name)
        for op in _COLLECTIVES:
            if f" {op}(" in line or f" {op}-start(" in line:
                lhs = line.split(f" {op}", 1)[0]
                b = sum(_bytes_of(d, s) for d, s in _SHAPE_RE.findall(lhs))
                totals[op] += b
                counts[op] += 1
                if cur_comp_is_body:
                    in_loop[op] += b
                break
    return totals, counts, in_loop


def _attach(tree, shardings):
    return jax.tree.map(
        lambda s, ns: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=ns),
        tree, shardings)


def should_skip(cfg, shape) -> str:
    if shape.name == "long_500k" and cfg.family not in SUBQUADRATIC_FAMILIES:
        return "long_500k needs sub-quadratic attention (full-attn arch)"
    return ""


def run_cell(arch: str, shape_name: str, mesh, mesh_name: str,
             collect_hlo: bool = True):
    cfg = get_arch(arch)
    shape = SHAPE_BY_NAME[shape_name]
    skip = should_skip(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "kind": shape.kind, "params": cfg.param_count(),
           "active_params": cfg.active_param_count(),
           "seq_len": shape.seq_len, "global_batch": shape.global_batch}
    if skip:
        rec["status"] = "skipped"
        rec["reason"] = skip
        return rec

    t0 = time.time()
    params = abstract_params(cfg)
    pshard = shd.param_shardings(mesh, params)
    params = _attach(params, pshard)
    specs = input_specs(cfg, shape)

    # ambient mesh so activation sharding constraints (dist.annotate) bind;
    # use_mesh is the documented context manager on newer jax (set_mesh is a
    # global setter, not a context manager), Mesh itself works on legacy jax
    use_mesh = getattr(jax.sharding, "use_mesh", None)
    mesh_ctx = use_mesh(mesh) if use_mesh is not None else mesh
    with mesh_ctx:
        return _lower_and_analyze(cfg, shape, mesh, rec, params, pshard,
                                  specs, t0, collect_hlo)


def _lower_and_analyze(cfg, shape, mesh, rec, params, pshard, specs, t0,
                       collect_hlo):
    if shape.kind == "train":
        opt = jax.eval_shape(adamw.init, params)
        opt = _attach(opt, shd.opt_shardings(mesh, opt, pshard))
        batch = _attach(specs["batch"],
                        shd.batch_shardings(mesh, specs["batch"]))
        step = make_train_step(cfg, grad_shardings=pshard)
        lowered = jax.jit(step, donate_argnums=(0, 1)).lower(
            params, opt, batch)
    elif shape.kind == "prefill":
        batch = _attach(specs["batch"],
                        shd.batch_shardings(mesh, specs["batch"]))
        step = make_prefill_step(cfg, cache_len=shape.seq_len)
        lowered = jax.jit(step).lower(params, batch)
    else:  # decode
        cache = _attach(specs["cache"],
                        shd.cache_shardings(mesh, specs["cache"]))
        tokens = _attach({"t": specs["tokens"]},
                         shd.batch_shardings(mesh, {"t": specs["tokens"]}))["t"]
        cur = specs["cur_idx"]
        step = make_serve_step(cfg)
        lowered = jax.jit(step, donate_argnums=(1,)).lower(
            params, cache, tokens, cur)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    rec["status"] = "ok"
    rec["lower_s"] = round(t_lower, 2)
    rec["compile_s"] = round(t_compile, 2)
    try:
        mem = compiled.memory_analysis()
        rec["memory_analysis"] = {
            k: int(getattr(mem, k)) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)}
    except Exception as e:  # CPU backend may not implement it
        rec["memory_analysis"] = {"error": str(e)}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        rec["cost_analysis"] = {
            k: float(v) for k, v in ca.items()
            if isinstance(v, (int, float)) and (
                k in ("flops", "bytes accessed", "optimal_seconds")
                or k.startswith("bytes accessed"))}
    except Exception as e:
        rec["cost_analysis"] = {"error": str(e)}
    if collect_hlo:
        try:
            hlo = compiled.as_text()
            totals, counts, in_loop = collective_bytes(hlo)
            rec["collective_bytes"] = totals
            rec["collective_counts"] = counts
            rec["collective_bytes_in_loop"] = in_loop
            rec["hlo_chars"] = len(hlo)
            del hlo
        except Exception as e:
            rec["collective_bytes"] = {"error": str(e)}
    # analytic per-device weight+opt memory (CPU memory_analysis is partial)
    rec["arg_bytes_per_device"] = arg_bytes_per_device(
        mesh, params, None if shape.kind != "train" else opt)
    return rec


def arg_bytes_per_device(mesh, params, opt=None) -> int:
    """Exact per-device bytes of weights+optimizer given their shardings."""
    total = 0
    for leaf in jax.tree.leaves(params) + (jax.tree.leaves(opt) if opt else []):
        size = leaf.size * leaf.dtype.itemsize
        ns = getattr(leaf, "sharding", None)
        if ns is not None and ns.spec is not None:
            shards = 1
            for axes, dim in zip(ns.spec, leaf.shape):
                if axes is None:
                    continue
                for a in (axes,) if isinstance(axes, str) else axes:
                    shards *= mesh.shape.get(a, 1)
            size = -(-size // max(1, shards))
        total += size
    return total


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--archs", nargs="*", default=sorted(ARCHS))
    ap.add_argument("--shapes", nargs="*", default=[s.name for s in SHAPES])
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--out", default="dryrun.json")
    ap.add_argument("--append", action="store_true")
    ap.add_argument("--no-hlo", action="store_true",
                    help="skip HLO text parsing (faster)")
    args = ap.parse_args()

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single_pod_16x16", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi_pod_2x16x16", make_production_mesh(multi_pod=True)))

    records = []
    if args.append and os.path.exists(args.out):
        records = json.load(open(args.out))
    done = {(r["arch"], r["shape"], r["mesh"]) for r in records}

    for mesh_name, mesh in meshes:
        for arch in args.archs:
            for shape_name in args.shapes:
                if (arch, shape_name, mesh_name) in done:
                    continue
                t0 = time.time()
                try:
                    rec = run_cell(arch, shape_name, mesh, mesh_name,
                                   collect_hlo=not args.no_hlo)
                except Exception as e:
                    rec = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_name, "status": "error",
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-2000:]}
                rec["wall_s"] = round(time.time() - t0, 2)
                records.append(rec)
                print(f"[{rec.get('status'):7s}] {mesh_name} {arch} "
                      f"{shape_name} ({rec['wall_s']}s)"
                      + (f" :: {rec.get('error', rec.get('reason', ''))}"
                         if rec.get("status") != "ok" else ""),
                      flush=True)
                json.dump(records, open(args.out, "w"), indent=1)
    n_ok = sum(r["status"] == "ok" for r in records)
    n_skip = sum(r["status"] == "skipped" for r in records)
    n_err = sum(r["status"] == "error" for r in records)
    print(f"done: {n_ok} ok, {n_skip} skipped, {n_err} errors -> {args.out}")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
