"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On real hardware this process runs per-host under the TPU runtime with the
production mesh; in this environment it runs reduced configs on CPU with the
same code path (config -> params -> sharded step -> fault-tolerant loop).
"""
import argparse

import jax
import jax.numpy as jnp

from ..configs import ARCHS, get_arch, reduced
from ..models import init_params
from ..runtime import TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--reduced", action="store_true", default=True,
                    help="use the smoke-scale config (full configs need TPU)")
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    params = init_params(cfg, jax.random.PRNGKey(args.seed), jnp.float32)
    n = sum(p.size for p in jax.tree.leaves(params))
    print(f"arch={args.arch} family={cfg.family} params={n/1e6:.1f}M "
          f"devices={jax.device_count()}")

    tc = TrainConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                     batch=args.batch, seq=args.seq, seed=args.seed,
                     grad_compression=args.grad_compression,
                     n_micro=args.n_micro)
    out = train(cfg, params, tc,
                on_metrics=lambda s, m: print(
                    f"step {s:5d} loss {m['loss']:.4f} "
                    f"lr {m['lr']:.2e} {m['step_s']*1e3:.0f}ms"))
    print(f"final loss {out['losses'][-1]:.4f} "
          f"(restarts={out['restarts']}, stragglers={out['stragglers']})")


if __name__ == "__main__":
    main()
