"""Step builders + abstract input specs for every (arch x shape) cell.

``train_step`` is loss + grad + AdamW update (donated params/opt state);
``prefill_step`` builds the KV/SSM cache from a prompt; ``serve_step`` is one
decode token against a full-length cache.  ``input_specs`` returns
ShapeDtypeStruct stand-ins (weak-type-correct, shardable, no allocation) for
the dry-run and roofline harness.
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeSet
from ..models import decode_step, init_cache, loss_fn, prefill
from ..optim import adamw

VISION_PATCHES = 1024


def make_train_step(cfg: ArchConfig, base_lr: float = 3e-4,
                    warmup: int = 100, total: int = 10_000,
                    grad_shardings=None):
    """``grad_shardings``: optional pytree of NamedShardings (the parameter
    shardings).  Constraining gradients to them lets XLA fuse the DP
    all-reduce + shard-slice into a reduce-scatter (ZeRO-2 reduction path;
    EXPERIMENTS.md §Perf iteration 2)."""
    lr_fn = adamw.cosine_schedule(base_lr, warmup, total)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch))(params)
        if grad_shardings is not None:
            grads = jax.tree.map(jax.lax.with_sharding_constraint,
                                 grads, grad_shardings)
        lr = lr_fn(opt_state["step"] + 1)
        params, opt_state, metrics = adamw.update(
            params, grads, opt_state, lr=lr)
        return params, opt_state, {"loss": loss, "lr": lr, **metrics}

    return train_step


def make_prefill_step(cfg: ArchConfig, cache_len: int):
    def prefill_step(params, batch):
        return prefill(cfg, params, batch, cache_len)
    return prefill_step


def make_serve_step(cfg: ArchConfig):
    def serve_step(params, cache, tokens, cur_idx):
        return decode_step(cfg, params, cache, tokens, cur_idx)
    return serve_step


# ---------------------------------------------------------------------------
# Abstract inputs
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ArchConfig, b: int, s: int,
                with_labels: bool) -> Dict[str, Any]:
    out = {"tokens": _sds((b, s), jnp.int32)}
    if with_labels:
        out["labels"] = _sds((b, s), jnp.int32)
    if cfg.family == "encdec":
        out["frames"] = _sds((b, s, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        out["vision_embeds"] = _sds(
            (b, min(VISION_PATCHES, s), cfg.d_model), jnp.bfloat16)
    return out


def cache_specs(cfg: ArchConfig, b: int, t: int, enc_len: int = 0):
    return jax.eval_shape(
        functools.partial(init_cache, cfg, b, t, enc_len))


def input_specs(cfg: ArchConfig, shape: ShapeSet) -> Dict[str, Any]:
    """All abstract inputs for one cell, keyed by step-argument name."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return {"batch": batch_specs(cfg, b, s, with_labels=True)}
    if shape.kind == "prefill":
        return {"batch": batch_specs(cfg, b, s, with_labels=False)}
    if shape.kind == "decode":
        enc_len = s if cfg.family == "encdec" else 0
        return {
            "cache": cache_specs(cfg, b, s, enc_len),
            "tokens": _sds((b, 1), jnp.int32),
            "cur_idx": _sds((), jnp.int32),
        }
    raise ValueError(shape.kind)
