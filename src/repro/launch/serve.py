"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Starts a Tardis-coherent replica cluster on the selected architecture's
reduced config and serves synthetic batched requests (the full configs are
exercised by the multi-pod dry-run; see repro.launch.dryrun).

``--hosts K`` serves through K simulated hosts sharing one sharded lease
directory: the request stream is served in two phases (host 0 first, then
round-robin over the others) so the later hosts demonstrably reuse the
prefix pages host 0 prefilled -- the report grows per-host breakouts and
the directory's cross-host message ledger (``xhost_*``).
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCHS, get_arch, reduced
from ..core import CONSISTENCY_MODELS, CoherencePolicy
from ..models import init_params
from ..runtime import MultiHostServingCluster, Request, ServingCluster


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--lease", type=int, default=8)
    ap.add_argument("--prefix-len", type=int, default=16,
                    help="shared system-prompt tokens (prefix-KV reuse)")
    ap.add_argument("--prefix-block", type=int, default=8)
    ap.add_argument("--decode-pages", type=int, default=256,
                    help="allocator-region pool pages (admission bound)")
    ap.add_argument("--max-pages", type=int, default=32,
                    help="page-table length per request")
    ap.add_argument("--max-batch", type=int, default=4,
                    help="continuous-batch slots per replica")
    ap.add_argument("--hosts", type=int, default=1,
                    help=">1: simulated hosts sharing a sharded lease "
                         "directory (cross-host prefix-KV migration)")
    ap.add_argument("--shards", type=int, default=0,
                    help="owner shards for --hosts mode (default: --hosts)")
    ap.add_argument("--roles", default="",
                    help="comma list of per-host roles (prefill|decode|"
                         "mixed), e.g. 'prefill,decode,decode'; implies "
                         "--hosts len(roles) and routes cold prefixes "
                         "through the prefill pods")
    ap.add_argument("--consistency", choices=CONSISTENCY_MODELS,
                    default="sc",
                    help="memory model for prefix-KV leases: tso/rc lets "
                         "decode serve tag-checked read-only blocks past "
                         "the lease end without a renewal message")
    ap.add_argument("--kv-lease", type=int, default=16,
                    help="base prefix-KV lease (logical ticks)")
    ap.add_argument("--lease-bounds", default="",
                    help="'min:max' bounds for the per-block lease "
                         "predictor; turns adaptive (Tardis 2.0) lease "
                         "prediction on")
    args = ap.parse_args()
    if args.lease_bounds:
        lo, _, hi = args.lease_bounds.partition(":")
        policy = CoherencePolicy(
            consistency=args.consistency, lease=args.kv_lease,
            lease_min=int(lo), lease_max=int(hi), predictor=True)
    else:
        policy = CoherencePolicy(consistency=args.consistency,
                                 lease=args.kv_lease)
    roles = [r.strip() for r in args.roles.split(",") if r.strip()]
    if roles:
        if args.hosts > 1 and args.hosts != len(roles):
            raise SystemExit(
                f"--hosts {args.hosts} != {len(roles)} roles in --roles")
        args.hosts = len(roles)

    cfg = reduced(get_arch(args.arch))
    if cfg.family == "encdec":
        raise SystemExit("serve CLI drives decoder-only archs; whisper is "
                         "exercised via tests/dry-run (needs frame inputs)")
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    kw = dict(n_replicas=args.replicas, lease=args.lease,
              prefix_block_tokens=args.prefix_block,
              policy=policy, cache_len=96,
              n_decode_pages=args.decode_pages,
              max_pages=args.max_pages,
              selfinc_period=4, max_batch=args.max_batch)
    rng = np.random.default_rng(0)
    system = rng.integers(1, cfg.vocab, args.prefix_len).astype(np.int32)
    reqs = [Request(i, np.concatenate(
                [system, rng.integers(1, cfg.vocab, rng.integers(4, 16))
                 .astype(np.int32)]), max_new=args.max_new)
            for i in range(args.requests)]
    if roles and any(r != "mixed" for r in roles):
        # disaggregated fleet: ONE routed run -- the admission router
        # forwards cold prefixes to the prefill pods, decode pods serve
        # the handed-back streams suffix-only (default decode affinity)
        cluster = MultiHostServingCluster(
            cfg, lambda: params, n_hosts=args.hosts,
            n_shards=args.shards or None, roles=roles, **kw)
        done, report = cluster.run(reqs)
    elif args.hosts > 1:
        cluster = MultiHostServingCluster(
            cfg, lambda: params, n_hosts=args.hosts,
            n_shards=args.shards or None, roles=roles or None, **kw)
        # phase 1: host 0 prefills + publishes the shared prefix; phase 2:
        # the other hosts serve the same system prompt suffix-only
        n0 = max(1, len(reqs) // args.hosts)
        cluster.run(reqs[:n0], affinity=[0] * n0)
        done, report = cluster.run(
            reqs[n0:],
            affinity=[1 + i % (args.hosts - 1)
                      for i in range(len(reqs) - n0)])
        done = reqs
    else:
        cluster = ServingCluster(cfg, lambda: params, **kw)
        done, report = cluster.run(reqs)
    print(f"served {len(done)} requests on {args.replicas} replicas x "
          f"{args.hosts} host(s) ({args.arch} reduced)")
    for k, v in report.items():
        print(f"  {k:28s} {v}")
    if report["prefix_prefill_tokens_skipped"]:
        print(f"paged-KV pool: prefill skipped "
              f"{report['prefix_prefill_tokens_skipped']} prompt tokens, "
              f"{report['prefix_flops_saved']/1e9:.2f} GFLOPs saved")
    if getattr(cluster, "paged", True):      # multi-host is always paged
        print(f"paged decode: {report['kv_tokens_appended']} token rows "
              f"through pool pages, peak {report['pool_page_peak']} pages "
              f"in use, {report['pool_pages_freed']} freed")
    if args.hosts > 1:
        print(f"sharded directory: {report['xhost_msgs']} cross-host msgs "
              f"({report['xhost_bytes']} bytes), "
              f"{report['xhost_migrations']} pages migrated, "
              f"{report['xhost_multicasts']} multicasts, "
              f"{report['xhost_invalidation_msgs']} invalidation msgs")
    if roles and any(r != "mixed" for r in roles):
        ticks = sum(report.get(f"host{h}_decode_ticks", 0)
                    for h, r in enumerate(roles) if r != "prefill")
        rmsgs = sum(report.get(f"host{h}_role_renewal_msgs", 0)
                    for h, r in enumerate(roles) if r != "prefill")
        per_tick = rmsgs / ticks if ticks else 0.0
        print(f"disaggregated: roles={','.join(roles)}, "
              f"{report['router_cold_forwards']} cold forwards, "
              f"{report['router_handoffs']} handoffs, "
              f"decode-pod renewal msgs/tick {per_tick:.3f}")


if __name__ == "__main__":
    main()
