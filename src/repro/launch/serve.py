"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Starts a Tardis-coherent replica cluster on the selected architecture's
reduced config and serves synthetic batched requests (the full configs are
exercised by the multi-pod dry-run; see repro.launch.dryrun).
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCHS, get_arch, reduced
from ..models import init_params
from ..runtime import Request, ServingCluster


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--lease", type=int, default=8)
    ap.add_argument("--prefix-len", type=int, default=16,
                    help="shared system-prompt tokens (prefix-KV reuse)")
    ap.add_argument("--prefix-block", type=int, default=8)
    ap.add_argument("--decode-pages", type=int, default=256,
                    help="allocator-region pool pages (admission bound)")
    ap.add_argument("--max-pages", type=int, default=32,
                    help="page-table length per request")
    ap.add_argument("--max-batch", type=int, default=4,
                    help="continuous-batch slots per replica")
    args = ap.parse_args()

    cfg = reduced(get_arch(args.arch))
    if cfg.family == "encdec":
        raise SystemExit("serve CLI drives decoder-only archs; whisper is "
                         "exercised via tests/dry-run (needs frame inputs)")
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    cluster = ServingCluster(cfg, lambda: params, n_replicas=args.replicas,
                             lease=args.lease,
                             prefix_block_tokens=args.prefix_block,
                             kv_lease=16, cache_len=96,
                             n_decode_pages=args.decode_pages,
                             max_pages=args.max_pages,
                             selfinc_period=4, max_batch=args.max_batch)
    rng = np.random.default_rng(0)
    system = rng.integers(1, cfg.vocab, args.prefix_len).astype(np.int32)
    reqs = [Request(i, np.concatenate(
                [system, rng.integers(1, cfg.vocab, rng.integers(4, 16))
                 .astype(np.int32)]), max_new=args.max_new)
            for i in range(args.requests)]
    done, report = cluster.run(reqs)
    print(f"served {len(done)} requests on {args.replicas} replicas "
          f"({args.arch} reduced)")
    for k, v in report.items():
        print(f"  {k:28s} {v}")
    if report["prefix_prefill_tokens_skipped"]:
        print(f"paged-KV pool: prefill skipped "
              f"{report['prefix_prefill_tokens_skipped']} prompt tokens, "
              f"{report['prefix_flops_saved']/1e9:.2f} GFLOPs saved")
    if cluster.paged:
        print(f"paged decode: {report['kv_tokens_appended']} token rows "
              f"through pool pages, peak {report['pool_page_peak']} pages "
              f"in use, {report['pool_pages_freed']} freed")


if __name__ == "__main__":
    main()
