"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
touches no jax device state.  The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; real deployments get the same shapes from the TPU runtime.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def _make_mesh(shape, axes, devices) -> Mesh:
    # no axis_types: Auto is the default on every jax that accepts it.
    return jax.make_mesh(shape, axes, devices=devices)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single pod: (16, 16) = (data, model) = 256 chips.
    Multi-pod: (2, 16, 16) = (pod, data, model) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} "
            "(dry-run must set --xla_force_host_platform_device_count=512 "
            "before importing jax)")
    return _make_mesh(shape, axes, devices[:n])


def make_host_mesh(*, data: int = 1, model: int = 1) -> Mesh:
    """Small mesh for tests/examples on whatever devices exist."""
    devices = jax.devices()[: data * model]
    return _make_mesh((data, model), ("data", "model"), devices)
