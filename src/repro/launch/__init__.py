# NOTE: deliberately import-free -- repro.launch.dryrun must set XLA_FLAGS
# before jax is imported anywhere in the process.
