from .pipeline import Prefetcher, shard_batch, synthetic_batch

__all__ = ["Prefetcher", "shard_batch", "synthetic_batch"]
