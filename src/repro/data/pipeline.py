"""Deterministic synthetic data pipeline with background prefetch.

Batches are a pure function of (seed, step) so every restart -- including
elastic restarts onto a different mesh -- replays the exact token stream
(checkpoint stores only the step).  The token stream is a mixture of Zipf
unigrams and repeated n-grams so small models show a real, declining loss.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import numpy as np


def synthetic_batch(seed: int, step: int, batch: int, seq: int,
                    vocab: int, extras: Optional[Dict] = None):
    rng = np.random.default_rng(np.uint64(seed * 1_000_003 + step))
    # zipfian unigrams
    z = rng.zipf(1.3, size=(batch, seq + 1))
    toks = (z % (vocab - 2)) + 1
    # inject copyable n-grams (predictable structure to learn)
    for b in range(batch):
        pat_len = int(rng.integers(4, 12))
        pat = rng.integers(1, vocab - 1, pat_len)
        reps = (seq + 1) // (pat_len * 2)
        for r in range(reps):
            at = int(rng.integers(0, seq + 1 - pat_len))
            toks[b, at:at + pat_len] = pat
    tokens = toks[:, :-1].astype(np.int32)
    labels = toks[:, 1:].astype(np.int32)
    out = {"tokens": tokens, "labels": labels}
    if extras:
        for k, spec in extras.items():
            out[k] = rng.normal(size=spec["shape"]).astype(spec.get(
                "dtype", np.float32))
    return out


class Prefetcher:
    """Host-side background prefetch of the next N batches."""

    def __init__(self, make_batch, start_step: int = 0, depth: int = 2):
        self._make = make_batch
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        s = self._step
        while not self._stop.is_set():
            try:
                self._q.put((s, self._make(s)), timeout=0.2)
                s += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()


def shard_batch(batch: Dict[str, np.ndarray], shardings):
    """Place a host batch onto the mesh with the given shardings."""
    return {k: jax.device_put(v, shardings[k]) for k, v in batch.items()}
