from .train_loop import TrainConfig, train
from .serve_loop import DecodeReplica, Request, ServingCluster
from .elastic import ElasticTrainer, ElasticReport

__all__ = ["TrainConfig", "train", "DecodeReplica", "Request",
           "ServingCluster", "ElasticTrainer", "ElasticReport"]
