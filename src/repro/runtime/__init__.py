from .train_loop import TrainConfig, train
from .serve_loop import (CoherenceReport, DecodeReplica,
                         MultiHostServingCluster, Request, ServingCluster)
from .elastic import ElasticTrainer, ElasticReport

__all__ = ["TrainConfig", "train", "CoherenceReport", "DecodeReplica",
           "MultiHostServingCluster", "Request", "ServingCluster",
           "ElasticTrainer", "ElasticReport"]
