"""Elastic data-parallel training with Tardis-leased parameters.

The learner publishes parameter versions into a TardisStore; each worker
computes gradients against its *leased* copy.  Because a publish jumps ahead
of outstanding leases instead of broadcasting, workers that are mid-step keep
a consistent (slightly stale) version and renew at their next step -- the
paper's deferred update propagation, used here as **bounded logical
staleness**: a worker can be at most ``lease`` logical ticks behind, and the
global order of versions is explicit in the timestamps.

Workers join and leave freely: joining = first acquire (full payload),
leaving = nothing at all (no sharer list to clean up -- the O(log N) scaling
argument of the paper, applied to the training control plane).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List

import jax
import jax.numpy as jnp

from ..core.store import Replica, TardisStore
from ..optim import adamw


@dataclasses.dataclass
class ElasticReport:
    steps: int
    losses: List[float]
    versions_used: List[int]
    max_staleness: int
    renewals: int
    data_less: int
    joins: int
    leaves: int
    # on-wire traffic incl. metadata headers (protocol.MESSAGE_FLITS)
    wire_flits: int = 0
    wire_bytes: int = 0
    payload_bytes: int = 0


class ElasticWorker:
    def __init__(self, store: TardisStore, name: str, grad_fn,
                 selfinc_period: int = 1):
        self.reader = Replica(store, name, selfinc_period=selfinc_period)
        self.grad_fn = grad_fn

    def step(self, batch):
        params = self.reader.read("params")
        wts = self.reader.cached_version("params")
        loss, grads = self.grad_fn(params, batch)
        return loss, grads, wts


class ElasticTrainer:
    """Learner + dynamic worker pool (cooperative simulation of a fleet)."""

    def __init__(self, params, grad_fn, make_batch, *, lease: int = 2,
                 lr: float = 1e-2):
        self.store = TardisStore(lease=lease)
        self.pub = Replica(self.store, "learner")
        nbytes = sum(x.size * x.dtype.itemsize
                     for x in jax.tree.leaves(params))
        self.nbytes = nbytes
        self.pub.write("params", params, nbytes=nbytes)
        self.params = params
        self.opt = adamw.init(params)
        self.grad_fn = grad_fn
        self.make_batch = make_batch
        self.lr = lr
        self.workers: List[ElasticWorker] = []
        self._wid = 0
        self.joins = 0
        self.leaves = 0

    def scale_to(self, n: int):
        while len(self.workers) < n:
            self.workers.append(ElasticWorker(
                self.store, f"w{self._wid}", self.grad_fn))
            self._wid += 1
            self.joins += 1
        while len(self.workers) > n:
            self.workers.pop()            # no protocol action on leave
            self.leaves += 1

    def run(self, steps: int,
            schedule: Callable[[int], int] = lambda s: 2) -> ElasticReport:
        losses, versions = [], []
        max_stale = 0
        for s in range(steps):
            self.scale_to(max(1, schedule(s)))
            grad_sum = None
            cur_wts = self.store.versions()["params"]
            for i, w in enumerate(self.workers):
                loss, grads, wts = w.step(self.make_batch(s, i))
                versions.append(wts)
                max_stale = max(max_stale, cur_wts - wts)
                g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
                grad_sum = g32 if grad_sum is None else jax.tree.map(
                    jnp.add, grad_sum, g32)
                losses.append(float(loss))
            grads = jax.tree.map(lambda g: g / len(self.workers), grad_sum)
            self.params, self.opt, _ = adamw.update(
                self.params, grads, self.opt, lr=self.lr, weight_decay=0.0)
            self.pub.write("params", self.params, nbytes=self.nbytes)
        st = self.store.stats
        return ElasticReport(
            steps=steps, losses=losses, versions_used=versions,
            max_staleness=max_stale, renewals=st.renews,
            data_less=st.renew_data_less, joins=self.joins,
            leaves=self.leaves, wire_flits=st.flits,
            wire_bytes=st.wire_bytes, payload_bytes=st.bytes_transferred)
