"""Fault-tolerant training loop.

Production concerns exercised here (and by tests/examples):
  * periodic sharded checkpoints (atomic; manifest carries the Tardis wts of
    the published parameter version),
  * crash/restart: any exception (or injected failure) restores the latest
    checkpoint and replays the deterministic data stream from that step,
  * straggler mitigation: per-step deadline = ``straggler_factor`` x rolling
    median; a breach is logged and counted (on real fleets this triggers the
    spare-swap path; the hook is ``on_straggler``),
  * optional int8 gradient compression with error feedback,
  * optional microbatch accumulation (overlap-friendly scan structure).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import ckpt
from ..data.pipeline import synthetic_batch
from ..dist.collectives import (compress_grads, decompress_grads,
                                init_residual, microbatch_grads)
from ..models import loss_fn as model_loss
from ..optim import adamw


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    ckpt_dir: str = ""
    ckpt_every: int = 25
    keep: int = 2
    base_lr: float = 3e-4
    warmup: int = 20
    batch: int = 8
    seq: int = 128
    seed: int = 0
    grad_compression: bool = False
    n_micro: int = 1
    straggler_factor: float = 3.0
    fail_at_step: int = -1          # inject one crash at this step
    log_every: int = 10


def build_step(cfg_model, tc: TrainConfig):
    lr_fn = adamw.cosine_schedule(tc.base_lr, tc.warmup, tc.steps)

    def step_fn(params, opt_state, residual, batch):
        if tc.n_micro > 1:
            loss, grads = microbatch_grads(
                lambda p, b: model_loss(cfg_model, p, b), params, batch,
                tc.n_micro)
        else:
            loss, grads = jax.value_and_grad(
                lambda p: model_loss(cfg_model, p, batch))(params)
        if tc.grad_compression:
            qs, residual = compress_grads(grads, residual)
            grads = decompress_grads(qs)     # what crosses the DP axis
        lr = lr_fn(opt_state["step"] + 1)
        params, opt_state, metrics = adamw.update(
            params, grads, opt_state, lr=lr)
        metrics["loss"] = loss
        metrics["lr"] = lr
        return params, opt_state, residual, metrics

    return jax.jit(step_fn, donate_argnums=(0, 1, 2))


def train(cfg_model, params, tc: TrainConfig,
          on_straggler: Optional[Callable[[int, float], None]] = None,
          on_metrics: Optional[Callable[[int, Dict], None]] = None
          ) -> Dict[str, Any]:
    """Runs the loop; returns summary {losses, restarts, stragglers, step}."""
    opt_state = adamw.init(params)
    residual = init_residual(params) if tc.grad_compression else \
        jax.tree.map(lambda _: jnp.zeros((), jnp.float32), params)
    step_fn = build_step(cfg_model, tc)

    start = 0
    if tc.ckpt_dir and ckpt.latest_step(tc.ckpt_dir) is not None:
        (params, opt_state), manifest = ckpt.restore(
            tc.ckpt_dir, (params, opt_state))
        start = manifest["step"]

    losses: List[float] = []
    durations: List[float] = []
    restarts = 0
    stragglers = 0
    injected = tc.fail_at_step
    step = start
    while step < tc.steps:
        try:
            batch = synthetic_batch(tc.seed, step, tc.batch, tc.seq,
                                    cfg_model.vocab)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            t0 = time.time()
            if step == injected:
                injected = -1            # fire once
                raise RuntimeError("injected node failure")
            params, opt_state, residual, metrics = step_fn(
                params, opt_state, residual, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            durations.append(dt)
            med = float(np.median(durations[-20:]))
            if len(durations) > 5 and dt > tc.straggler_factor * med:
                stragglers += 1
                if on_straggler:
                    on_straggler(step, dt)
            losses.append(loss)
            if on_metrics and step % tc.log_every == 0:
                on_metrics(step, {**{k: float(v) for k, v in metrics.items()},
                                  "step_s": dt})
            step += 1
            if tc.ckpt_dir and step % tc.ckpt_every == 0:
                ckpt.save(tc.ckpt_dir, step, (params, opt_state),
                          wts=step, keep=tc.keep)
        except (RuntimeError, jax.errors.JaxRuntimeError) as e:
            if "injected" not in str(e):
                raise
            restarts += 1
            if tc.ckpt_dir and ckpt.latest_step(tc.ckpt_dir) is not None:
                (params, opt_state), manifest = ckpt.restore(
                    tc.ckpt_dir, (params, opt_state))
                step = manifest["step"]
            else:                         # no checkpoint yet: restart cold
                opt_state = adamw.init(params)
                step = 0
    if tc.ckpt_dir:
        ckpt.save(tc.ckpt_dir, step, (params, opt_state), wts=step,
                  keep=tc.keep)
    return {"losses": losses, "restarts": restarts,
            "stragglers": stragglers, "final_step": step,
            "params": params, "opt_state": opt_state}
