"""Tardis-coherent serving engine: continuous batching + leased weights/KV.

Multiple decode replicas serve requests against
  * a shared *weight version* (hot-swapped by a trainer/publisher), and
  * a shared paged prefix-KV block store (RadixAttention-style reuse),
both coherent through the TardisStore: replicas hold leases, renew on expiry
(data-less when unchanged -- the common case), and a weight publish never
broadcasts: it jumps ahead of all outstanding leases.  Metadata is O(log N)
per object; there is no sharer list in the system.

The engine is single-process (replicas are cooperative objects) but every
coherence message is accounted, so benchmarks can compare against a
directory-style invalidation broadcast on the same request stream.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.store import BlockTable, Replica, TardisStore
from ..models import decode_step, init_cache, prefill


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray               # (S,) int32
    max_new: int = 8
    done: bool = False
    output: Optional[np.ndarray] = None


class DecodeReplica:
    """One model replica: leased weights + local continuous batch."""

    def __init__(self, cfg, store: TardisStore, name: str,
                 max_batch: int = 4, cache_len: int = 256,
                 selfinc_period: int = 8):
        self.cfg = cfg
        self.name = name
        self.reader = Replica(store, name, selfinc_period=selfinc_period)
        self.max_batch = max_batch
        self.cache_len = cache_len
        self._decode = jax.jit(
            lambda p, c, t, i: decode_step(cfg, p, c, t, i))
        self._prefill = jax.jit(
            lambda p, b: prefill(cfg, p, b, cache_len))

    def params(self):
        """Weight access through the lease (renewal-on-expiry)."""
        return self.reader.read("params")

    def serve(self, reqs: List[Request]) -> List[Request]:
        """Greedy-decode a wave of requests (one continuous batch)."""
        if not reqs:
            return reqs
        params = self.params()
        s = max(len(r.prompt) for r in reqs)
        toks = np.zeros((len(reqs), s), np.int32)
        for i, r in enumerate(reqs):
            toks[i, :len(r.prompt)] = r.prompt
        cache, logits = self._prefill(params, {"tokens": jnp.asarray(toks)})
        outs = [[] for _ in reqs]
        cur = jnp.int32(s)
        next_tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        max_new = max(r.max_new for r in reqs)
        for step in range(max_new):
            for i in range(len(reqs)):
                outs[i].append(int(next_tok[i, 0]))
            params = self.params()           # lease check per decode wave
            cache, logits = self._decode(params, cache, next_tok, cur)
            next_tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
            cur = cur + 1
        for r, o in zip(reqs, outs):
            r.output = np.asarray(o[:r.max_new], np.int32)
            r.done = True
        return reqs


class ServingCluster:
    """N replicas + weight publisher + shared prefix-KV block table."""

    def __init__(self, cfg, init_params_fn: Callable[[], Any],
                 n_replicas: int = 2, lease: int = 10,
                 n_prefix_blocks: int = 4096, **replica_kw):
        self.store = TardisStore(lease=lease)
        p0 = init_params_fn()
        nbytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(p0))
        self.publisher = Replica(self.store, "trainer")
        self.publisher.write("params", p0, nbytes=nbytes)
        self.param_bytes = nbytes
        self.replicas = [
            DecodeReplica(cfg, self.store, f"replica{i}", **replica_kw)
            for i in range(n_replicas)]
        self.prefix_blocks = BlockTable(n_prefix_blocks)

    def publish_weights(self, params) -> int:
        """Hot-swap: no invalidation broadcast; replicas renew on expiry."""
        self.publisher.write("params", params, nbytes=self.param_bytes)
        return self.publisher.pts

    def run(self, requests: List[Request]) -> Tuple[List[Request], Dict]:
        waves: List[List[Request]] = []
        for i, r in enumerate(requests):
            if i % len(self.replicas) == 0:
                waves.append([])
            waves[-1].append(r)
        for i, wave in enumerate(waves):
            rep = self.replicas[i % len(self.replicas)]
            rep.serve(wave)
        return requests, self.coherence_report()

    def coherence_report(self) -> Dict[str, Any]:
        s = self.store.stats
        saved = s.renew_data_less * self.param_bytes
        return {
            "reads": s.reads, "writes": s.writes,
            "renewals": s.renews, "data_less_renewals": s.renew_data_less,
            "payload_transfers": s.payload_transfers,
            "bytes_transferred": s.bytes_transferred,
            "bytes_saved_by_renewals": saved,
            "directory_would_invalidate": s.dir_invalidations,
            "directory_peak_sharers": s.dir_sharer_bits,
            "replica_local_hits": sum(r.reader.local_hits
                                      for r in self.replicas),
        }
