"""Tardis-coherent serving engine: continuous batching + leased weights/KV.

Multiple decode replicas serve requests against
  * a shared *weight version* (hot-swapped by a trainer/publisher), and
  * a shared paged prefix-KV block store (RadixAttention-style reuse),
both coherent through Tardis leases: replicas hold leases, renew on expiry
(data-less when unchanged -- the common case), and a weight publish never
broadcasts: it jumps ahead of all outstanding leases.  Metadata is O(log N)
per object; there is no sharer list in the system.

Weights go through :class:`repro.core.store.TardisStore`; the prefix-KV
block table is a :class:`repro.core.lease_engine.LeaseEngine` whose
read/renew/write-jump-ahead transitions run in the ``tardis_lease`` Pallas
kernel.  Prefill hashes prompt-prefix chunks to block ids (content
addressing, CRC-chained so a block id names the *whole* prefix up to that
chunk); blocks whose content tag matches are leased -- locally when the
replica's lease still covers its pts, by data-less renewal when the version
is unchanged, by payload transfer otherwise -- and new prefixes are written
with the jump-ahead rule, evicting colliding tags without any invalidation
(readers of the old content keep their leases, exactly the paper's stale-
but-SC-legal window).

Leased blocks carry the *actual* paged KV tensors: the engine's pool holds
one ``(chunk, 2, n_layers*kv_heads, head_dim)`` payload per block, filled
by write-back after a wave prefills a new prefix and materialized through
the Pallas gather kernel when a later wave hits -- prefill then runs only
the suffix (``models.prefill_suffix``), skipping the prefix's attention and
MLP entirely (``prefix_flops_saved`` in the coherence report).  The lease
protocol itself is batched per wave: one logical tick, one
``read_many`` kernel dispatch for every renewal in the wave and at most one
jump-ahead write over the union of its misses, instead of per-request
full-table passes.

The engine is single-process (replicas are cooperative objects) but every
coherence message is accounted in flits, so benchmarks can compare against
a directory-style invalidation broadcast on the same request stream.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.lease_engine import LeaseEngine
from ..core.store import Replica, TardisStore
from ..models import decode_step, init_cache, prefill, prefill_suffix

# families whose prefill KV cache is position-addressable block-wise, i.e.
# can be carried through the paged prefix-KV pool (an SSM state cannot).
KV_POOL_FAMILIES = ("dense", "vlm")


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray               # (S,) int32
    max_new: int = 8
    done: bool = False
    output: Optional[np.ndarray] = None


@dataclasses.dataclass
class WavePlan:
    """Outcome of the per-wave batched lease protocol for one wave.

    ``groups`` holds each request's prefix block ids; ``skip_tokens`` /
    ``skip_bids`` name the pool-backed common prefix prefill may skip
    (pool-valid *before* this wave, identical bids across the wave);
    ``miss_writers`` maps each newly-written block id to the
    ``(request_index, chunk_index)`` whose prefill output backs it, and
    ``repair_writers`` the tag-hit blocks whose pool slot is invalid (e.g.
    freed by a weight publish) and gets repopulated by this wave's prefill.
    """
    groups: List[List[int]]
    skip_tokens: int
    skip_bids: List[int]
    miss_writers: Dict[int, Tuple[int, int]]
    repair_writers: Dict[int, Tuple[int, int]]


def _prefix_cache(kp, vp, batch, cache_len: int, skip: int):
    """Per-layer (L, skip, hk, dh) leased prefix KV -> a wave's
    (L, B, cache_len, hk, dh) decode cache with the prefix pre-filled."""
    shape = (kp.shape[0], batch, cache_len) + kp.shape[2:]
    kc = jnp.zeros(shape, jnp.bfloat16)
    vc = jnp.zeros(shape, jnp.bfloat16)
    return {"k": kc.at[:, :, :skip].set(kp[:, None].astype(jnp.bfloat16)),
            "v": vc.at[:, :, :skip].set(vp[:, None].astype(jnp.bfloat16))}


class DecodeReplica:
    """One model replica: leased weights + local continuous batch.

    Besides the weight lease (via ``self.reader``) the replica keeps its own
    program timestamp ``kv_pts`` and cached ``(wts, rts)`` leases for prefix-
    KV blocks; the cluster's LeaseEngine is their timestamp manager.
    """

    def __init__(self, cfg, store: TardisStore, name: str,
                 max_batch: int = 4, cache_len: int = 256,
                 selfinc_period: int = 8):
        self.cfg = cfg
        self.name = name
        self.reader = Replica(store, name, selfinc_period=selfinc_period)
        self.max_batch = max_batch
        self.cache_len = cache_len
        self.kv_pts = 0
        # bid -> (wts, rts, content_tag): the tag names WHICH prefix the
        # cached copy holds; a lease alone says a read is SC-legal, the tag
        # says it is the content this request wants (collision evictions
        # re-tag blocks without invalidating anybody).
        self.kv_leases: Dict[int, Tuple[int, int, int]] = {}
        self.last_prefill_cache = None   # wave's KV, read by pool write-back
        self._decode = jax.jit(
            lambda p, c, t, i: decode_step(cfg, p, c, t, i))
        self._prefill = jax.jit(
            lambda p, b: prefill(cfg, p, b, cache_len))
        # the prefix cache is assembled INSIDE the jit so XLA fuses the
        # zeros + prefix scatter instead of shipping full caches as inputs
        self._prefill_suffix = jax.jit(
            lambda p, b, kp, vp, n: prefill_suffix(
                cfg, p, b,
                _prefix_cache(kp, vp, b["tokens"].shape[0], cache_len, n),
                n),
            static_argnums=4)

    def params(self):
        """Weight access through the lease (renewal-on-expiry)."""
        return self.reader.read("params")

    def rebase_kv(self, shift: int) -> None:
        """Apply an engine rebase: shift pts/leases; drop leases whose rts
        would fall below the new base (cannot be raised unilaterally)."""
        if not shift:
            return
        self.kv_pts = max(0, self.kv_pts - shift)
        self.kv_leases = {
            bid: (max(0, w - shift), r - shift, t)
            for bid, (w, r, t) in self.kv_leases.items() if r >= shift}

    def serve(self, reqs: List[Request], prefix_kv=None,
              skip: int = 0, params=None) -> List[Request]:
        """Greedy-decode a wave of requests (one continuous batch).

        When ``prefix_kv`` carries the wave's shared leased prefix --
        per-layer ``(k, v)`` of shape (L, skip, kv_heads, head_dim),
        materialized from the engine's paged pool -- prefill runs only on
        the suffix tokens, skipping the prefix's attention + MLP.
        ``params`` may be preloaded by the caller (the cluster reads the
        weight lease first so it can match pool KV to the weight version
        this prefill will actually use).
        """
        if not reqs:
            return reqs
        if params is None:
            params = self.params()
        s = max(len(r.prompt) for r in reqs)
        toks = np.zeros((len(reqs), s), np.int32)
        for i, r in enumerate(reqs):
            toks[i, :len(r.prompt)] = r.prompt
        if prefix_kv is not None and 0 < skip < s:
            kp, vp = prefix_kv
            cache, logits = self._prefill_suffix(
                params, {"tokens": jnp.asarray(toks[:, skip:])},
                kp, vp, int(skip))
        else:
            cache, logits = self._prefill(params,
                                          {"tokens": jnp.asarray(toks)})
        self.last_prefill_cache = cache
        outs = [[] for _ in reqs]
        cur = jnp.int32(s)
        next_tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        max_new = max(r.max_new for r in reqs)
        for step in range(max_new):
            for i in range(len(reqs)):
                outs[i].append(int(next_tok[i, 0]))
            params = self.params()           # lease check per decode wave
            cache, logits = self._decode(params, cache, next_tok, cur)
            next_tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
            cur = cur + 1
        for r, o in zip(reqs, outs):
            r.output = np.asarray(o[:r.max_new], np.int32)
            r.done = True
        return reqs


class ServingCluster:
    """N replicas + weight publisher + shared prefix-KV block table."""

    def __init__(self, cfg, init_params_fn: Callable[[], Any],
                 n_replicas: int = 2, lease: int = 10,
                 n_prefix_blocks: int = 4096, prefix_block_tokens: int = 16,
                 kv_lease: int = 64, prefix_reuse: bool = True,
                 ts_bits: int = 30, prefix_backend: str = "pallas",
                 **replica_kw):
        self.cfg = cfg
        self.store = TardisStore(lease=lease)
        p0 = init_params_fn()
        nbytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(p0))
        self.publisher = Replica(self.store, "trainer")
        self.publisher.write("params", p0, nbytes=nbytes)
        self.param_bytes = nbytes
        # forward-pass cost of one prompt token (2 flops per param-weight);
        # what a prefix-pool hit saves prefill per skipped token.
        self._flops_per_token = 2 * int(
            sum(x.size for x in jax.tree.leaves(p0)))
        self.replicas = [
            DecodeReplica(cfg, self.store, f"replica{i}", **replica_kw)
            for i in range(n_replicas)]
        # paged prefix-KV blocks: lease metadata + real KV payloads (for
        # attention-cache families) in one engine.
        self.prefix_block_tokens = int(prefix_block_tokens)
        self.prefix_reuse = bool(prefix_reuse)
        kv_bytes = (2 * cfg.n_layers * cfg.n_kv_heads * cfg.head_dim()
                    * 4 * self.prefix_block_tokens)
        kv_shape = None
        if self.prefix_reuse and cfg.family in KV_POOL_FAMILIES:
            kv_shape = (self.prefix_block_tokens, 2,
                        cfg.n_layers * cfg.n_kv_heads, cfg.head_dim())
        self.prefix_engine = LeaseEngine(
            n_prefix_blocks, lease=kv_lease, block_bytes=kv_bytes,
            ts_bits=ts_bits, backend=prefix_backend,
            kv_block_shape=kv_shape)
        self._tags = np.full(n_prefix_blocks, -1, np.int64)  # content hashes
        # weight version each pool slot's KV was computed under: a wave may
        # only skip prefill on KV matching the weights it will serve with
        # (same-version staleness is SC-legal; cross-version mixing is not)
        self._pool_wver = np.full(n_prefix_blocks, -1, np.int64)
        self.prefix_stats = {
            "prefix_block_hits": 0, "prefix_local_hits": 0,
            "prefix_renewals": 0, "prefix_block_misses": 0,
            "prefix_evictions": 0, "prefix_tokens_reused": 0,
            "prefix_prefill_tokens_skipped": 0, "prefix_flops_saved": 0,
        }

    def publish_weights(self, params) -> int:
        """Hot-swap: no invalidation broadcast; replicas renew on expiry.

        The prefix-KV pool's payloads were computed under the OLD weights,
        and pool validity (unlike a lease) never expires -- so the publish
        frees every pool slot locally (a manager-side bitmap clear, zero
        messages to replicas; tags and lease metadata stay).  Later waves
        repair the slots from their own prefill (``repair_writers``).
        """
        self.publisher.write("params", params, nbytes=self.param_bytes)
        if self.prefix_engine.has_kv:
            self.prefix_engine.invalidate_kv(
                np.arange(self.prefix_engine.n_blocks))
        return self.publisher.pts

    # -- prefix-KV reuse ----------------------------------------------------

    def _prefix_blocks_of(self, prompt: np.ndarray) -> Tuple[List[int],
                                                             List[int]]:
        """Chain-hash whole prompt prefixes into (block_ids, content_tags)."""
        bt = self.prefix_block_tokens
        bids, tags = [], []
        h = 0
        for c in range(len(prompt) // bt):
            h = zlib.crc32(np.ascontiguousarray(
                prompt[c * bt:(c + 1) * bt]).tobytes(), h)
            bids.append(h % self.prefix_engine.n_blocks)
            tags.append(h)
        return bids, tags

    def _lease_prefix(self, rep: DecodeReplica, prompt: np.ndarray) -> None:
        """Single-request compatibility wrapper: a wave of one."""
        self._lease_prefix_wave(rep, [prompt])

    def _lease_prefix_wave(self, rep: DecodeReplica,
                           prompts: List[np.ndarray]) -> WavePlan:
        """Per-wave batched prefix leasing for one replica.

        The whole wave charges ONE logical tick (the paper's self-inc
        bounds staleness per protocol interaction, and the wave is one
        interaction), classifies every request's blocks against the same
        table snapshot, then resolves all renewals in a single
        ``read_many`` kernel dispatch and all misses in at most one
        jump-ahead write over their union -- N requests sharing a system
        prompt collapse to 1 read + <=1 write instead of N full-table
        dispatch pairs.  No invalidation reaches other replicas.
        """
        rep.kv_pts += 1
        ps = self.prefix_stats
        bt = self.prefix_block_tokens
        groups, tags_by_req = [], []
        for prompt in prompts:
            bids, tags = self._prefix_blocks_of(prompt)
            groups.append(bids)
            tags_by_req.append(tags)
        # pool-backed leading blocks per request, against the PRE-wave pool
        # (blocks written later this wave aren't materialized yet).
        covered = []
        for bids, tags in zip(groups, tags_by_req):
            c = 0
            for bid, tag in zip(bids, tags):
                if self._tags[bid] != tag or not self.prefix_engine.kv_ok(bid):
                    break
                c += 1
            covered.append(c)
        skip_blocks = min(covered) if covered else 0
        while skip_blocks and any(g[:skip_blocks] != groups[0][:skip_blocks]
                                  for g in groups):
            skip_blocks -= 1         # hash collision: bids diverge, back off
        skip_bids = list(groups[0][:skip_blocks]) if skip_blocks else []

        local_wts: List[int] = []
        renew_groups: List[List[int]] = [[] for _ in prompts]
        renew_req: Dict[int, int] = {}
        miss_writers: Dict[int, Tuple[int, int]] = {}
        repair_writers: Dict[int, Tuple[int, int]] = {}
        for ri, (bids, tags) in enumerate(zip(groups, tags_by_req)):
            for c, (bid, tag) in enumerate(zip(bids, tags)):
                if self._tags[bid] == tag:
                    ps["prefix_block_hits"] += 1
                    ps["prefix_tokens_reused"] += bt
                    if (self.prefix_engine.has_kv
                            and not self.prefix_engine.kv_ok(bid)
                            and bid not in repair_writers):
                        # tag hit but the payload slot was freed (weight
                        # publish / eviction): repopulate from this wave
                        repair_writers[bid] = (ri, c)
                    ent = rep.kv_leases.get(bid)
                    cached_ok = ent is not None and ent[2] == tag
                    if cached_ok and rep.kv_pts <= ent[1]:
                        ps["prefix_local_hits"] += 1   # unexpired lease
                        local_wts.append(ent[0])
                    else:
                        renew_groups[ri].append(bid)
                        if bid not in renew_req:
                            # a copy of DIFFERENT content can't renew
                            renew_req[bid] = ent[0] if cached_ok else -1
                else:
                    if self._tags[bid] != -1:
                        ps["prefix_evictions"] += 1    # collision: re-tag
                        if self.prefix_engine.has_kv:
                            # the slot's payload no longer matches its tag
                            self.prefix_engine.invalidate_kv([bid])
                    ps["prefix_block_misses"] += 1
                    self._tags[bid] = tag
                    miss_writers[bid] = (ri, c)        # last writer wins
        if local_wts:                                  # Table II local hits
            rep.kv_pts = max(rep.kv_pts, max(local_wts))
        active = [g for g in renew_groups if g]
        if active:                                     # ONE kernel dispatch
            res = self.prefix_engine.read_many(active, rep.kv_pts,
                                               req_wts=renew_req)
            rep.kv_pts = int(res.new_pts.max())
            ps["prefix_renewals"] += sum(
                1 for b in res.union_idx if renew_req[int(b)] >= 0)
            for i, bid in enumerate(res.union_idx):
                bid = int(bid)
                rep.kv_leases[bid] = (int(res.wts[i]), int(res.rts[i]),
                                      int(self._tags[bid]))
        if miss_writers:                               # one wave jump-ahead
            ts = self.prefix_engine.write_many([list(miss_writers)],
                                               rep.kv_pts)
            rep.kv_pts = ts
            for bid in miss_writers:
                rep.kv_leases[bid] = (ts, ts, int(self._tags[bid]))
        # a repair superseded by a same-wave eviction defers to the miss
        repair_writers = {b: rc for b, rc in repair_writers.items()
                          if b not in miss_writers}
        return WavePlan(groups, skip_blocks * bt, skip_bids, miss_writers,
                        repair_writers)

    def _maybe_rebase(self) -> None:
        shift = self.prefix_engine.maybe_rebase()
        if shift:
            for rep in self.replicas:
                rep.rebase_kv(shift)

    # -- paged-KV pool <-> per-layer cache layout ---------------------------

    def _pool_to_layer_kv(self, pooled) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """(nb, chunk, 2, L*hk, dh) pool blocks -> per-layer (L, P, hk, dh)
        k and v, P = nb * chunk contiguous prefix tokens."""
        nb, bt = pooled.shape[0], self.prefix_block_tokens
        layers, hk = self.cfg.n_layers, self.cfg.n_kv_heads
        dh = self.cfg.head_dim()
        kv = jnp.asarray(pooled).reshape(nb, bt, 2, layers, hk, dh)
        kv = kv.transpose(2, 3, 0, 1, 4, 5).reshape(2, layers, nb * bt,
                                                    hk, dh)
        return kv[0], kv[1]

    def _cache_block_kv(self, cache, ri: int, chunk: int) -> jnp.ndarray:
        """One request's prefix chunk out of a wave's prefill cache, in the
        pool's (chunk, 2, L*hk, dh) block layout."""
        bt = self.prefix_block_tokens
        lo = chunk * bt
        kv = jnp.stack([cache["k"][:, ri, lo:lo + bt],
                        cache["v"][:, ri, lo:lo + bt]])   # (2, L, bt, hk, dh)
        layers, hk = self.cfg.n_layers, self.cfg.n_kv_heads
        return kv.transpose(2, 0, 1, 3, 4).reshape(
            bt, 2, layers * hk, self.cfg.head_dim())

    def _writeback_prefix(self, rep: DecodeReplica, plan: WavePlan,
                          wver: Optional[int]) -> None:
        """Publish the wave's freshly-prefilled prefix blocks into the pool
        (the payload half of the jump-ahead writes already issued), plus
        repairs of freed slots whose tag still matches.  ``wver`` is the
        weight version the wave's prefill ran under; it tags the slots."""
        cache = rep.last_prefill_cache
        if cache is None or "k" not in cache:
            return
        writers = {**plan.repair_writers, **plan.miss_writers}
        bids = list(writers)
        blocks = jnp.stack([self._cache_block_kv(cache, ri, c)
                            for ri, c in writers.values()])
        self.prefix_engine.write_kv(bids, blocks)
        self._pool_wver[bids] = -1 if wver is None else int(wver)

    # -- request loop -------------------------------------------------------

    def _serve_wave(self, rep: DecodeReplica, wave: List[Request],
                    plan: Optional[WavePlan]) -> None:
        # read the weight lease first: the pool may only serve KV computed
        # under the SAME weight version this wave's prefill will use
        params = rep.params()
        wver = rep.reader.cached_version("params")
        skip, prefix_kv = 0, None
        if (plan is not None and plan.skip_tokens
                and self.prefix_engine.has_kv):
            n_ok = 0
            for bid in plan.skip_bids:
                # re-check validity too: a same-wave collision eviction may
                # have freed a slot after the plan's covered walk ran
                if (self._pool_wver[bid] != wver
                        or not self.prefix_engine.kv_ok(bid)):
                    break
                n_ok += 1
            stale = plan.skip_bids[n_ok:]
            if stale:
                # cross-version KV must never mix into one forward pass:
                # free the slots; this wave recomputes those positions
                # (they're beyond its skip), so repair them right away
                self.prefix_engine.invalidate_kv(stale)
                for j, bid in enumerate(stale):
                    plan.repair_writers.setdefault(bid, (0, n_ok + j))
            skip = n_ok * self.prefix_block_tokens
            if 0 < skip < min(len(r.prompt) for r in wave):
                pooled = self.prefix_engine.read_kv(plan.skip_bids[:n_ok])
                prefix_kv = self._pool_to_layer_kv(pooled)
                self.prefix_stats["prefix_prefill_tokens_skipped"] += (
                    skip * len(wave))
                self.prefix_stats["prefix_flops_saved"] += (
                    skip * len(wave) * self._flops_per_token)
            else:
                skip = 0
        rep.serve(wave, prefix_kv=prefix_kv, skip=skip, params=params)
        if (plan is not None and self.prefix_engine.has_kv
                and (plan.miss_writers or plan.repair_writers)):
            self._writeback_prefix(rep, plan, wver)
        rep.last_prefill_cache = None    # only needed until the write-back

    def run(self, requests: List[Request]) -> Tuple[List[Request], Dict]:
        waves: List[List[Request]] = []
        for i, r in enumerate(requests):
            if i % len(self.replicas) == 0:
                waves.append([])
            waves[-1].append(r)
        for i, wave in enumerate(waves):
            rep = self.replicas[i % len(self.replicas)]
            plan = None
            if self.prefix_reuse:
                plan = self._lease_prefix_wave(rep, [r.prompt for r in wave])
                self._maybe_rebase()
            self._serve_wave(rep, wave, plan)
        return requests, self.coherence_report()

    def coherence_report(self) -> Dict[str, Any]:
        s = self.store.stats
        e = self.prefix_engine.stats
        saved = s.renew_data_less * self.param_bytes
        kv_saved = e.data_less * self.prefix_engine.block_bytes
        # local hits never generate a message at all -- ledger them apart
        local_saved = (self.prefix_stats["prefix_local_hits"]
                       * self.prefix_engine.block_bytes)
        return {
            "reads": s.reads, "writes": s.writes,
            "renewals": s.renews + e.renewals,
            "data_less_renewals": s.renew_data_less + e.data_less,
            "payload_transfers": s.payload_transfers + e.payload_transfers,
            "bytes_transferred": s.bytes_transferred + e.payload_bytes,
            "bytes_saved_by_renewals": saved + kv_saved,
            "bytes_saved_by_local_hits": local_saved,
            "wire_flits": s.flits + e.flits,
            "wire_bytes": s.wire_bytes + e.wire_bytes,
            "directory_would_invalidate": s.dir_invalidations,
            "directory_peak_sharers": s.dir_sharer_bits,
            "replica_local_hits": sum(r.reader.local_hits
                                      for r in self.replicas),
            # LeaseEngine prefix-KV path
            **self.prefix_stats,
            "prefix_data_less_renewals": e.data_less,
            "prefix_payload_transfers": e.payload_transfers,
            "prefix_blocks_written": e.writes,
            "prefix_rebases": e.rebases,
            # per-wave batched dispatch + paged-KV-pool ledger
            "prefix_read_dispatches": e.read_ops,
            "prefix_write_dispatches": e.write_ops,
            "prefix_kv_blocks_written": e.kv_blocks_written,
            "prefix_kv_blocks_read": e.kv_blocks_read,
            "prefix_kv_evictions": e.kv_evictions,
        }
