"""Tardis-coherent serving engine: continuous batching over paged pool KV.

Multiple decode replicas serve requests against
  * a shared *weight version* (hot-swapped by a trainer/publisher), and
  * a shared paged KV pool (RadixAttention-style prefix reuse),
both coherent through Tardis leases: replicas hold leases, renew on expiry
(data-less when unchanged -- the common case), and a weight publish never
broadcasts: it jumps ahead of all outstanding leases.  Metadata is O(log N)
per object; there is no sharer list in the system.

Weights go through :class:`repro.core.store.TardisStore`; the KV pool is a
:class:`repro.core.lease_engine.LeaseEngine` whose read/renew/write-jump-
ahead transitions run in the ``tardis_lease`` Pallas kernels.

**Paged serving (dense/vlm/moe).**  Every KV byte a decode step touches
lives in LeaseEngine pool pages; there is no dense per-request cache on
this path.  The pool is split into a content-addressed region
(prompt-prefix chunks chain-hashed to block ids, shared across requests
under leases) and an allocator region (private decode pages, free-listed).
A request's page table names its covered shared-prefix blocks followed by
its own pages; prefill scatters the prompt's suffix KV into the own pages
(``LeaseEngine.append_kv``) and each decode step appends the new token's
KV through the ``tardis_lease`` scatter kernel inside the jitted step
(:func:`repro.models.decode_step_paged`) -- no host round trip.  Decode
attention streams K/V straight out of the pool (the gather path is
bit-exact with the dense-cache decode; the Pallas paged flash-decode
kernel is routed on TPU).  The moe family's DUAL cache stacks (leading
dense layers + moe layers) page through one engine with **named KV
pools**: each stack's segment interleaves into the same token row at a
static pool offset (:func:`repro.models.pool_layout` is the layout's
single source of truth, asserted against the engine here), so one block id
leases both stacks' payloads, one scatter per step appends both, and
admission accounting (pages, occupancy, validity) counts both by
construction.

The request loop is a **continuous-batching scheduler**: requests join a
replica's running batch as soon as a batch slot and enough free pool pages
exist (admission is bounded by ``free_page_count``), finish independently,
and release their pages immediately.  Covered prefix blocks stay pinned
and leased for the whole decode -- decode-time re-reads of shared blocks
are the renewal-dominated pattern Tardis 2.0 lease tuning targets, and
expired leases renew (data-less) in one batched ``read_many`` per tick.  A
collision eviction hitting a pinned block relocates its payload into a
freshly allocated page and remaps the active page tables (zero messages;
readers of the old content keep reading their bits), so content
re-addressing can never corrupt an in-flight decode.

The lease protocol is batched per admission group: one logical tick, one
``read_many`` dispatch for every renewal and at most one jump-ahead write
over the union of the misses.  Only the ssm/hybrid families (whose
recurrent states are not block-addressable) fall back to the fixed-wave
dense-cache loop.

The engine is single-process (replicas are cooperative objects) but every
coherence message is accounted in flits, so benchmarks can compare against
a directory-style invalidation broadcast on the same request stream.
"""
from __future__ import annotations

import dataclasses
import warnings
import zlib
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.lease_engine import LeaseEngine
from ..core.policy import CoherencePolicy, resolve_policy
from ..core.shard_directory import ShardedLeaseDirectory
from ..core.store import Replica, TardisStore
from ..models import (PAGED_FAMILIES, decode_step, decode_step_paged,
                      pool_layout, prefill, prefill_suffix)

# families whose prefill KV cache is position-addressable block-wise, i.e.
# can be carried through the paged KV pool (an SSM state cannot); the moe
# family pages both of its cache stacks through named pools.
KV_POOL_FAMILIES = PAGED_FAMILIES


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray               # (S,) int32
    max_new: int = 8
    done: bool = False
    output: Optional[np.ndarray] = None


@dataclasses.dataclass
class WavePlan:
    """Outcome of the per-wave batched lease protocol for one wave.

    ``groups`` holds each request's prefix block ids; ``covered[i]`` is how
    many leading blocks of request i are pool-backed (tag match + payload
    valid *before* this wave), already clamped against the request's own
    prompt length so at least one token is always left for prefill -- the
    clamp lives HERE, in the plan, so the plan and the serve side can never
    disagree about it (the old code recomputed the wave minimum at serve
    time).  ``miss_writers`` maps each newly-written block id to the
    ``(request_index, chunk_index)`` whose prefill output backs it, and
    ``repair_writers`` the tag-hit blocks whose pool slot is invalid (e.g.
    freed by a weight publish) and gets repopulated by this wave's prefill.
    """
    groups: List[List[int]]
    covered: List[int]
    miss_writers: Dict[int, Tuple[int, int]]
    repair_writers: Dict[int, Tuple[int, int]]


@dataclasses.dataclass
class Stream:
    """One in-flight request on the paged path: its page table and nothing
    else -- the KV itself lives in the engine's pool pages."""
    req: Request
    page_row: np.ndarray             # (max_pages,) int32 block ids
    own_pages: List[int]             # allocator-region pages (freed at end)
    shared_bids: List[int]           # pinned content blocks (leased)
    reloc_pages: List[int]           # eviction-relocated private copies
    length: int                      # tokens currently in pages
    emitted: List[int]

    @property
    def finished(self) -> bool:
        return len(self.emitted) >= self.req.max_new


class DecodeReplica:
    """One model replica: leased weights + local continuous batch.

    Besides the weight lease (via ``self.reader``) the replica keeps its own
    program timestamp ``kv_pts`` and cached ``(wts, rts)`` leases for prefix-
    KV blocks; the cluster's LeaseEngine is their timestamp manager.
    """

    def __init__(self, cfg, store: TardisStore, name: str,
                 max_batch: int = 4, cache_len: int = 256,
                 selfinc_period: int = 8):
        self.cfg = cfg
        self.name = name
        self.reader = Replica(store, name, selfinc_period=selfinc_period)
        self.max_batch = max_batch
        self.cache_len = cache_len
        self.kv_pts = 0
        # bid -> (wts, rts, content_tag): the tag names WHICH prefix the
        # cached copy holds; a lease alone says a read is SC-legal, the tag
        # says it is the content this request wants (collision evictions
        # re-tag blocks without invalidating anybody).
        self.kv_leases: Dict[int, Tuple[int, int, int]] = {}
        self._decode = jax.jit(
            lambda p, c, t, i: decode_step(cfg, p, c, t, i))
        self._prefill = jax.jit(
            lambda p, b: prefill(cfg, p, b, cache_len))

    def params(self):
        """Weight access through the lease (renewal-on-expiry)."""
        return self.reader.read("params")

    def rebase_kv(self, shift: int) -> None:
        """Apply an engine rebase: shift pts/leases; drop leases whose rts
        would fall below the new base (cannot be raised unilaterally)."""
        if not shift:
            return
        self.kv_pts = max(0, self.kv_pts - shift)
        self.kv_leases = {
            bid: (max(0, w - shift), r - shift, t)
            for bid, (w, r, t) in self.kv_leases.items() if r >= shift}

    def serve(self, reqs: List[Request], params=None) -> List[Request]:
        """Dense-cache fallback: greedy-decode a fixed wave of requests
        (ssm/hybrid families only -- their recurrent states are not
        block-addressable; every attention-cache family, moe included,
        serves through pool pages)."""
        if not reqs:
            return reqs
        if params is None:
            params = self.params()
        s = max(len(r.prompt) for r in reqs)
        toks = np.zeros((len(reqs), s), np.int32)
        for i, r in enumerate(reqs):
            toks[i, :len(r.prompt)] = r.prompt
        cache, logits = self._prefill(params, {"tokens": jnp.asarray(toks)})
        outs = [[] for _ in reqs]
        cur = jnp.int32(s)
        next_tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        max_new = max(r.max_new for r in reqs)
        for step in range(max_new):
            for i in range(len(reqs)):
                outs[i].append(int(next_tok[i, 0]))
            params = self.params()           # lease check per decode wave
            cache, logits = self._decode(params, cache, next_tok, cur)
            next_tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
            cur = cur + 1
        for r, o in zip(reqs, outs):
            r.output = np.asarray(o[:r.max_new], np.int32)
            r.done = True
        return reqs


def _prefix_cache(stacks, pkv, batch, cache_len: int, skip: int):
    """Leased prefix KV -> a request's prefill cache with the prefix
    pre-filled, one entry pair per cache stack: ``pkv`` maps a stack's
    pool name to its ((L_s, skip, hk, dh) k, v)."""
    cache = {}
    for s in stacks:
        kp, vp = pkv[s.pool]
        shape = (kp.shape[0], batch, cache_len) + kp.shape[2:]
        kc = jnp.zeros(shape, jnp.bfloat16)
        vc = jnp.zeros(shape, jnp.bfloat16)
        cache[s.cache_keys[0]] = kc.at[:, :, :skip].set(
            kp[:, None].astype(jnp.bfloat16))
        cache[s.cache_keys[1]] = vc.at[:, :, :skip].set(
            vp[:, None].astype(jnp.bfloat16))
    return cache


class CoherenceReport(dict):
    """The coherence ledger: the legacy flat counter dict plus typed group
    accessors, so callers address a whole namespace (``report.xhost``,
    ``report.role``, ``report.router``, ``report.lease``) instead of
    string-matching individual key names.  Every flat key is preserved --
    the accessors are read-only views over the same entries.
    """

    # the lease-protocol namespace has historical un-prefixed names; the
    # accessor gathers them so new call sites never hard-code the list
    _LEASE_KEYS = (
        "kv_lease", "consistency", "renewals", "data_less_renewals",
        "prefix_renewals", "prefix_local_hits",
        "prefix_data_less_renewals", "decode_renewals",
        "decode_renewals_skipped", "decode_local_hits", "pred_grows",
        "pred_shrinks", "pred_lease_lo", "pred_lease_hi")

    def _ns(self, prefix: str) -> Dict[str, Any]:
        return {k[len(prefix):]: self[k]
                for k in self if k.startswith(prefix)}

    @property
    def lease(self) -> Dict[str, Any]:
        """Lease-protocol group: renewals, local hits, predictor state."""
        return {k: self[k] for k in self._LEASE_KEYS if k in self}

    @property
    def xhost(self) -> Dict[str, Any]:
        """Cross-host group: the ``xhost_*`` directory/migration ledger."""
        return self._ns("xhost_")

    @property
    def role(self) -> Dict[str, Any]:
        """Per-role group: the ``role_*`` disaggregation ledger."""
        return self._ns("role_")

    @property
    def router(self) -> Dict[str, Any]:
        """Admission-router group: the ``router_*`` ledger."""
        return self._ns("router_")


class ServingCluster:
    """N replicas + weight publisher + shared paged-KV LeaseEngine pool."""

    def __init__(self, cfg, init_params_fn: Callable[[], Any],
                 n_replicas: int = 2, lease: int = 10,
                 n_prefix_blocks: int = 4096, prefix_block_tokens: int = 16,
                 kv_lease: Optional[int] = None, prefix_reuse: bool = True,
                 ts_bits: Optional[int] = None,
                 prefix_backend: str = "pallas",
                 n_decode_pages: int = 512, max_pages: int = 32,
                 sanitize: Optional[bool] = None,
                 policy: Optional[CoherencePolicy] = None,
                 **replica_kw):
        self.cfg = cfg
        if kv_lease is not None or ts_bits is not None:
            if policy is not None:
                raise ValueError(
                    "pass either policy= or the legacy kv_lease=/ts_bits= "
                    "kwargs, not both")
            warnings.warn(
                "kv_lease=/ts_bits= are deprecated; pass policy="
                "CoherencePolicy(lease=..., ts_bits=...) instead",
                DeprecationWarning, stacklevel=2)
        self.policy = resolve_policy(policy, lease=kv_lease, ts_bits=ts_bits)
        self.store = TardisStore(lease=lease)
        p0 = init_params_fn()
        nbytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(p0))
        self.publisher = Replica(self.store, "trainer")
        self.publisher.write("params", p0, nbytes=nbytes)
        self.param_bytes = nbytes
        # forward-pass cost of one prompt token (2 flops per param-weight);
        # what a prefix-pool hit saves prefill per skipped token.
        self._flops_per_token = 2 * int(
            sum(x.size for x in jax.tree.leaves(p0)))
        self.replicas = [
            DecodeReplica(cfg, self.store, f"replica{i}", **replica_kw)
            for i in range(n_replicas)]
        # the paged pool: a content-addressed region (chain-hashed prompt
        # prefixes, shared under leases) + an allocator region (private
        # decode pages), one engine, one payload pool.
        self.prefix_block_tokens = int(prefix_block_tokens)
        self.prefix_reuse = bool(prefix_reuse)
        self.n_prefix_blocks = int(n_prefix_blocks)
        self.n_decode_pages = int(n_decode_pages)
        self.max_pages = int(max_pages)
        # block_bytes covers EVERY cache stack of a block (2 * n_layers
        # counts the moe family's dense + moe stacks together)
        kv_bytes = (2 * cfg.n_layers * cfg.n_kv_heads * cfg.head_dim()
                    * 4 * self.prefix_block_tokens)
        kv_pools = None
        self._stacks = []
        if self.prefix_reuse and cfg.family in KV_POOL_FAMILIES:
            # one NAMED pool per cache stack (moe: dense + moe), all
            # leasing through the same block table and free list
            self._stacks = pool_layout(cfg)
            hk, dh = cfg.n_kv_heads, cfg.head_dim()
            kv_pools = {s.pool: (self.prefix_block_tokens, 2,
                                 s.n_layers * hk, dh)
                        for s in self._stacks}
        n_blocks = self.n_prefix_blocks + (self.n_decode_pages
                                           if kv_pools else 0)
        self.prefix_engine = LeaseEngine(
            n_blocks, policy=self.policy, block_bytes=kv_bytes,
            backend=prefix_backend,
            kv_pools=kv_pools, alloc_reserve=self.n_prefix_blocks,
            sanitize=sanitize)
        if kv_pools:
            for s in self._stacks:
                # the models' static k/v offsets (pool_layout) and the
                # engine's interleaved row must agree byte for byte
                assert self.prefix_engine.pool_offset(s.pool) == s.offset, \
                    (s, self.prefix_engine.pool_offset(s.pool))
        self._tags = np.full(n_blocks, -1, np.int64)       # content hashes
        # weight version each pool slot's KV was computed under: a request
        # may only reuse KV matching the weights it will serve with
        # (same-version staleness is SC-legal; cross-version mixing is not)
        self._pool_wver = np.full(n_blocks, -1, np.int64)
        # paged-decode bookkeeping: pin counts on shared content blocks
        # referenced by in-flight page tables, refcounts on relocated
        # private copies, and the live scheduler's active streams.
        self._pins: Dict[int, int] = {}
        self._reloc_refs: Dict[int, int] = {}
        self._admit_reserved = 0          # pages promised to joiners in
        #                                   flight (relocation may not eat)
        self._active: List[List[Stream]] = [[] for _ in self.replicas]
        self.trace: Optional[List[Dict]] = None   # test/debug hook
        self.prefix_stats = {
            "prefix_block_hits": 0, "prefix_local_hits": 0,
            "prefix_renewals": 0, "prefix_block_misses": 0,
            "prefix_evictions": 0, "prefix_evictions_deferred": 0,
            "prefix_tokens_reused": 0,
            "prefix_prefill_tokens_skipped": 0, "prefix_flops_saved": 0,
            "decode_renewals": 0, "decode_local_hits": 0,
            "decode_renewals_skipped": 0, "decode_block_reads": 0,
            "pinned_relocations": 0, "paged_mid_batch_admissions": 0,
            "paged_admission_deferrals": 0, "pool_page_peak": 0,
            "xhost_pages_fetched": 0, "xhost_pages_published": 0,
            # per-role ledger (disaggregated serving): a COLD-prefix
            # prefill is an admission that ran the full prefill although
            # the prompt had at least one coverable leading block -- a
            # decode pod must never do one (the router forwards that work
            # to a prefill pod); suffix admissions, published pages,
            # prefill-pod jobs, decode ticks, and renewal messages break
            # the same traffic down by what each role actually did.
            "role_cold_prefills": 0, "role_suffix_admissions": 0,
            "role_pages_published": 0, "role_prefill_jobs": 0,
            "role_renewal_msgs": 0, "decode_ticks": 0,
        }
        # disaggregated serving role: "mixed" (default, serves everything),
        # "prefill" (only admits cold prefixes, publishes, never decodes),
        # or "decode" (never prefills a cold prefix; cold work is routed
        # to a prefill pod and handed back by publish-then-notify).
        self.role = "mixed"
        # multi-host mode: when a ShardedLeaseDirectory is attached, the
        # directory shards own the prefix region's (wts, rts) tables and
        # home payloads; the local engine keeps only this host's payload
        # cache + decode pages.  Single-host behavior is byte-identical.
        self.directory = None
        self.host_id = 0
        self._migrated: set = set()       # bids installed by page migration
        self.paged = self.prefix_engine.has_kv
        if self.paged:
            interp = self.prefix_engine.interpret
            ch = self.prefix_block_tokens
            self._decode_paged_fn = jax.jit(
                lambda p, pool, pr, ln, tk: decode_step_paged(
                    cfg, p, pool, pr, ln, tk, chunk=ch, interpret=interp),
                donate_argnums=(1,))
            # admission prefills are right-padded to a block multiple with
            # the true last position a traced index, so retraces are
            # bounded by (cache_len, skip) buckets, not request lengths
            self._prefill_fn = jax.jit(
                lambda p, b, cl, li: prefill(cfg, p, b, cl, last_idx=li),
                static_argnums=2)
            stacks = self._stacks
            self._psuffix_fn = jax.jit(
                lambda p, b, pkv, n, cl, li: prefill_suffix(
                    cfg, p, b,
                    _prefix_cache(stacks, pkv, b["tokens"].shape[0], cl, n),
                    n, last_idx=li),
                static_argnums=(3, 4))

    def attach_directory(self, directory, host_id: int) -> None:
        """Join a sharded lease directory as host ``host_id``.

        The directory must cover exactly this cluster's prefix region with
        the same pool layout; from here on every prefix lease transition
        (classification, renewal, miss write, decode renewal) goes through
        :meth:`ShardedLeaseDirectory.wave` -- at most one message per owner
        shard per wave -- instead of the local engine's tables.
        """
        if not self.paged:
            raise ValueError("multi-host serving requires a paged family")
        eng = self.prefix_engine
        if directory.n_blocks != self.n_prefix_blocks:
            raise ValueError(
                f"directory covers {directory.n_blocks} blocks, host has "
                f"{self.n_prefix_blocks} prefix blocks")
        if directory.block_bytes != eng.block_bytes:
            raise ValueError("directory/host block_bytes mismatch")
        self.directory = directory
        self.host_id = int(host_id)

    def publish_weights(self, params) -> int:
        """Hot-swap: no invalidation broadcast; replicas renew on expiry.

        The pool's payloads were computed under the OLD weights, and pool
        validity (unlike a lease) never expires -- so the publish frees
        every pool slot locally (a manager-side bitmap clear, zero messages
        to replicas; tags and lease metadata stay).  Later admissions
        repair the slots from their own prefill (``repair_writers``).
        In-flight decodes keep reading their pages' payload bits -- within
        one request a single weight version keeps serving, which is the
        same-version staleness rule, not mixing.
        """
        self.publisher.write("params", params, nbytes=self.param_bytes)
        if self.prefix_engine.has_kv:
            self.prefix_engine.invalidate_kv(
                np.arange(self.prefix_engine.n_blocks))
        if self.directory is not None:
            msan = self.directory._msan
            if msan is not None:
                for bid in self._migrated:
                    msan.on_invalidate(self.host_id, bid)
            self._migrated.clear()
        return self.publisher.pts

    # -- prefix-KV content addressing ---------------------------------------

    def _prefix_blocks_of(self, prompt: np.ndarray) -> Tuple[List[int],
                                                             List[int]]:
        """Chain-hash whole prompt prefixes into (block_ids, content_tags)."""
        bt = self.prefix_block_tokens
        bids, tags = [], []
        h = 0
        for c in range(len(prompt) // bt):
            h = zlib.crc32(np.ascontiguousarray(
                prompt[c * bt:(c + 1) * bt]).tobytes(), h)
            bids.append(h % self.n_prefix_blocks)
            tags.append(h)
        return bids, tags

    def _evict_block(self, bid: int) -> bool:
        """Collision eviction of a content block about to be re-tagged.

        If in-flight page tables reference it (pinned), its payload first
        relocates to a freshly allocated private page and the active
        streams remap -- zero messages, the old content keeps its bits.
        Returns False when the block is pinned but no free page exists
        (the new content stays uncacheable this wave)."""
        if self.prefix_engine.has_kv and self._pins.get(bid, 0):
            if (self.prefix_engine.free_page_count()
                    - self._admit_reserved) < 1:
                return False
            new = int(self.prefix_engine.alloc_pages(1)[0])
            self.prefix_engine.write_kv([new],
                                        self.prefix_engine.read_kv([bid]))
            self._pool_wver[new] = self._pool_wver[bid]
            self._reloc_refs[new] = self._pins.pop(bid)
            for act in self._active:
                for s in act:
                    if bid in s.shared_bids:
                        s.shared_bids.remove(bid)
                        s.reloc_pages.append(new)
                        s.page_row = np.where(s.page_row == bid, new,
                                              s.page_row).astype(np.int32)
            self.prefix_stats["pinned_relocations"] += 1
        if self.prefix_engine.has_kv:
            self.prefix_engine.invalidate_kv([bid])
        if self.directory is not None and bid in self._migrated:
            self._migrated.discard(bid)
            if self.directory._msan is not None:
                self.directory._msan.on_invalidate(self.host_id, bid)
        return True

    def _lease_prefix(self, rep: DecodeReplica, prompt: np.ndarray) -> None:
        """Single-request compatibility wrapper: a wave of one."""
        self._lease_prefix_wave(rep, [prompt])

    def _lease_prefix_wave(self, rep: DecodeReplica,
                           prompts: List[np.ndarray]) -> WavePlan:
        """Per-wave batched prefix leasing for one replica.

        The whole wave charges ONE logical tick (the paper's self-inc
        bounds staleness per protocol interaction, and the wave is one
        interaction), classifies every request's blocks against the same
        table snapshot, then resolves all renewals in a single
        ``read_many`` kernel dispatch and all misses in at most one
        jump-ahead write over their union -- N requests sharing a system
        prompt collapse to 1 read + <=1 write instead of N full-table
        dispatch pairs.  No invalidation reaches other replicas.
        """
        if self.directory is not None:
            return self._lease_prefix_wave_dir(rep, prompts)
        rep.kv_pts += 1
        ps = self.prefix_stats
        bt = self.prefix_block_tokens
        groups, tags_by_req = [], []
        for prompt in prompts:
            bids, tags = self._prefix_blocks_of(prompt)
            groups.append(bids)
            tags_by_req.append(tags)
        # pool-backed leading blocks per request, against the PRE-wave pool
        # (blocks written later this wave aren't materialized yet); clamped
        # so at least one prompt token remains for prefill to compute.
        covered = []
        for prompt, bids, tags in zip(prompts, groups, tags_by_req):
            c = 0
            for bid, tag in zip(bids, tags):
                if self._tags[bid] != tag or not self.prefix_engine.kv_ok(bid):
                    break
                c += 1
            covered.append(min(c, (len(prompt) - 1) // bt))

        local_wts: List[int] = []
        renew_groups: List[List[int]] = [[] for _ in prompts]
        renew_req: Dict[int, int] = {}
        miss_writers: Dict[int, Tuple[int, int]] = {}
        repair_writers: Dict[int, Tuple[int, int]] = {}
        for ri, (bids, tags) in enumerate(zip(groups, tags_by_req)):
            for c, (bid, tag) in enumerate(zip(bids, tags)):
                if self._tags[bid] == tag:
                    ps["prefix_block_hits"] += 1
                    ps["prefix_tokens_reused"] += bt
                    if (self.prefix_engine.has_kv
                            and not self.prefix_engine.kv_ok(bid)
                            and bid not in repair_writers):
                        # tag hit but the payload slot was freed (weight
                        # publish / eviction): repopulate from this wave
                        repair_writers[bid] = (ri, c)
                    ent = rep.kv_leases.get(bid)
                    cached_ok = ent is not None and ent[2] == tag
                    if cached_ok and rep.kv_pts <= ent[1]:
                        ps["prefix_local_hits"] += 1   # unexpired lease
                        local_wts.append(ent[0])
                    else:
                        renew_groups[ri].append(bid)
                        if bid not in renew_req:
                            # a copy of DIFFERENT content can't renew
                            renew_req[bid] = ent[0] if cached_ok else -1
                else:
                    if self._tags[bid] != -1:
                        if not self._evict_block(bid):
                            # pinned + no free page: leave the old tag in
                            # place; this chunk stays uncacheable this wave
                            ps["prefix_evictions_deferred"] += 1
                            continue
                        ps["prefix_evictions"] += 1    # collision: re-tag
                    ps["prefix_block_misses"] += 1
                    self._tags[bid] = tag
                    miss_writers[bid] = (ri, c)        # last writer wins
        if local_wts:                                  # Table II local hits
            rep.kv_pts = max(rep.kv_pts, max(local_wts))
        active = [g for g in renew_groups if g]
        if active:                                     # ONE kernel dispatch
            res = self.prefix_engine.read_many(active, rep.kv_pts,
                                               req_wts=renew_req)
            rep.kv_pts = int(res.new_pts.max())
            ps["prefix_renewals"] += sum(
                1 for b in res.union_idx if renew_req[int(b)] >= 0)
            for i, bid in enumerate(res.union_idx):
                bid = int(bid)
                rep.kv_leases[bid] = (int(res.wts[i]), int(res.rts[i]),
                                      int(self._tags[bid]))
        if miss_writers:                               # one wave jump-ahead
            ts = self.prefix_engine.write_many([list(miss_writers)],
                                               rep.kv_pts)
            rep.kv_pts = ts
            for bid in miss_writers:
                rep.kv_leases[bid] = (ts, ts, int(self._tags[bid]))
        # a repair superseded by a same-wave eviction defers to the miss
        repair_writers = {b: rc for b, rc in repair_writers.items()
                          if b not in miss_writers}
        return WavePlan(groups, covered, miss_writers, repair_writers)

    def _lease_prefix_wave_dir(self, rep: DecodeReplica,
                               prompts: List[np.ndarray]) -> WavePlan:
        """Directory-mode prefix leasing: same wave protocol, but the
        (wts, rts) truth for the prefix region lives in the sharded
        directory, so classification runs against the directory's content
        tags and ALL lease traffic -- renewals, miss re-tags, and payload
        fetches of remotely-prefilled blocks -- resolves in ONE
        :meth:`ShardedLeaseDirectory.wave` call (at most one message per
        owner shard).  A block whose payload another host published serves
        this wave by timestamp-ordered page migration instead of being
        recomputed: it counts as covered, and `_install_fetched` lands it
        in the local pool before the admission prefill reads it.
        """
        dirx = self.directory
        eng = self.prefix_engine
        rep.kv_pts += 1
        ps = self.prefix_stats
        bt = self.prefix_block_tokens
        groups, tags_by_req = [], []
        for prompt in prompts:
            bids, tags = self._prefix_blocks_of(prompt)
            groups.append(bids)
            tags_by_req.append(tags)

        local_wts: List[int] = []
        renew_groups: List[List[int]] = [[] for _ in prompts]
        renew_req: Dict[int, int] = {}
        write_bids: List[int] = []
        write_tags: List[int] = []
        fetch_bids: List[int] = []
        miss_writers: Dict[int, Tuple[int, int]] = {}
        repair_writers: Dict[int, Tuple[int, int]] = {}
        pending_tags: Dict[int, int] = {}    # re-tags queued for this wave
        covered: List[int] = []
        for ri, (prompt, bids, tags) in enumerate(
                zip(prompts, groups, tags_by_req)):
            run_ok = True                    # still in the leading run
            c_cov = 0
            for c, (bid, tag) in enumerate(zip(bids, tags)):
                eff_tag = pending_tags.get(bid, int(dirx.tags[bid]))
                if eff_tag == tag:
                    ps["prefix_block_hits"] += 1
                    ps["prefix_tokens_reused"] += bt
                    will_cover = (self._tags[bid] == tag
                                  and eng.kv_ok(bid))
                    if not will_cover:
                        if (run_ok and bid not in pending_tags
                                and dirx.home_ok(bid)):
                            # another host prefilled it: migrate the page
                            if bid not in fetch_bids:
                                fetch_bids.append(bid)
                            will_cover = True
                        elif (bid not in repair_writers
                              and bid not in miss_writers
                              and bid not in fetch_bids):
                            repair_writers[bid] = (ri, c)
                    ent = rep.kv_leases.get(bid)
                    cached_ok = ent is not None and ent[2] == tag
                    if cached_ok and rep.kv_pts <= ent[1]:
                        ps["prefix_local_hits"] += 1
                        local_wts.append(ent[0])
                    else:
                        renew_groups[ri].append(bid)
                        if bid not in renew_req:
                            renew_req[bid] = ent[0] if cached_ok else -1
                    if run_ok and will_cover:
                        c_cov += 1
                    else:
                        run_ok = False
                else:
                    if eff_tag != -1 or self._tags[bid] != -1:
                        if not self._evict_block(bid):
                            ps["prefix_evictions_deferred"] += 1
                            run_ok = False
                            continue
                        ps["prefix_evictions"] += 1
                    ps["prefix_block_misses"] += 1
                    self._tags[bid] = tag
                    pending_tags[bid] = tag
                    write_bids.append(bid)
                    write_tags.append(tag)
                    miss_writers[bid] = (ri, c)
                    run_ok = False
            covered.append(min(c_cov, (len(prompt) - 1) // bt))
        if local_wts:                                  # Table II local hits
            rep.kv_pts = max(rep.kv_pts, max(local_wts))
        active = [g for g in renew_groups if g]
        if active or write_bids or fetch_bids or \
                self.host_id in dirx._pending:
            res = dirx.wave(self.host_id, rep.kv_pts, read_groups=active,
                            req_wts=renew_req or None,
                            write_bids=write_bids, write_tags=write_tags,
                            fetch_bids=fetch_bids)
            rep.kv_pts = int(res.new_pts)
            ps["prefix_renewals"] += sum(
                1 for w in renew_req.values() if w >= 0)
            for bid, (w, r) in res.leases.items():
                rep.kv_leases[bid] = (w, r, int(dirx.tags[bid]))
            for bid in miss_writers:
                ts = res.write_ts.get(bid)
                if ts is not None:
                    rep.kv_leases[bid] = (ts, ts, int(self._tags[bid]))
            self._install_fetched(res, rep)
        repair_writers = {b: rc for b, rc in repair_writers.items()
                          if b not in miss_writers}
        return WavePlan(groups, covered, miss_writers, repair_writers)

    def _install_fetched(self, res, rep: DecodeReplica) -> None:
        """Land migrated pages in the local pool under exactly the carried
        ``(wts, rts, version)``: the lease the wave's read extended becomes
        the local cached lease, the content tag carries over, and the slot
        joins the host's payload cache (evicting-relocating any pinned
        different-content local copy first)."""
        eng = self.prefix_engine
        dirx = self.directory
        wver = rep.reader.cached_version("params")
        for bid, page in res.fetched.items():
            if self._tags[bid] not in (-1, page.tag):
                if not self._evict_block(bid):
                    continue     # pinned + no free page: skip the install
            eng.write_kv([bid], dict(page.blocks))
            self._tags[bid] = page.tag
            self._pool_wver[bid] = -1 if wver is None else int(wver)
            rep.kv_leases[bid] = (page.wts, page.rts, page.tag)
            if self.policy.predictor:
                # Tardis 2.0: the owner's learned lease travels with the page
                eng.set_pred_lease([bid], page.pred_lease)
            self._migrated.add(bid)
            self.prefix_stats["xhost_pages_fetched"] += 1
            if dirx._msan is not None:
                dirx._msan.mark_installed(self.host_id, bid, page.tag)

    def _maybe_rebase(self) -> None:
        if self.directory is not None:
            return        # the multi-host coordinator drives rebases
        shift = self.prefix_engine.maybe_rebase()
        if shift:
            for rep in self.replicas:
                rep.rebase_kv(shift)

    # -- paged-KV pool <-> per-stack cache layout ---------------------------

    def _read_kv_stacks(self, bids) -> Dict[str, Any]:
        """Engine pool payloads for leased block ids as a per-stack dict
        (a single-pool engine returns a bare array; normalize it)."""
        out = self.prefix_engine.read_kv(bids)
        if not isinstance(out, dict):
            out = {self._stacks[0].pool: out}
        return out

    def _pool_to_stack_kv(self, pooled: Dict[str, Any]) -> Dict[str, Tuple]:
        """{pool: (nb, chunk, 2, L_s*hk, dh)} blocks -> {pool: (k, v)} with
        per-layer (L_s, P, hk, dh), P = nb * chunk contiguous prefix
        tokens -- one entry per cache stack."""
        bt = self.prefix_block_tokens
        hk, dh = self.cfg.n_kv_heads, self.cfg.head_dim()
        out = {}
        for s in self._stacks:
            nb = pooled[s.pool].shape[0]
            kv = jnp.asarray(pooled[s.pool]).reshape(
                nb, bt, 2, s.n_layers, hk, dh)
            kv = kv.transpose(2, 3, 0, 1, 4, 5).reshape(
                2, s.n_layers, nb * bt, hk, dh)
            out[s.pool] = (kv[0], kv[1])
        return out

    def _cache_block_kv(self, cache, ri: int, chunk: int) -> Dict[str, Any]:
        """One request's prefix chunk out of a prefill cache, per stack in
        the pool's (chunk, 2, L_s*hk, dh) block layout."""
        bt = self.prefix_block_tokens
        lo = chunk * bt
        hk, dh = self.cfg.n_kv_heads, self.cfg.head_dim()
        out = {}
        for s in self._stacks:
            kv = jnp.stack([cache[s.cache_keys[0]][:, ri, lo:lo + bt],
                            cache[s.cache_keys[1]][:, ri, lo:lo + bt]])
            out[s.pool] = kv.transpose(2, 0, 1, 3, 4).reshape(
                bt, 2, s.n_layers * hk, dh)      # (bt, 2, L_s*hk, dh)
        return out

    def _cache_token_rows(self, cache, lo: int, hi: int) -> np.ndarray:
        """Positions [lo, hi) of a B=1 prefill cache as (hi-lo,
        kv_token_row) FULL pool token rows: every stack's segment packed at
        its pool offset (the stack's layers' K then V), zeros in the
        inter-segment lane padding -- one append covers both cache
        stacks."""
        m = hi - lo
        rows = np.zeros((m, self.prefix_engine.kv_token_row), np.float32)
        for s in self._stacks:
            k = np.asarray(cache[s.cache_keys[0]][:, 0, lo:hi])
            v = np.asarray(cache[s.cache_keys[1]][:, 0, lo:hi])
            kr = k.transpose(1, 0, 2, 3).reshape(m, -1)
            vr = v.transpose(1, 0, 2, 3).reshape(m, -1)
            rows[:, s.offset:s.offset + s.token_elems] = \
                np.concatenate([kr, vr], axis=1)
        return rows

    # -- continuous-batching paged scheduler --------------------------------

    def _pages_needed(self, req: Request, covered: int = 0) -> int:
        bt = self.prefix_block_tokens
        total = -(-(len(req.prompt) + req.max_new) // bt)
        return total - covered

    def _admit(self, r: int, rep: DecodeReplica, queue: deque,
               act: List[Stream], tick: int) -> None:
        """Admit queued requests into the replica's running batch while a
        batch slot and enough free pool pages exist (worst case: no prefix
        coverage).  One lease interaction covers the whole joiner group."""
        eng = self.prefix_engine
        joiners: List[Request] = []
        budget = eng.free_page_count()
        while queue and len(act) + len(joiners) < rep.max_batch:
            req = queue[0]
            need = self._pages_needed(req)
            if need > self.max_pages:
                raise ValueError(
                    f"request {req.rid} needs {need} pages > max_pages="
                    f"{self.max_pages}")
            if need > budget:
                if not act and not joiners and need > self.n_decode_pages:
                    raise RuntimeError(
                        f"request {req.rid} needs {need} pages; pool has "
                        f"{self.n_decode_pages}")
                self.prefix_stats["paged_admission_deferrals"] += 1
                break                       # head-of-line: wait for pages
            budget -= need
            joiners.append(queue.popleft())
        if not joiners:
            return
        if act:
            self.prefix_stats["paged_mid_batch_admissions"] += len(joiners)
        # the joiners' pages are promised: a relocation triggered by this
        # very plan's evictions may not starve their allocation
        self._admit_reserved = sum(self._pages_needed(j) for j in joiners)
        # weight lease first: reuse only KV computed under the SAME weight
        # version this admission's prefill will use (and, in directory
        # mode, the version a migrated page installs under)
        params = rep.params()
        wver = rep.reader.cached_version("params")
        plan = self._lease_prefix_wave(rep, [j.prompt for j in joiners])
        mat_cache: Dict[Tuple[int, ...], Tuple] = {}
        for ji, req in enumerate(joiners):
            self._admit_reserved -= self._pages_needed(req)
            s = self._admit_one(rep, req, plan, ji, params, wver, mat_cache,
                                tick)
            if s is not None:
                act.append(s)
        self._admit_reserved = 0

    def _admit_one(self, rep: DecodeReplica, req: Request, plan: WavePlan,
                   ji: int, params, wver, mat_cache: Dict,
                   tick: int) -> Optional[Stream]:
        eng = self.prefix_engine
        ps = self.prefix_stats
        bt = self.prefix_block_tokens
        bids = plan.groups[ji]
        plen = len(req.prompt)
        # re-check coverage against wver and current validity: a same-wave
        # collision eviction or a cross-version slot truncates the reuse
        n_ok = 0
        for bid in bids[:plan.covered[ji]]:
            if self._pool_wver[bid] != wver or not eng.kv_ok(bid):
                break
            n_ok += 1
        stale = [b for b in bids[n_ok:plan.covered[ji]]
                 if self._pool_wver[b] != wver and eng.kv_ok(b)]
        if stale:
            # cross-version KV must never mix into one forward pass: free
            # the slots; this admission recomputes those positions, so
            # repair them right away
            eng.invalidate_kv(stale)
            for b in stale:
                plan.repair_writers.setdefault(
                    b, (ji, bids.index(b)))
        covered, skip = n_ok, n_ok * bt
        if bids:
            if skip:
                ps["role_suffix_admissions"] += 1
            else:
                # full prefill of a chain-hashable prefix: the cold-prefix
                # work the router keeps off decode pods
                ps["role_cold_prefills"] += 1
        cache_len = max(bt, -(-plen // bt) * bt)
        # suffix right-padded to the block bucket (cache_len - skip); the
        # real last position rides in as a traced index, so one trace
        # serves every suffix length in the bucket
        suffix = req.prompt[skip:]
        toks = jnp.asarray(np.pad(suffix,
                                  (0, cache_len - skip - len(suffix)))[None])
        last = jnp.int32(len(suffix) - 1)
        if skip:
            key = tuple(bids[:covered])
            if self.directory is not None and self.directory._msan is not None:
                for bid in key:
                    if bid in self._migrated:   # serving a migrated page:
                        self.directory._msan.on_use(     # tag must still
                            self.host_id, bid,           # be current
                            int(self.directory.tags[bid]))
            if key not in mat_cache:
                mat_cache[key] = self._pool_to_stack_kv(
                    self._read_kv_stacks(list(key)))
            cache, logits = self._psuffix_fn(params, {"tokens": toks},
                                             mat_cache[key], skip,
                                             cache_len, last)
            ps["prefix_prefill_tokens_skipped"] += skip
            ps["prefix_flops_saved"] += skip * self._flops_per_token
        else:
            cache, logits = self._prefill_fn(params, {"tokens": toks},
                                             cache_len, last)
        # payload write-back: the blocks this request owns per the plan
        # (every cache stack's payload published in one write_kv)
        wb = [(bid, c) for bid, (ri, c) in plan.miss_writers.items()
              if ri == ji]
        wb += [(bid, c) for bid, (ri, c) in plan.repair_writers.items()
               if ri == ji and bid not in plan.miss_writers]
        if wb:
            per_block = [self._cache_block_kv(cache, 0, c) for _, c in wb]
            blocks = {s.pool: jnp.stack([d[s.pool] for d in per_block])
                      for s in self._stacks}
            eng.write_kv([bid for bid, _ in wb], blocks)
            self._pool_wver[[bid for bid, _ in wb]] = \
                -1 if wver is None else int(wver)
            if self.directory is not None:
                # write-behind home publish: the payload rides the NEXT
                # wave's request message to the owner shard (no extra
                # message); a stale publish (re-tagged first) is dropped
                # owner-side by version
                for i_wb, (bid, _c) in enumerate(wb):
                    if self.directory.tags[bid] != self._tags[bid]:
                        continue
                    self.directory.defer_publish(
                        self.host_id, bid,
                        {p: np.asarray(a[i_wb:i_wb + 1])
                         for p, a in blocks.items()})
                    ps["xhost_pages_published"] += 1
                    ps["role_pages_published"] += 1
        # page table: covered shared blocks (pinned + leased for the whole
        # decode) then privately allocated pages for suffix + decode KV
        total_pages = -(-(plen + req.max_new) // bt)
        own = [int(b) for b in eng.alloc_pages(total_pages - covered)]
        page_row = np.zeros(self.max_pages, np.int32)
        page_row[:covered] = bids[:covered]
        page_row[covered:total_pages] = own
        for bid in bids[:covered]:
            self._pins[bid] = self._pins.get(bid, 0) + 1
        if own:
            self._pool_wver[own] = -1 if wver is None else int(wver)
        # the prompt's suffix KV lands in the own pages, token-granular
        rows = self._cache_token_rows(cache, skip, plen)
        pos = np.arange(skip, plen)
        flat_idx = (page_row[pos // bt].astype(np.int64) * bt + pos % bt)
        eng.append_kv(flat_idx, rows)
        t0 = int(np.argmax(np.asarray(logits[0, -1])))
        stream = Stream(req=req, page_row=page_row, own_pages=own,
                        shared_bids=list(bids[:covered]), reloc_pages=[],
                        length=plen, emitted=[t0])
        in_use = self.n_decode_pages - eng.free_page_count()
        ps["pool_page_peak"] = max(ps["pool_page_peak"], in_use)
        if self.trace is not None:
            self.trace.append({
                "ev": "admit", "tick": tick, "rep": rep.name,
                "rid": req.rid, "prompt_len": plen, "skip": skip,
                "page_row": page_row.copy(), "pages": total_pages,
                "logits": np.asarray(logits).copy(),
                "rows": np.asarray(eng.kv_rows_view()).copy()})
        if stream.finished:
            self._finalize(stream)
            return None
        return stream

    def prefill_only_tick(self, queue: deque, tick: int) -> List[Request]:
        """One prefill-pod tick: admit up to ``max_batch`` forwarded jobs
        per replica, run the full prefill over each prompt's block-aligned
        head, and queue the prefix pages write-behind (the coordinator
        flushes after the tick, which fires the publish-then-notify wave a
        waiting decode pod subscribed to).  Nothing decodes here: each job
        admits as a zero-token SHADOW request over the aligned head, so
        its pages allocate, publish, and free inside the tick -- a prefill
        pod holds no decode state across ticks, and the caller's Request
        objects are never touched.  Returns the jobs completed this tick
        (including pass-throughs too short to have a block-aligned head).
        """
        ps = self.prefix_stats
        bt = self.prefix_block_tokens
        done: List[Request] = []
        for r, rep in enumerate(self.replicas):
            jobs: List[Request] = []
            shadows: List[Request] = []
            budget = self.prefix_engine.free_page_count()
            while queue and len(jobs) < rep.max_batch:
                req = queue[0]
                cut = (len(req.prompt) // bt) * bt
                if cut == 0:
                    # no block-aligned head to publish: nothing a prefill
                    # pod can contribute, hand the request straight back
                    done.append(queue.popleft())
                    continue
                shadow = Request(req.rid, req.prompt[:cut], max_new=0)
                need = self._pages_needed(shadow)
                if need > self.max_pages:
                    raise ValueError(
                        f"prefill job {req.rid} needs {need} pages > "
                        f"max_pages={self.max_pages}")
                if need > budget:
                    if not jobs and need > self.n_decode_pages:
                        raise RuntimeError(
                            f"prefill job {req.rid} needs {need} pages; "
                            f"pool has {self.n_decode_pages}")
                    ps["paged_admission_deferrals"] += 1
                    break
                budget -= need
                jobs.append(queue.popleft())
                shadows.append(shadow)
            if not shadows:
                continue
            self._admit_reserved = sum(self._pages_needed(s)
                                       for s in shadows)
            params = rep.params()
            wver = rep.reader.cached_version("params")
            plan = self._lease_prefix_wave(rep, [s.prompt for s in shadows])
            mat_cache: Dict[Tuple[int, ...], Tuple] = {}
            for ji, shadow in enumerate(shadows):
                self._admit_reserved -= self._pages_needed(shadow)
                s = self._admit_one(rep, shadow, plan, ji, params, wver,
                                    mat_cache, tick)
                assert s is None, "max_new=0 shadow must finalize inline"
            self._admit_reserved = 0
            ps["role_prefill_jobs"] += len(jobs)
            done.extend(jobs)
        return done

    def _finalize(self, s: Stream) -> None:
        """A finished request releases everything immediately: pins drop,
        relocated copies refcount down, private pages go back on the free
        list -- zero coherence messages."""
        eng = self.prefix_engine
        for bid in s.shared_bids:
            n = self._pins.get(bid, 0) - 1
            if n > 0:
                self._pins[bid] = n
            else:
                self._pins.pop(bid, None)
        for pg in s.reloc_pages:
            n = self._reloc_refs.get(pg, 0) - 1
            if n > 0:
                self._reloc_refs[pg] = n
            else:
                self._reloc_refs.pop(pg, None)
                eng.free_pages([pg])
        if s.own_pages:
            eng.free_pages(s.own_pages)
        s.req.output = np.asarray(s.emitted[:s.req.max_new], np.int32)
        s.req.done = True

    def _renew_decode_leases(self, rep: DecodeReplica,
                             act: List[Stream]) -> None:
        """Decode-time re-reads of shared prefix blocks: every tick each
        stream reads its pinned blocks; expired leases renew data-less in
        ONE batched dispatch (the renewal-dominated pattern lease tuning
        optimizes).  Unexpired leases are local hits -- no messages."""
        if self.directory is not None:
            return self._renew_decode_leases_dir(rep, act)
        expired: Dict[int, int] = {}
        for s in act:
            for bid in s.shared_bids:
                ent = rep.kv_leases.get(bid)
                if ent is None or ent[2] != self._tags[bid]:
                    continue          # relocated/re-tagged: private copy
                if rep.kv_pts <= ent[1]:
                    # unexpired lease: a Table II local hit, zero messages
                    self.prefix_stats["prefix_local_hits"] += 1
                    self.prefix_stats["decode_local_hits"] += 1
                    rep.kv_pts = max(rep.kv_pts, ent[0])   # Table I load
                elif self.policy.skip_expired_renewal():
                    # TSO/RC: the store->load relaxation orders this read
                    # before the pts advance that aged the lease out, so a
                    # tag-checked read-only block serves locally with no
                    # renewal message (and no pts move off the stale wts)
                    self.prefix_stats["decode_renewals_skipped"] += 1
                elif bid not in expired:
                    expired[bid] = ent[0]
        if not expired:
            return
        res = self.prefix_engine.read_many([list(expired)], rep.kv_pts,
                                           req_wts=expired)
        rep.kv_pts = int(res.new_pts.max())
        for i, bid in enumerate(res.union_idx):
            bid = int(bid)
            rep.kv_leases[bid] = (int(res.wts[i]), int(res.rts[i]),
                                  int(self._tags[bid]))
        self.prefix_stats["decode_renewals"] += len(expired)

    def _renew_decode_leases_dir(self, rep: DecodeReplica,
                                 act: List[Stream]) -> None:
        """Directory-mode decode renewals: the same renewal-dominated
        pattern, one :meth:`ShardedLeaseDirectory.wave` for every expired
        lease (<=1 message per owner shard, data-less when the cached
        version matches).  A renewal that comes back with a NEWER version
        means another host re-tagged the block underneath this decode: the
        local copy keeps serving its bits as a frozen private copy (the
        same-version staleness rule relocation implements locally), so the
        cached lease is dropped rather than refreshed."""
        dirx = self.directory
        ps = self.prefix_stats
        expired: Dict[int, int] = {}
        for s in act:
            for bid in s.shared_bids:
                ent = rep.kv_leases.get(bid)
                if ent is None or ent[2] != int(dirx.tags[bid]):
                    continue          # re-tagged/migrated: private copy
                if rep.kv_pts <= ent[1]:
                    ps["prefix_local_hits"] += 1
                    ps["decode_local_hits"] += 1
                    rep.kv_pts = max(rep.kv_pts, ent[0])   # Table I load
                elif self.policy.skip_expired_renewal():
                    # TSO/RC: serve the tag-checked copy past its lease end
                    # with no renewal wave (see _renew_decode_leases)
                    ps["decode_renewals_skipped"] += 1
                elif bid not in expired:
                    expired[bid] = ent[0]
        if not expired:
            return
        res = dirx.wave(self.host_id, rep.kv_pts,
                        read_groups=[list(expired)], req_wts=expired)
        rep.kv_pts = int(res.new_pts)
        ps["role_renewal_msgs"] += res.msgs
        for bid, (w, r) in res.leases.items():
            if w == expired.get(bid, w):
                rep.kv_leases[bid] = (w, r, int(dirx.tags[bid]))
            else:
                rep.kv_leases.pop(bid, None)   # superseded: private copy
        ps["decode_renewals"] += len(expired)

    def _decode_tick(self, rep: DecodeReplica, act: List[Stream],
                     tick: int) -> None:
        """One continuous-batch decode step: every active stream advances a
        token, all KV traffic through pool pages."""
        eng = self.prefix_engine
        rep.kv_pts += 1                   # the tick is one logical step
        self.prefix_stats["decode_ticks"] += 1
        self._renew_decode_leases(rep, act)
        bt = self.prefix_block_tokens
        page_rows = np.stack([s.page_row for s in act])
        lengths = np.asarray([s.length for s in act], np.int32)
        tokens = np.asarray([[s.emitted[-1]] for s in act], np.int32)
        params = rep.params()             # weight lease check per tick
        with warnings.catch_warnings():
            # CPU XLA can't honor the pool donation; the TPU path does
            warnings.filterwarnings("ignore", message=".*donat.*")
            pool, logits = self._decode_paged_fn(
                params, eng.kv_rows_view(), jnp.asarray(page_rows),
                jnp.asarray(lengths), jnp.asarray(tokens))
        eng.set_kv_rows(pool, tokens_appended=len(act))
        self.prefix_stats["decode_block_reads"] += int(
            sum(-(-(int(n) + 1) // bt) for n in lengths))
        nxt = np.asarray(jnp.argmax(logits[:, -1], -1))
        if self.trace is not None:
            self.trace.append({
                "ev": "tick", "tick": tick, "rep": rep.name,
                "rids": [s.req.rid for s in act],
                "lengths": lengths.copy(), "tokens": tokens.copy(),
                "logits": np.asarray(logits).copy()})
        done = []
        for s, t in zip(act, nxt):
            s.length += 1
            s.emitted.append(int(t))
            if s.finished:
                done.append(s)
        for s in done:
            self._finalize(s)
            act.remove(s)

    def _mk_queues(self, requests: List[Request]) -> List[deque]:
        """Arrival-order groups of ``n_replicas`` requests affined to
        replicas round-robin (the old wave layout)."""
        nr = len(self.replicas)
        queues: List[deque] = [deque() for _ in range(nr)]
        for k in range(0, len(requests), nr):
            queues[(k // nr) % nr].extend(requests[k:k + nr])
        return queues

    def _busy(self, queues: List[deque]) -> bool:
        return any(queues) or any(self._active)

    def _paged_tick(self, queues: List[deque], tick: int) -> None:
        """One scheduler tick: admissions then decode steps on every
        replica.  The multi-host coordinator calls this per host to run K
        clusters in lockstep against the shared directory."""
        for r, rep in enumerate(self.replicas):
            self._admit(r, rep, queues[r], self._active[r], tick)
        for r, rep in enumerate(self.replicas):
            if self._active[r]:
                self._decode_tick(rep, self._active[r], tick)
        self._maybe_rebase()

    def _run_paged(self, requests: List[Request]) -> None:
        """The continuous-batching scheduler: requests join the running
        batch as pages free up, finish independently, and release pages
        immediately.  Admission and completion are fully independent per
        stream."""
        queues = self._mk_queues(requests)
        tick = 0
        while self._busy(queues):
            self._paged_tick(queues, tick)
            tick += 1

    # -- request loop -------------------------------------------------------

    def _serve_wave(self, rep: DecodeReplica, wave: List[Request],
                    plan: Optional[WavePlan]) -> None:
        """Dense-cache fallback wave (ssm/hybrid only): the lease protocol
        still runs per wave (prefix metadata sharing), decode stays on the
        per-request dense caches.  Everything serve needs from the plan
        (per-request coverage, clamped in the plan itself) already lives in
        ``WavePlan`` -- serve recomputes nothing."""
        del plan
        rep.serve(wave, params=rep.params())

    def run(self, requests: List[Request]) -> Tuple[List[Request], Dict]:
        if self.paged:
            self._run_paged(requests)
            return requests, self.coherence_report()
        waves: List[List[Request]] = []
        for i, r in enumerate(requests):
            if i % len(self.replicas) == 0:
                waves.append([])
            waves[-1].append(r)
        for i, wave in enumerate(waves):
            rep = self.replicas[i % len(self.replicas)]
            plan = None
            if self.prefix_reuse:
                plan = self._lease_prefix_wave(rep, [r.prompt for r in wave])
                self._maybe_rebase()
            self._serve_wave(rep, wave, plan)
        return requests, self.coherence_report()

    def coherence_report(self) -> Dict[str, Any]:
        s = self.store.stats
        e = self.prefix_engine.stats
        saved = s.renew_data_less * self.param_bytes
        kv_saved = e.data_less * self.prefix_engine.block_bytes
        # local hits never generate a message at all -- ledger them apart
        local_saved = (self.prefix_stats["prefix_local_hits"]
                       * self.prefix_engine.block_bytes)
        return CoherenceReport({
            "reads": s.reads, "writes": s.writes,
            "renewals": s.renews + e.renewals,
            "data_less_renewals": s.renew_data_less + e.data_less,
            "payload_transfers": s.payload_transfers + e.payload_transfers,
            "bytes_transferred": s.bytes_transferred + e.payload_bytes,
            "bytes_saved_by_renewals": saved + kv_saved,
            "bytes_saved_by_local_hits": local_saved,
            "wire_flits": s.flits + e.flits,
            "wire_bytes": s.wire_bytes + e.wire_bytes,
            "directory_would_invalidate": s.dir_invalidations,
            "directory_peak_sharers": s.dir_sharer_bits,
            "sanitize_checks": self.prefix_engine.sanitize_checks,
            "replica_local_hits": sum(r.reader.local_hits
                                      for r in self.replicas),
            # LeaseEngine prefix-KV path
            **self.prefix_stats,
            "prefix_data_less_renewals": e.data_less,
            "prefix_payload_transfers": e.payload_transfers,
            "prefix_blocks_written": e.writes,
            "prefix_rebases": e.rebases,
            # per-wave batched dispatch + paged-KV-pool ledger
            "prefix_read_dispatches": e.read_ops,
            "prefix_write_dispatches": e.write_ops,
            "prefix_kv_blocks_written": e.kv_blocks_written,
            "prefix_kv_blocks_read": e.kv_blocks_read,
            "prefix_kv_evictions": e.kv_evictions,
            # Tardis 2.0 lease-predictor ledger
            "pred_grows": e.pred_grows,
            "pred_shrinks": e.pred_shrinks,
            # decode-through-pages ledger (pool occupancy / page churn)
            "kv_tokens_appended": e.kv_tokens_appended,
            "pool_pages_allocated": e.pages_allocated,
            "pool_pages_freed": e.pages_freed,
            "pool_pages_free": self.prefix_engine.free_page_count(),
            # per-stack occupancy: one counter pair per named KV pool (the
            # moe family reports its dense and moe cache stacks separately)
            **{f"pool_tokens_appended_{s.pool}":
               e.kv_pool_tokens.get(s.pool, 0) for s in self._stacks},
            **({"kv_pool_stacks": ",".join(s.pool for s in self._stacks)}
               if self._stacks else {}),
            # config-like scalars (identical across a fleet's hosts; the
            # multi-host aggregate reports them once instead of summing)
            "ts_bits": self.prefix_engine.ts_bits,
            "kv_lease": self.prefix_engine.lease,
            "consistency": self.policy.consistency,
            "n_prefix_blocks": self.n_prefix_blocks,
            "role": self.role,
        })


class MultiHostServingCluster:
    """K serving hosts sharing ONE sharded lease directory.

    Each host is a full :class:`ServingCluster` (replicas, local payload
    cache, decode pages, its own weight store -- weight publishes sweep
    every host, so version sequences align); the
    :class:`~repro.core.shard_directory.ShardedLeaseDirectory` owns the
    prefix region's ``(wts, rts)`` tables and home KV pages, hashed across
    owner shards.  A prefix prefilled on host 0 is published write-behind
    to its home shards and served on host K-1 by timestamp-ordered page
    migration -- suffix-only prefill, no recomputation -- with the whole
    wave's cross-host lease traffic batched into at most one message per
    owner shard and ZERO invalidations or multicasts (the directory ledger
    proves both).  Hosts tick in lockstep (the simulated-fleet analogue of
    per-pod serving loops) and the coordinator drives one uniform
    timestamp rebase across every shard and replica.
    """

    ROLES = ("prefill", "decode", "mixed")

    def __init__(self, cfg, init_params_fn: Callable[[], Any],
                 n_hosts: int = 2, n_shards: Optional[int] = None,
                 dir_backend: str = "numpy",
                 sanitize: Optional[bool] = None,
                 roles: Optional[List[str]] = None,
                 spill_depth: int = 4, **kw):
        if n_hosts < 1:
            raise ValueError(f"n_hosts must be >= 1, got {n_hosts}")
        if roles is None:
            roles = ["mixed"] * n_hosts
        roles = [str(r) for r in roles]
        if len(roles) != n_hosts:
            raise ValueError(
                f"roles has {len(roles)} entries for {n_hosts} hosts")
        bad = sorted(set(roles) - set(self.ROLES))
        if bad:
            raise ValueError(
                f"unknown roles {bad}; each must be one of {self.ROLES}")
        if "decode" in roles and not any(
                r in ("prefill", "mixed") for r in roles):
            raise ValueError(
                "decode pods need at least one prefill or mixed host to "
                "forward cold prefixes to")
        if "prefill" in roles and not any(
                r in ("decode", "mixed") for r in roles):
            raise ValueError(
                "prefill pods need at least one decode or mixed host to "
                "hand streams back to")
        self.roles = roles
        self.spill_depth = int(spill_depth)
        # how many routed ticks a forwarded stream may wait on its
        # publish-then-notify wake before the decode pod force-admits it
        # (a dropped publish then surfaces as a ledgered cold prefill
        # instead of a hang)
        self.handoff_patience = 16
        self.hosts = [ServingCluster(cfg, init_params_fn,
                                     sanitize=sanitize, **kw)
                      for _ in range(n_hosts)]
        for host, role in zip(self.hosts, roles):
            host.role = role
        # cold-prefix work chain-hashes onto pure prefill pods; a fleet
        # with no pure prefill pod prefills on the mixed hosts
        self._prefill_pool = ([h for h, r in enumerate(roles)
                               if r == "prefill"]
                              or [h for h, r in enumerate(roles)
                                  if r == "mixed"])
        self._route_stats = {
            "router_warm_direct": 0, "router_cold_forwards": 0,
            "router_spills": 0, "router_handoffs": 0,
            "router_forced_admissions": 0,
        }
        h0 = self.hosts[0]
        if not h0.paged:
            raise ValueError(
                "multi-host serving requires a paged family (dense/vlm/moe)")
        eng = h0.prefix_engine
        self.directory = ShardedLeaseDirectory(
            h0.n_prefix_blocks, int(n_shards or n_hosts), n_hosts=n_hosts,
            policy=h0.policy, backend=dir_backend,
            block_bytes=eng.block_bytes, kv_pools=eng.kv_pools,
            kv_dtype=np.asarray(eng._kv_pool[:0]).dtype, sanitize=sanitize)
        for h, host in enumerate(self.hosts):
            host.attach_directory(self.directory, h)

    def publish_weights(self, params) -> int:
        """Hot-swap on every host + the directory's home-payload barrier:
        still zero invalidation MESSAGES anywhere -- both invalidation
        sweeps are manager-side bitmap clears.  Returns the max publish
        timestamp across hosts (per-host stores tick independently) and
        asserts the fleet agrees on the post-publish weight version."""
        pts = 0
        for host in self.hosts:
            pts = max(pts, host.publish_weights(params))
        self.directory.publish_barrier()
        vers = {host.store.versions().get("params")
                for host in self.hosts}
        if len(vers) != 1:
            raise RuntimeError(
                f"hosts disagree on post-publish weight version: "
                f"{sorted(vers)}")
        return pts

    def _maybe_rebase_all(self) -> None:
        """One uniform shift across every directory shard and every
        host's replicas: cross-shard timestamp order is protocol state."""
        shift = self.directory.maybe_rebase()
        if shift:
            for host in self.hosts:
                for rep in host.replicas:
                    rep.rebase_kv(shift)

    def run(self, requests: List[Request],
            affinity: Optional[List[int]] = None
            ) -> Tuple[List[Request], Dict]:
        """Serve ``requests`` across the hosts.  ``affinity[i]`` pins
        request i to a (decode-capable) host -- default round-robin over
        the decode/mixed hosts; the cross-host smoke pins a shared prefix
        to host 0 first, then its reuse to the last host.  A symmetric
        fleet (all mixed) runs every host's scheduler directly; a fleet
        with prefill/decode roles routes each request through the
        admission router first (see :meth:`_run_routed`)."""
        serve_pool = [h for h, r in enumerate(self.roles)
                      if r != "prefill"]
        if affinity is None:
            affinity = [serve_pool[i % len(serve_pool)]
                        for i in range(len(requests))]
        if len(affinity) != len(requests):
            raise ValueError(
                f"affinity has {len(affinity)} entries for "
                f"{len(requests)} requests")
        for i, a in enumerate(affinity):
            a = int(a)
            if not 0 <= a < len(self.hosts):
                raise ValueError(
                    f"affinity[{i}] = {a} is out of range for "
                    f"{len(self.hosts)} hosts (negative ids do not wrap)")
            if self.hosts[a].role == "prefill":
                raise ValueError(
                    f"affinity[{i}] = {a} pins a stream to a prefill-only "
                    f"pod; pin it to a decode or mixed host")
        affinity = [int(a) for a in affinity]
        if all(r == "mixed" for r in self.roles):
            self._run_symmetric(requests, affinity)
        else:
            self._run_routed(requests, affinity)
        return requests, self.coherence_report()

    def _run_symmetric(self, requests: List[Request],
                       affinity: List[int]) -> None:
        per_host: List[List[Request]] = [[] for _ in self.hosts]
        for req, a in zip(requests, affinity):
            per_host[a].append(req)
        queues = [h._mk_queues(reqs)
                  for h, reqs in zip(self.hosts, per_host)]
        tick = 0
        while any(h._busy(q) for h, q in zip(self.hosts, queues)):
            for h, host in enumerate(self.hosts):
                host._paged_tick(queues[h], tick)
            self._maybe_rebase_all()
            tick += 1
        self.directory.flush_deferred()    # drain write-behind payloads

    # -- disaggregated prefill/decode routing -------------------------------

    def _enqueue(self, queues: List[List[deque]], arrivals: List[int],
                 h: int, req: Request) -> None:
        """Hand a stream to host ``h``'s scheduler, replica-affined in the
        same round-robin-by-group layout ``_mk_queues`` produces for an
        up-front request list."""
        nr = len(self.hosts[h].replicas)
        queues[h][(arrivals[h] // nr) % nr].append(req)
        arrivals[h] += 1

    def _route(self, requests: List[Request], affinity: List[int],
               queues: List[List[deque]], arrivals: List[int],
               pq: List[deque], waiting: List[List]) -> None:
        """The admission router.  A request whose LEADING prefix block is
        warm (directory tag matches and the page is home, or the decode
        host already caches that content) goes straight to its decode
        host -- suffix-only prefill plus any tail repair is decode-pod
        work.  A cold leading block means full-prefix prefill: the stream
        is forwarded to the prefill pod its chain hash names (spilling to
        a less-loaded pod past ``spill_depth``), the decode host
        subscribes to the prefix gids, and the stream parks in
        ``waiting`` until the publish-then-notify wake hands it back."""
        dirx = self.directory
        rs = self._route_stats
        pool = self._prefill_pool
        for req, d in zip(requests, affinity):
            host = self.hosts[d]
            bids, tags = host._prefix_blocks_of(req.prompt)
            warm = not bids or (
                int(dirx.tags[bids[0]]) == tags[0]
                and (dirx.home_ok(bids[0])
                     or (host._tags[bids[0]] == tags[0]
                         and host.prefix_engine.kv_ok(bids[0]))))
            if warm:
                rs["router_warm_direct"] += 1
                self._enqueue(queues, arrivals, d, req)
                continue
            landed = dirx.subscribe(d, bids, tags)
            pending = {int(b) for b in bids} - {int(b) for b in landed}
            if not pending:
                # raced warm: everything is already home
                rs["router_warm_direct"] += 1
                self._enqueue(queues, arrivals, d, req)
                continue
            p = pool[tags[0] % len(pool)]
            if len(pq[p]) >= self.spill_depth:
                for off in range(1, len(pool)):
                    q = pool[(tags[0] % len(pool) + off) % len(pool)]
                    if len(pq[q]) < len(pq[p]):
                        p = q
                        rs["router_spills"] += 1
                        break
            rs["router_cold_forwards"] += 1
            pq[p].append(req)
            waiting.append([req, d, pending, 0])

    def _run_routed(self, requests: List[Request],
                    affinity: List[int]) -> None:
        """The disaggregated serving loop: prefill pods burn down their
        forwarded cold-prefix queues and flush write-behind publishes
        (firing the notify waves), woken streams hand off to their decode
        hosts, and the decode/mixed hosts run the ordinary paged
        scheduler -- all in lockstep ticks on the one directory."""
        queues = [h._mk_queues([]) for h in self.hosts]
        arrivals = [0] * len(self.hosts)
        pq: List[deque] = [deque() for _ in self.hosts]
        waiting: List[List] = []      # [req, decode_host, pending_gids, age]
        self._route(requests, affinity, queues, arrivals, pq, waiting)
        rs = self._route_stats
        tick = 0
        while (waiting or any(pq)
               or any(h._busy(q) for h, q in zip(self.hosts, queues))):
            for p in self._prefill_pool:
                if pq[p]:
                    self.hosts[p].prefill_only_tick(pq[p], tick)
                    # flush NOW so this tick's notify waves fire and the
                    # decode pods can admit next tick, not eventually
                    self.directory.flush_deferred(p)
            for d in {w[1] for w in waiting}:
                got = set(self.directory.pop_notifications(d))
                if got:
                    for w in waiting:
                        if w[1] == d:
                            w[2] -= got
            for w in list(waiting):
                req, d, pending, age = w
                if pending and age < self.handoff_patience:
                    w[3] += 1
                    continue
                if pending:
                    # a publish was dropped (collision re-tag, version
                    # race): force the admission rather than hang; any
                    # cold prefill it causes lands in the decode pod's
                    # role ledger where the smoke can see it
                    rs["router_forced_admissions"] += 1
                else:
                    rs["router_handoffs"] += 1
                waiting.remove(w)
                self._enqueue(queues, arrivals, d, req)
            for h, host in enumerate(self.hosts):
                if host.role != "prefill":
                    host._paged_tick(queues[h], tick)
            self._maybe_rebase_all()
            tick += 1
        self.directory.flush_deferred()    # drain write-behind payloads

    # config-like report keys: identical on every host by construction,
    # so the aggregate reports them ONCE (and asserts the fleet agrees)
    # instead of summing them like traffic counters.
    _CONFIG_KEYS = ("ts_bits", "kv_lease", "consistency",
                    "n_prefix_blocks", "kv_pool_stacks")
    # high-water marks: the fleet-wide value is the max, not the sum.
    _MAX_KEYS = ("pool_page_peak", "directory_peak_sharers")
    # per-host breakout columns (the smokes grep host{h}_* rows).
    _PER_HOST_KEYS = ("prefix_prefill_tokens_skipped", "prefix_flops_saved",
                      "prefix_block_hits", "xhost_pages_fetched",
                      "xhost_pages_published", "role_cold_prefills",
                      "role_suffix_admissions", "role_pages_published",
                      "role_prefill_jobs", "role_renewal_msgs",
                      "decode_renewals", "decode_ticks")

    def coherence_report(self) -> Dict[str, Any]:
        """Per-host traffic counters summed, config scalars reported once,
        high-water marks maxed, per-role/per-host counters broken out
        (the smokes assert host K-1 skipped prefill flops and a decode
        pod did zero cold prefills), and the directory's cross-host
        ledger merged in."""
        agg: Dict[str, Any] = {}
        reports = [host.coherence_report() for host in self.hosts]
        for k in self._CONFIG_KEYS:
            vals = {rep[k] for rep in reports if k in rep}
            if len(vals) > 1:
                raise RuntimeError(
                    f"hosts disagree on config scalar {k!r}: {sorted(vals)}")
            if vals:
                agg[k] = vals.pop()
        for h, rep in enumerate(reports):
            for k, v in rep.items():
                if k in self._CONFIG_KEYS or k == "role":
                    continue
                if k in self._MAX_KEYS:
                    agg[k] = max(agg.get(k, 0), int(v))
                elif isinstance(v, (int, np.integer)) \
                        and not isinstance(v, bool):
                    agg[k] = agg.get(k, 0) + int(v)
                elif k not in agg:
                    agg[k] = v
            agg[f"host{h}_role"] = rep["role"]
            for k in self._PER_HOST_KEYS:
                agg[f"host{h}_{k}"] = rep[k]
        agg["roles"] = ",".join(rep["role"] for rep in reports)
        agg["n_hosts"] = len(self.hosts)
        agg.update(self._route_stats)
        agg.update(self.directory.report())
        return CoherenceReport(agg)
