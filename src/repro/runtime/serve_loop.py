"""Tardis-coherent serving engine: continuous batching + leased weights/KV.

Multiple decode replicas serve requests against
  * a shared *weight version* (hot-swapped by a trainer/publisher), and
  * a shared paged prefix-KV block store (RadixAttention-style reuse),
both coherent through Tardis leases: replicas hold leases, renew on expiry
(data-less when unchanged -- the common case), and a weight publish never
broadcasts: it jumps ahead of all outstanding leases.  Metadata is O(log N)
per object; there is no sharer list in the system.

Weights go through :class:`repro.core.store.TardisStore`; the prefix-KV
block table is a :class:`repro.core.lease_engine.LeaseEngine` whose
read/renew/write-jump-ahead transitions run in the ``tardis_lease`` Pallas
kernel.  Prefill hashes prompt-prefix chunks to block ids (content
addressing, CRC-chained so a block id names the *whole* prefix up to that
chunk); blocks whose content tag matches are leased -- locally when the
replica's lease still covers its pts, by data-less renewal when the version
is unchanged, by payload transfer otherwise -- and new prefixes are written
with the jump-ahead rule, evicting colliding tags without any invalidation
(readers of the old content keep their leases, exactly the paper's stale-
but-SC-legal window).

The engine is single-process (replicas are cooperative objects) but every
coherence message is accounted in flits, so benchmarks can compare against
a directory-style invalidation broadcast on the same request stream.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.lease_engine import LeaseEngine
from ..core.store import Replica, TardisStore
from ..models import decode_step, init_cache, prefill


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray               # (S,) int32
    max_new: int = 8
    done: bool = False
    output: Optional[np.ndarray] = None


class DecodeReplica:
    """One model replica: leased weights + local continuous batch.

    Besides the weight lease (via ``self.reader``) the replica keeps its own
    program timestamp ``kv_pts`` and cached ``(wts, rts)`` leases for prefix-
    KV blocks; the cluster's LeaseEngine is their timestamp manager.
    """

    def __init__(self, cfg, store: TardisStore, name: str,
                 max_batch: int = 4, cache_len: int = 256,
                 selfinc_period: int = 8):
        self.cfg = cfg
        self.name = name
        self.reader = Replica(store, name, selfinc_period=selfinc_period)
        self.max_batch = max_batch
        self.cache_len = cache_len
        self.kv_pts = 0
        # bid -> (wts, rts, content_tag): the tag names WHICH prefix the
        # cached copy holds; a lease alone says a read is SC-legal, the tag
        # says it is the content this request wants (collision evictions
        # re-tag blocks without invalidating anybody).
        self.kv_leases: Dict[int, Tuple[int, int, int]] = {}
        self._decode = jax.jit(
            lambda p, c, t, i: decode_step(cfg, p, c, t, i))
        self._prefill = jax.jit(
            lambda p, b: prefill(cfg, p, b, cache_len))

    def params(self):
        """Weight access through the lease (renewal-on-expiry)."""
        return self.reader.read("params")

    def rebase_kv(self, shift: int) -> None:
        """Apply an engine rebase: shift pts/leases; drop leases whose rts
        would fall below the new base (cannot be raised unilaterally)."""
        if not shift:
            return
        self.kv_pts = max(0, self.kv_pts - shift)
        self.kv_leases = {
            bid: (max(0, w - shift), r - shift, t)
            for bid, (w, r, t) in self.kv_leases.items() if r >= shift}

    def serve(self, reqs: List[Request]) -> List[Request]:
        """Greedy-decode a wave of requests (one continuous batch)."""
        if not reqs:
            return reqs
        params = self.params()
        s = max(len(r.prompt) for r in reqs)
        toks = np.zeros((len(reqs), s), np.int32)
        for i, r in enumerate(reqs):
            toks[i, :len(r.prompt)] = r.prompt
        cache, logits = self._prefill(params, {"tokens": jnp.asarray(toks)})
        outs = [[] for _ in reqs]
        cur = jnp.int32(s)
        next_tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        max_new = max(r.max_new for r in reqs)
        for step in range(max_new):
            for i in range(len(reqs)):
                outs[i].append(int(next_tok[i, 0]))
            params = self.params()           # lease check per decode wave
            cache, logits = self._decode(params, cache, next_tok, cur)
            next_tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
            cur = cur + 1
        for r, o in zip(reqs, outs):
            r.output = np.asarray(o[:r.max_new], np.int32)
            r.done = True
        return reqs


class ServingCluster:
    """N replicas + weight publisher + shared prefix-KV block table."""

    def __init__(self, cfg, init_params_fn: Callable[[], Any],
                 n_replicas: int = 2, lease: int = 10,
                 n_prefix_blocks: int = 4096, prefix_block_tokens: int = 16,
                 kv_lease: int = 64, prefix_reuse: bool = True,
                 **replica_kw):
        self.store = TardisStore(lease=lease)
        p0 = init_params_fn()
        nbytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(p0))
        self.publisher = Replica(self.store, "trainer")
        self.publisher.write("params", p0, nbytes=nbytes)
        self.param_bytes = nbytes
        self.replicas = [
            DecodeReplica(cfg, self.store, f"replica{i}", **replica_kw)
            for i in range(n_replicas)]
        # paged prefix-KV metadata: one leased block per prefix chunk.
        self.prefix_block_tokens = int(prefix_block_tokens)
        self.prefix_reuse = bool(prefix_reuse)
        kv_bytes = (2 * cfg.n_layers * cfg.n_kv_heads * cfg.head_dim()
                    * 4 * self.prefix_block_tokens)
        self.prefix_engine = LeaseEngine(
            n_prefix_blocks, lease=kv_lease, block_bytes=kv_bytes)
        self._tags = np.full(n_prefix_blocks, -1, np.int64)  # content hashes
        self.prefix_stats = {
            "prefix_block_hits": 0, "prefix_local_hits": 0,
            "prefix_renewals": 0, "prefix_block_misses": 0,
            "prefix_evictions": 0, "prefix_tokens_reused": 0,
        }

    def publish_weights(self, params) -> int:
        """Hot-swap: no invalidation broadcast; replicas renew on expiry."""
        self.publisher.write("params", params, nbytes=self.param_bytes)
        return self.publisher.pts

    # -- prefix-KV reuse ----------------------------------------------------

    def _prefix_blocks_of(self, prompt: np.ndarray) -> Tuple[List[int],
                                                             List[int]]:
        """Chain-hash whole prompt prefixes into (block_ids, content_tags)."""
        bt = self.prefix_block_tokens
        bids, tags = [], []
        h = 0
        for c in range(len(prompt) // bt):
            h = zlib.crc32(np.ascontiguousarray(
                prompt[c * bt:(c + 1) * bt]).tobytes(), h)
            bids.append(h % self.prefix_engine.n_blocks)
            tags.append(h)
        return bids, tags

    def _lease_prefix(self, rep: DecodeReplica, prompt: np.ndarray) -> None:
        """Prefill-side prefix reuse for one request on one replica.

        Matching blocks are leased: locally when the replica's lease still
        covers its pts, through the engine otherwise (data-less renewal when
        its cached version matches).  New prefixes are written with the
        jump-ahead rule -- no invalidation reaches other replicas.
        """
        rep.kv_pts += 1        # per-request logical tick (paper's self-inc:
        #                        bounds staleness and lets leases expire)
        bids, tags = self._prefix_blocks_of(prompt)
        ps = self.prefix_stats
        renew_idx, renew_req, miss_idx = [], [], []
        for bid, tag in zip(bids, tags):
            if self._tags[bid] == tag:
                ps["prefix_block_hits"] += 1
                ps["prefix_tokens_reused"] += self.prefix_block_tokens
                ent = rep.kv_leases.get(bid)
                cached_ok = ent is not None and ent[2] == tag
                if cached_ok and rep.kv_pts <= ent[1]:
                    ps["prefix_local_hits"] += 1     # unexpired local lease
                    rep.kv_pts = max(rep.kv_pts, ent[0])
                elif bid not in renew_idx:
                    renew_idx.append(bid)
                    # a cached copy of DIFFERENT content can't renew
                    renew_req.append(ent[0] if cached_ok else -1)
            else:
                if self._tags[bid] != -1:
                    ps["prefix_evictions"] += 1      # collision: re-tag
                ps["prefix_block_misses"] += 1
                if bid not in miss_idx:
                    miss_idx.append(bid)
                self._tags[bid] = tag
        if renew_idx:                                # before any jump-ahead
            res = self.prefix_engine.read(renew_idx, rep.kv_pts,
                                          req_wts=renew_req)
            rep.kv_pts = res.new_pts
            # only requests carrying a cached version are renewals; the
            # rest are first fetches of someone else's prefix blocks
            ps["prefix_renewals"] += sum(1 for rq in renew_req if rq >= 0)
            for i, bid in enumerate(renew_idx):
                rep.kv_leases[bid] = (int(res.wts[i]), int(res.rts[i]),
                                      int(self._tags[bid]))
        if miss_idx:
            ts = self.prefix_engine.write(miss_idx, rep.kv_pts)
            rep.kv_pts = ts
            for bid in miss_idx:
                rep.kv_leases[bid] = (ts, ts, int(self._tags[bid]))

    def _maybe_rebase(self) -> None:
        shift = self.prefix_engine.maybe_rebase()
        if shift:
            for rep in self.replicas:
                rep.rebase_kv(shift)

    # -- request loop -------------------------------------------------------

    def run(self, requests: List[Request]) -> Tuple[List[Request], Dict]:
        waves: List[List[Request]] = []
        for i, r in enumerate(requests):
            if i % len(self.replicas) == 0:
                waves.append([])
            waves[-1].append(r)
        for i, wave in enumerate(waves):
            rep = self.replicas[i % len(self.replicas)]
            if self.prefix_reuse:
                for r in wave:
                    self._lease_prefix(rep, r.prompt)
                self._maybe_rebase()
            rep.serve(wave)
        return requests, self.coherence_report()

    def coherence_report(self) -> Dict[str, Any]:
        s = self.store.stats
        e = self.prefix_engine.stats
        saved = s.renew_data_less * self.param_bytes
        kv_saved = e.data_less * self.prefix_engine.block_bytes
        # local hits never generate a message at all -- ledger them apart
        local_saved = (self.prefix_stats["prefix_local_hits"]
                       * self.prefix_engine.block_bytes)
        return {
            "reads": s.reads, "writes": s.writes,
            "renewals": s.renews + e.renewals,
            "data_less_renewals": s.renew_data_less + e.data_less,
            "payload_transfers": s.payload_transfers + e.payload_transfers,
            "bytes_transferred": s.bytes_transferred + e.payload_bytes,
            "bytes_saved_by_renewals": saved + kv_saved,
            "bytes_saved_by_local_hits": local_saved,
            "wire_flits": s.flits + e.flits,
            "wire_bytes": s.wire_bytes + e.wire_bytes,
            "directory_would_invalidate": s.dir_invalidations,
            "directory_peak_sharers": s.dir_sharer_bits,
            "replica_local_hits": sum(r.reader.local_hits
                                      for r in self.replicas),
            # LeaseEngine prefix-KV path
            **self.prefix_stats,
            "prefix_data_less_renewals": e.data_less,
            "prefix_payload_transfers": e.payload_transfers,
            "prefix_blocks_written": e.writes,
            "prefix_rebases": e.rebases,
        }
