"""Oracles: naive attention over the valid cache prefix / gathered pages."""
import jax.numpy as jnp

from ...models.attention import reference_attention


def decode_attention_ref(q, k_cache, v_cache, kv_len):
    return reference_attention(q, k_cache, v_cache, causal=False,
                               kv_len=kv_len)


def paged_decode_attention_ref(q, cur_k, cur_v, pool_rows, page_rows,
                               lengths, *, chunk, k_off, v_off, hkv):
    """Gather-then-attend: materialize each request's pages into a dense
    cache, place the current token at slot ``lengths[b]``, and run the
    naive reference over the valid prefix (kv_len = lengths + 1)."""
    b, one, h, dh = q.shape
    t = page_rows.shape[1] * chunk
    rows_idx = (jnp.asarray(page_rows, jnp.int32)[:, :, None] * chunk
                + jnp.arange(chunk, dtype=jnp.int32)).reshape(b, t)
    gathered = pool_rows[rows_idx]                    # (B, T, token_row)
    kc = gathered[..., k_off:k_off + hkv * dh].reshape(b, t, hkv, dh)
    vc = gathered[..., v_off:v_off + hkv * dh].reshape(b, t, hkv, dh)
    slot = (jnp.arange(t)[None, :] == jnp.asarray(lengths)[:, None])
    kc = jnp.where(slot[..., None, None], cur_k.astype(kc.dtype), kc)
    vc = jnp.where(slot[..., None, None], cur_v.astype(vc.dtype), vc)
    return reference_attention(q, kc, vc, causal=False,
                               kv_len=jnp.asarray(lengths) + 1)
