"""Oracle: naive attention over the valid cache prefix."""
from ...models.attention import reference_attention


def decode_attention_ref(q, k_cache, v_cache, kv_len):
    return reference_attention(q, k_cache, v_cache, causal=False,
                               kv_len=kv_len)
