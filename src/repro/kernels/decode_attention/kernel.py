"""Flash-decode Pallas kernels: one query token vs. a long KV cache.

``_decode_kernel`` is the dense-cache path -- grid (batch * kv_heads,
num_kv_blocks), the kv dimension sequential, with the GQA group's
(m, l, acc) accumulators in VMEM scratch (split-S partial softmax).
``kv_len`` is a *dynamic* scalar (continuous batching!) delivered through
scalar prefetch so block masking needs no recompilation.

``_paged_decode_kernel`` is the paged-pool path: each request's KV lives in
LeaseEngine pool pages (one lane-padded row per token, all layers packed),
named by a per-request page-table row of block ids.  The scalar-prefetched
page tables drive the K/V input index maps -- the same DMA trick as the
lease engine's ``_gather_kernel`` -- so grid step (b, j) streams request
b's j-th page straight from the pool with no host round trip and no
materialized per-request cache.  Per-request ``lengths`` (also prefetched)
mask the ragged tail; the current decode token's fresh (k, v) ride in as a
separate operand folded into the accumulators at j == 0, which keeps the
append-then-attend ordering of the dense path without re-reading the row
the step just wrote.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr,
                   acc_scr, *, scale: float, block_k: int, num_kv: int):
    ik = pl.program_id(1)
    kv_len = len_ref[0]

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                  # (g, dh)
    k = k_ref[0].astype(jnp.float32)                  # (bk, dh)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (g, bk)
    kpos = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, s.shape, 1)
    s = jnp.where(kpos < kv_len, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.where(m_new <= NEG_INF, 0.0, jnp.exp(s - m_new))
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    m_scr[...] = m_new
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())))

    @pl.when(ik == num_kv - 1)
    def _finish():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def decode_attention_grouped(q, k, v, kv_len, *, scale: float,
                             block_k: int = 512, interpret: bool = False):
    """q: (B*Hkv, G, Dh); k, v: (B*Hkv, T, Dh); kv_len: () int32."""
    bh, g, dh = q.shape
    _, t, _ = k.shape
    block_k = min(block_k, t)
    assert t % block_k == 0
    num_kv = t // block_k
    grid = (bh, num_kv)
    return pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, block_k=block_k,
                          num_kv=num_kv),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, g, dh), lambda bh_, ik, _s: (bh_, 0, 0)),
                pl.BlockSpec((1, block_k, dh),
                             lambda bh_, ik, _s: (bh_, ik, 0)),
                pl.BlockSpec((1, block_k, dh),
                             lambda bh_, ik, _s: (bh_, ik, 0)),
            ],
            out_specs=pl.BlockSpec((1, g, dh),
                                   lambda bh_, ik, _s: (bh_, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, dh), jnp.float32),
            ]),
        out_shape=jax.ShapeDtypeStruct((bh, g, dh), q.dtype),
        interpret=interpret,
    )(jnp.asarray(kv_len, jnp.int32).reshape(1), q, k, v)


def _paged_decode_kernel(scalars_ref, q_ref, cur_k_ref, cur_v_ref, pool_ref,
                         o_ref, m_scr, l_scr, acc_scr, *, scale: float,
                         chunk: int, k_off: int, v_off: int, hk: int,
                         dh: int, num_pages: int):
    b = pl.program_id(0)
    j = pl.program_id(1)
    kv_len = scalars_ref[b]                           # this request's tokens

    q = q_ref[0].astype(jnp.float32)                  # (hk, g, dh)

    @pl.when(j == 0)
    def _init():
        # fold the CURRENT token (always attended, position == kv_len)
        # into fresh accumulators before any pool page streams in
        ck = cur_k_ref[0].astype(jnp.float32)         # (hk, dh)
        cv = cur_v_ref[0].astype(jnp.float32)
        s0 = jnp.sum(q * ck[:, None, :], axis=-1, keepdims=True) * scale
        m_scr[...] = s0                               # (hk, g, 1)
        l_scr[...] = jnp.ones_like(s0)
        acc_scr[...] = jnp.broadcast_to(cv[:, None, :], acc_scr.shape)

    rows = pool_ref[...]                              # (chunk, token_row)
    k = rows[:, k_off:k_off + hk * dh].reshape(chunk, hk, dh)
    v = rows[:, v_off:v_off + hk * dh].reshape(chunk, hk, dh)
    k = k.astype(jnp.float32).transpose(1, 0, 2)      # (hk, chunk, dh)
    v = v.astype(jnp.float32).transpose(1, 0, 2)
    # (hk, g, chunk): contract dh, batch over the kv heads
    s = jax.lax.dot_general(q, k, (((2,), (2,)), ((0,), (0,)))) * scale
    kpos = j * chunk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
    s = jnp.where(kpos < kv_len, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=2, keepdims=True))
    p = jnp.where(m_new <= NEG_INF, 0.0, jnp.exp(s - m_new))
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=2, keepdims=True)
    m_scr[...] = m_new
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((2,), (1,)), ((0,), (0,))))

    @pl.when(j == num_pages - 1)
    def _finish():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def paged_decode_attention_grouped(q, cur_k, cur_v, pool_rows, page_rows,
                                   lengths, *, scale: float, chunk: int,
                                   k_off: int, v_off: int,
                                   interpret: bool = False):
    """q: (B, Hkv, G, Dh); cur_k/cur_v: (B, Hkv, Dh) -- the token being
    decoded; pool_rows: (n_blocks*chunk, token_row) engine pool view;
    page_rows: (B, P) int32 page tables (entries past a request's pages
    must be clamped valid); lengths: (B,) int32 tokens already in pages.

    Attends over [pool tokens 0..lengths[b]) ; current token] per request.
    """
    b, hk, g, dh = q.shape
    num_pages = page_rows.shape[1]
    token_row = pool_rows.shape[1]
    scalars = jnp.concatenate([
        jnp.asarray(lengths, jnp.int32).reshape(-1),
        jnp.asarray(page_rows, jnp.int32).reshape(-1)])
    grid = (b, num_pages)
    return pl.pallas_call(
        functools.partial(_paged_decode_kernel, scale=scale, chunk=chunk,
                          k_off=k_off, v_off=v_off, hk=hk, dh=dh,
                          num_pages=num_pages),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, hk, g, dh), lambda bb, j, _s: (bb, 0, 0, 0)),
                pl.BlockSpec((1, hk, dh), lambda bb, j, _s: (bb, 0, 0)),
                pl.BlockSpec((1, hk, dh), lambda bb, j, _s: (bb, 0, 0)),
                # the page table drives the pool DMA: page j of request bb
                pl.BlockSpec((chunk, token_row),
                             lambda bb, j, s: (s[b + bb * num_pages + j], 0)),
            ],
            out_specs=pl.BlockSpec((1, hk, g, dh),
                                   lambda bb, j, _s: (bb, 0, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((hk, g, 1), jnp.float32),
                pltpu.VMEM((hk, g, 1), jnp.float32),
                pltpu.VMEM((hk, g, dh), jnp.float32),
            ]),
        out_shape=jax.ShapeDtypeStruct((b, hk, g, dh), q.dtype),
        interpret=interpret,
    )(scalars, q, cur_k, cur_v, pool_rows)
