"""Flash-decode Pallas kernel: one query token vs. a long KV cache.

Grid: (batch * kv_heads, num_kv_blocks) -- the kv dimension is sequential,
with the GQA group's (m, l, acc) accumulators in VMEM scratch (split-S
partial softmax).  ``kv_len`` is a *dynamic* scalar (continuous batching!)
delivered through scalar prefetch so block masking needs no recompilation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr,
                   acc_scr, *, scale: float, block_k: int, num_kv: int):
    ik = pl.program_id(1)
    kv_len = len_ref[0]

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                  # (g, dh)
    k = k_ref[0].astype(jnp.float32)                  # (bk, dh)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (g, bk)
    kpos = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, s.shape, 1)
    s = jnp.where(kpos < kv_len, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.where(m_new <= NEG_INF, 0.0, jnp.exp(s - m_new))
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    m_scr[...] = m_new
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())))

    @pl.when(ik == num_kv - 1)
    def _finish():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def decode_attention_grouped(q, k, v, kv_len, *, scale: float,
                             block_k: int = 512, interpret: bool = False):
    """q: (B*Hkv, G, Dh); k, v: (B*Hkv, T, Dh); kv_len: () int32."""
    bh, g, dh = q.shape
    _, t, _ = k.shape
    block_k = min(block_k, t)
    assert t % block_k == 0
    num_kv = t // block_k
    grid = (bh, num_kv)
    return pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, block_k=block_k,
                          num_kv=num_kv),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, g, dh), lambda bh_, ik, _s: (bh_, 0, 0)),
                pl.BlockSpec((1, block_k, dh),
                             lambda bh_, ik, _s: (bh_, ik, 0)),
                pl.BlockSpec((1, block_k, dh),
                             lambda bh_, ik, _s: (bh_, ik, 0)),
            ],
            out_specs=pl.BlockSpec((1, g, dh),
                                   lambda bh_, ik, _s: (bh_, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, dh), jnp.float32),
            ]),
        out_shape=jax.ShapeDtypeStruct((bh, g, dh), q.dtype),
        interpret=interpret,
    )(jnp.asarray(kv_len, jnp.int32).reshape(1), q, k, v)
