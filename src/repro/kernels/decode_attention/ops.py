"""Public wrapper: (B, 1, H, Dh) query + (B, T, Hkv, Dh) caches."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import decode_attention_grouped


@partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(q, k_cache, v_cache, kv_len, *, block_k: int = 512,
                     interpret: bool = False):
    b, one, h, dh = q.shape
    _, t, hkv, _ = k_cache.shape
    g = h // hkv
    scale = dh ** -0.5
    pad = (-dh) % 128
    if pad:
        padw = [(0, 0)] * 3 + [(0, pad)]
        q, k_cache, v_cache = (jnp.pad(a, padw) for a in (q, k_cache, v_cache))
    qg = q.reshape(b, h, -1).reshape(b, hkv, g, q.shape[-1]) \
        .reshape(b * hkv, g, q.shape[-1])
    kt = k_cache.transpose(0, 2, 1, 3).reshape(b * hkv, t, k_cache.shape[-1])
    vt = v_cache.transpose(0, 2, 1, 3).reshape(b * hkv, t, v_cache.shape[-1])
    out = decode_attention_grouped(qg, kt, vt, kv_len, scale=scale,
                                   block_k=block_k, interpret=interpret)
    out = out.reshape(b, hkv, g, -1).reshape(b, h, -1)[..., :dh]
    return out[:, None].reshape(b, 1, h, dh)
