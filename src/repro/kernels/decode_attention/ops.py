"""Public wrappers: dense-cache and paged-pool flash decode.

``decode_attention``: (B, 1, H, Dh) query + (B, T, Hkv, Dh) caches.
``paged_decode_attention``: (B, 1, H, Dh) query + the LeaseEngine pool's
(n_rows, token_row) view + per-request page tables / lengths + the current
token's fresh (k, v) -- KV never leaves its pool pages.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import decode_attention_grouped, paged_decode_attention_grouped


@partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(q, k_cache, v_cache, kv_len, *, block_k: int = 512,
                     interpret: bool = False):
    b, one, h, dh = q.shape
    _, t, hkv, _ = k_cache.shape
    g = h // hkv
    scale = dh ** -0.5
    pad = (-dh) % 128
    if pad:
        padw = [(0, 0)] * 3 + [(0, pad)]
        q, k_cache, v_cache = (jnp.pad(a, padw) for a in (q, k_cache, v_cache))
    qg = q.reshape(b, h, -1).reshape(b, hkv, g, q.shape[-1]) \
        .reshape(b * hkv, g, q.shape[-1])
    kt = k_cache.transpose(0, 2, 1, 3).reshape(b * hkv, t, k_cache.shape[-1])
    vt = v_cache.transpose(0, 2, 1, 3).reshape(b * hkv, t, v_cache.shape[-1])
    out = decode_attention_grouped(qg, kt, vt, kv_len, scale=scale,
                                   block_k=block_k, interpret=interpret)
    out = out.reshape(b, hkv, g, -1).reshape(b, h, -1)[..., :dh]
    return out[:, None].reshape(b, 1, h, dh)


@partial(jax.jit, static_argnames=("chunk", "k_off", "v_off", "hkv",
                                   "pool_off", "interpret"))
def paged_decode_attention(q, cur_k, cur_v, pool_rows, page_rows, lengths,
                           *, chunk: int, k_off: int, v_off: int, hkv: int,
                           pool_off: int = 0, interpret: bool = False):
    """q: (B, 1, H, Dh); cur_k/cur_v: (B, 1, Hkv, Dh) (the decode token's
    fresh KV, already RoPE'd); pool_rows: (n_blocks*chunk, token_row);
    page_rows: (B, P) int32; lengths: (B,) int32.

    ``k_off`` / ``v_off`` are the layer's static column offsets inside its
    cache stack's segment (a stack's rows pack every layer's K then V
    contiguously) and ``pool_off`` is the stack's segment offset inside the
    interleaved multi-pool token row (0 for single-stack families) -- the
    kernel slices the page row at ``pool_off + k_off`` / ``pool_off +
    v_off``, so one page DMA serves every stack living in the row.
    """
    b, one, h, dh = q.shape
    g = h // hkv
    qg = q.reshape(b, hkv, g, dh)
    out = paged_decode_attention_grouped(
        qg, cur_k.reshape(b, hkv, dh), cur_v.reshape(b, hkv, dh),
        pool_rows, page_rows, lengths, scale=dh ** -0.5, chunk=chunk,
        k_off=pool_off + k_off, v_off=pool_off + v_off, interpret=interpret)
    return out.reshape(b, 1, h, dh)
