"""Fused RMSNorm Pallas TPU kernel.

Bandwidth-bound: one HBM read of x, one write -- the fp32 square/mean/rsqrt
and the weight multiply all happen in VMEM.  Rows are tiled (block_rows, D);
D stays whole per block (norm reduction axis), so VMEM per block is
block_rows * D * 4 bytes of fp32 scratch -- block_rows=8 holds D up to ~64k.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * w_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm_2d(x, w, *, eps: float = 1e-5, block_rows: int = 8,
               interpret: bool = False):
    """x: (R, D); w: (D,)."""
    r, d = x.shape
    block_rows = min(block_rows, r)
    assert r % block_rows == 0, (r, block_rows)
    grid = (r // block_rows,)
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, d), x.dtype),
        interpret=interpret,
    )(x, w)
