"""Jitted public wrapper for the RMSNorm kernel (any leading batch dims)."""
from __future__ import annotations

from functools import partial

import jax

from .kernel import rmsnorm_2d


@partial(jax.jit, static_argnames=("eps", "interpret"))
def rmsnorm(x, w, eps: float = 1e-5, interpret: bool = False):
    shape = x.shape
    r = 1
    for s in shape[:-1]:
        r *= s
    x2 = x.reshape(r, shape[-1])
    block = 8
    while r % block:
        block //= 2
    out = rmsnorm_2d(x2, w, eps=eps, block_rows=max(1, block),
                     interpret=interpret)
    return out.reshape(shape)
