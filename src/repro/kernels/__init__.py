"""Pallas TPU kernels for the framework's compute hot-spots.

Each subpackage is kernel.py (pl.pallas_call + BlockSpec VMEM tiling),
ops.py (jit'd public wrapper) and ref.py (pure-jnp oracle):
  flash_attention, decode_attention, ssd_scan, rmsnorm, tardis_lease.
All are validated in interpret mode against their oracles by
tests/test_kernels_*.py with shape/dtype sweeps.
"""
