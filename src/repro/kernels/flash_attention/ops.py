"""Public wrapper: (B, S, H, Dh) layout + head-dim padding + GQA handling."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import flash_attention_bhsd


def _pad_dh(x, mult=128):
    dh = x.shape[-1]
    pad = (-dh) % mult
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x, dh


@partial(jax.jit, static_argnames=("causal", "q_offset", "block_q",
                                   "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, q_offset: int = 0,
                    kv_len=None, block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q: (B, Sq, H, Dh); k, v: (B, Skv, Hkv, Dh) -> (B, Sq, H, Dh)."""
    dh_orig = q.shape[-1]
    scale = dh_orig ** -0.5
    q, _ = _pad_dh(q)
    k, _ = _pad_dh(k)
    v, _ = _pad_dh(v)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    kv_len_c = kt.shape[2] if kv_len is None else kv_len
    out = flash_attention_bhsd(
        qt, kt, vt, causal=causal, q_offset=q_offset, kv_len=kv_len_c,
        scale=scale, block_q=block_q, block_k=block_k, interpret=interpret)
    return out.transpose(0, 2, 1, 3)[..., :dh_orig]
