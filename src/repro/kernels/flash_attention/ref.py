"""Oracle: naive masked softmax attention (same as models.attention ref)."""
from ...models.attention import reference_attention


def flash_attention_ref(q, k, v, *, causal=True, q_offset=0, kv_len=None):
    return reference_attention(q, k, v, causal=causal, q_offset=q_offset,
                               kv_len=kv_len)
