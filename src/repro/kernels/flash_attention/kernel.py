"""Causal GQA flash-attention forward, Pallas TPU.

Grid: (batch*heads, num_q_blocks, num_kv_blocks) with the kv dimension
innermost/sequential; running (m, l, acc) live in VMEM scratch across kv
steps (the canonical TPU flash schedule).  Blocks are MXU-aligned:
block_q x head_dim and block_k x head_dim tiles with head_dim padded to a
multiple of 128 by ops.py (zero-padding is exact for both QK^T and AV).

GQA is expressed in the k/v index_map: query head h reads kv head h // group
-- no materialized kv replication in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, block_q: int, block_k: int, causal: bool,
                  q_offset: int, kv_len: int, num_kv: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                 # (bq, dh)
    k = k_ref[0].astype(jnp.float32)                 # (bk, dh)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale

    qpos = q_offset + pl.program_id(1) * block_q + \
        jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    kpos = ik * block_k + \
        jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = kpos < kv_len
    if causal:
        mask &= qpos >= kpos
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    # fully-masked-so-far rows: keep p = 0 (avoid exp(-inf + inf) = 1)
    p = jnp.where(m_new <= NEG_INF, 0.0, jnp.exp(s - m_new))
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    m_scr[...] = m_new
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())))

    @pl.when(ik == num_kv - 1)
    def _finish():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool = True, q_offset: int = 0,
                         kv_len: int | None = None, scale: float | None = None,
                         block_q: int = 128, block_k: int = 128,
                         interpret: bool = False):
    """q: (B, H, Sq, Dh); k, v: (B, Hkv, Skv, Dh).  Dh % 128 == 0."""
    b, h, sq, dh = q.shape
    _, hkv, skv, _ = k.shape
    g = h // hkv
    scale = dh ** -0.5 if scale is None else scale
    kv_len = skv if kv_len is None else kv_len
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    assert sq % block_q == 0 and skv % block_k == 0
    grid = (b * h, sq // block_q, skv // block_k)

    qs = q.reshape(b * h, sq, dh)
    ks = k.reshape(b * hkv, skv, dh)
    vs = v.reshape(b * hkv, skv, dh)

    def kv_index(bh, iq, ik):
        return ((bh // h) * hkv + (bh % h) // g, ik, 0)

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, scale=scale, block_q=block_q, block_k=block_k,
            causal=causal, q_offset=q_offset, kv_len=kv_len,
            num_kv=skv // block_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, block_k, dh), kv_index),
            pl.BlockSpec((1, block_k, dh), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh),
                               lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),     # running max
            pltpu.VMEM((block_q, 1), jnp.float32),     # running denom
            pltpu.VMEM((block_q, dh), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(qs, ks, vs)
    return out.reshape(b, h, sq, dh)
