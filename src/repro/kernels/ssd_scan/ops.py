"""Full SSD scan: Pallas intra-chunk kernel + jnp inter-chunk recurrence."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import ssd_intra_chunk


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, B, C, D, *, chunk: int = 64, interpret: bool = False):
    """Same contract as models.ssm.ssd_chunked (the oracle)."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[1] // chunk
    dtc = dt.reshape(b, nc, chunk, h).astype(jnp.float32)
    da = dtc * A
    cum = jnp.cumsum(da, axis=2)                                  # (b,nc,q,h)
    xdt = x.reshape(b, nc, chunk, h, p).astype(jnp.float32) * dtc[..., None]

    # fold (b, nc, h) into the kernel grid; B/C broadcast over heads
    Bc = jnp.broadcast_to(B.reshape(b, nc, chunk, 1, n),
                          (b, nc, chunk, h, n))
    Cc = jnp.broadcast_to(C.reshape(b, nc, chunk, 1, n),
                          (b, nc, chunk, h, n))
    def fold(a):
        return a.transpose(0, 1, 3, 2, 4).reshape(b * nc * h, chunk,
                                                  a.shape[-1])

    y_i, S = ssd_intra_chunk(
        fold(Cc), fold(Bc), fold(xdt[..., :, :]),
        cum.transpose(0, 1, 3, 2).reshape(b * nc * h, chunk, 1),
        interpret=interpret)
    y_i = y_i.reshape(b, nc, h, chunk, p).transpose(0, 1, 3, 2, 4)
    S = S.reshape(b, nc, h, n, p).transpose(0, 1, 2, 4, 3)        # (b,nc,h,p,n)

    # inter-chunk recurrence (sequential, tiny)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                       # (b,nc,h)

    def scan_fn(carry, inp):
        s_chunk, dec = inp
        out = carry * dec[:, :, None, None] + s_chunk
        return out, carry

    final, s_prev = jax.lax.scan(
        scan_fn, jnp.zeros((b, h, p, n), jnp.float32),
        (S.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    s_prev = s_prev.transpose(1, 0, 2, 3, 4)                      # (b,nc,h,p,n)

    decay_from_start = jnp.exp(cum)                               # (b,nc,q,h)
    y_x = jnp.einsum("bcqn,bcqh,bchpn->bcqhp",
                     C.reshape(b, nc, chunk, n).astype(jnp.float32),
                     decay_from_start, s_prev)
    y = (y_i + y_x).reshape(b, nc * chunk, h, p)[:, :s]
    y = y + x[:, :s].astype(jnp.float32) * D[:, None]
    return y, final
