"""Oracle: the pure-jnp chunked SSD from the model zoo."""
from ...models.ssm import ssd_chunked


def ssd_scan_ref(x, dt, A, B, C, D, *, chunk: int = 64):
    return ssd_chunked(x, dt, A, B, C, D, chunk)
