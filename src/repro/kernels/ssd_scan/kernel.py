"""Intra-chunk SSD (Mamba2) Pallas kernel.

Computes, per (batch, chunk, head) grid point, the chunk-local quadratic
term and the chunk's boundary-state contribution:

  y_intra[i] = sum_{j<=i} (C_i . B_j) * exp(cum_i - cum_j) * xdt_j
  S_chunk    = sum_j B_j^T (exp(cum_last - cum_j) * xdt_j)

Both are MXU matmuls over (Q x N)/(Q x P) tiles held in VMEM; the decay
matrix L is built in-register from the cumulative log-decay vector.  The
sequential inter-chunk recurrence (tiny (H,P,N) state updates) stays in jnp
inside ops.py -- the quadratic work is the hot spot, matching how the paper's
SSD algorithm maps onto tensor cores (here: the MXU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(c_ref, b_ref, x_ref, cum_ref, y_ref, s_ref):
    c = c_ref[0].astype(jnp.float32)          # (Q, N)
    b = b_ref[0].astype(jnp.float32)          # (Q, N)
    x = x_ref[0].astype(jnp.float32)          # (Q, P)  (already * dt)
    cum = cum_ref[0].astype(jnp.float32)      # (Q, 1)

    q = c.shape[0]
    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())))      # (Q, Q)
    seg = cum - cum.reshape(1, q)                                 # cum_i - cum_j
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    L = jnp.where(ii >= jj, jnp.exp(seg), 0.0)
    y_ref[0] = jax.lax.dot_general(
        cb * L, x, (((1,), (0,)), ((), ()))).astype(y_ref.dtype)

    decay_end = jnp.exp(cum[-1] - cum)                            # (Q, 1)
    s_ref[0] = jax.lax.dot_general(
        b, x * decay_end, (((0,), (0,)), ((), ()))).astype(s_ref.dtype)


def ssd_intra_chunk(C, B, xdt, cum, *, interpret: bool = False):
    """C, B: (G, Q, N); xdt: (G, Q, P); cum: (G, Q, 1).

    G folds (batch, chunk, head).  Returns (y_intra (G, Q, P),
    S_chunk (G, N, P)) in fp32.
    """
    g, q, n = C.shape
    p = xdt.shape[-1]
    grid = (g,)
    def spec(*shape):
        return pl.BlockSpec((1,) + shape,
                            lambda i: (i,) + (0,) * len(shape))

    return pl.pallas_call(
        _ssd_kernel,
        grid=grid,
        in_specs=[spec(q, n), spec(q, n), spec(q, p), spec(q, 1)],
        out_specs=[spec(q, p), spec(n, p)],
        out_shape=[jax.ShapeDtypeStruct((g, q, p), jnp.float32),
                   jax.ShapeDtypeStruct((g, n, p), jnp.float32)],
        interpret=interpret,
    )(C, B, xdt, cum)
