"""Oracle: the same rules straight from repro.core.protocol.

These are the differential-test references for the kernels: the masked
forms compose the scalar Table I-III rules (``lease_extend``, ``renewable``,
``shared_expired``) with the batched helpers ``batched_read_check`` /
``batched_write_advance`` exactly as the kernel does, so outputs must be
bit-identical int32.
"""
import jax.numpy as jnp

from ...core import protocol as P


def masked_lease_check_ref(wts, rts, req_wts, mask, pts, lease):
    mask = mask != 0
    # batched_read_check on the masked view: unselected blocks look like
    # expired empty lines (rts = -1) so they are neither readable nor consumed.
    readable, new_pts = P.batched_read_check(
        pts, jnp.where(mask, wts, 0), jnp.where(mask, rts, -1))
    del readable
    return {
        "new_rts": jnp.where(mask, P.lease_extend(wts, rts, pts, lease), rts),
        "renew_ok": mask & P.renewable(req_wts, wts),
        "expired": mask & P.shared_expired(pts, rts),
        "write_ts": jnp.max(jnp.where(mask, rts, -1), initial=-1) + 1,
        "new_pts": new_pts,
    }


def write_advance_ref(wts, rts, mask, pts):
    mask = mask != 0
    new_pts, w, r = P.batched_write_advance(pts, rts, mask)
    return jnp.where(mask, w, wts), jnp.where(mask, r, rts), new_pts


def lease_check_ref(wts, rts, req_wts, pts, lease):
    return masked_lease_check_ref(wts, rts, req_wts, jnp.ones_like(wts),
                                  pts, lease)
