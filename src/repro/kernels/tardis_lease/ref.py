"""Oracle: the same rules straight from repro.core.protocol.

These are the differential-test references for the kernels: the masked
forms compose the scalar Table I-III rules (``lease_extend``, ``renewable``,
``shared_expired``) with the batched helpers ``batched_read_check`` /
``batched_write_advance`` exactly as the kernel does, so outputs must be
bit-identical int32.
"""
import jax.numpy as jnp

from ...core import protocol as P


def masked_lease_check_ref(wts, rts, req_wts, mask, pts, lease):
    mask = mask != 0
    # batched_read_check on the masked view: unselected blocks look like
    # expired empty lines (rts = -1) so they are neither readable nor consumed.
    readable, new_pts = P.batched_read_check(
        pts, jnp.where(mask, wts, 0), jnp.where(mask, rts, -1))
    del readable
    return {
        "new_rts": jnp.where(mask, P.lease_extend(wts, rts, pts, lease), rts),
        "renew_ok": mask & P.renewable(req_wts, wts),
        "expired": mask & P.shared_expired(pts, rts),
        "write_ts": jnp.max(jnp.where(mask, rts, -1), initial=-1) + 1,
        "new_pts": new_pts,
    }


def masked_lease_check_many_ref(wts, rts, req_wts, masks, pts_vec, lease):
    """Oracle for the multi-row mask path: the per-group scalar rules
    composed exactly as the batched kernel does -- flags and consumed maxima
    against the pre-call table, rts extended by the union (max over groups)
    of the per-group Table III extensions."""
    masks = masks != 0
    union = jnp.any(masks, axis=0)
    new_rts = rts
    expired, renew_ok, new_pts = [], [], []
    for g in range(masks.shape[0]):
        m, pts = masks[g], pts_vec[g]
        expired.append(m & P.shared_expired(pts, rts))
        renew_ok.append(m & P.renewable(req_wts, wts))
        _, npts = P.batched_read_check(
            pts, jnp.where(m, wts, 0), jnp.where(m, rts, -1))
        new_pts.append(npts)
        new_rts = jnp.where(
            m, jnp.maximum(new_rts, P.lease_extend(wts, rts, pts, lease)),
            new_rts)
    return {
        "new_rts": new_rts,
        "renew_ok": jnp.stack(renew_ok),
        "expired": jnp.stack(expired),
        "write_ts": jnp.max(jnp.where(union, rts, -1), initial=-1) + 1,
        "new_pts": jnp.stack(new_pts),
    }


def write_advance_ref(wts, rts, mask, pts):
    mask = mask != 0
    new_pts, w, r = P.batched_write_advance(pts, rts, mask)
    return jnp.where(mask, w, wts), jnp.where(mask, r, rts), new_pts


def lease_check_ref(wts, rts, req_wts, pts, lease):
    return masked_lease_check_ref(wts, rts, req_wts, jnp.ones_like(wts),
                                  pts, lease)


def append_rows_ref(pool, idx, rows, col_lo: int = 0, width: int = None):
    """Oracle for the append-KV scatter: pool.at[idx, window].set(rows) with
    rows right-padded to the window width (last write wins on duplicates);
    ``col_lo``/``width`` select a stack's column window of an interleaved
    multi-pool token row (default: the whole row)."""
    if width is None:
        width = pool.shape[1] - col_lo
    w = rows.shape[1]
    if w != width:
        rows = jnp.pad(rows, ((0, 0), (0, width - w)))
    return pool.at[jnp.asarray(idx), col_lo:col_lo + width].set(
        rows.astype(pool.dtype))


def gather_blocks_ref(pool, idx, col_lo: int = 0, width: int = None):
    """Oracle for the paged-KV gather with a stack column window."""
    if width is None:
        width = pool.shape[1] - col_lo
    return pool[jnp.asarray(idx), col_lo:col_lo + width]
