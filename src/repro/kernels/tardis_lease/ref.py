"""Oracle: the same rules straight from repro.core.protocol."""
import jax.numpy as jnp

from ...core import protocol as P


def lease_check_ref(wts, rts, req_wts, pts, lease):
    new_rts = P.lease_extend(wts, rts, pts, lease)
    return {
        "new_rts": new_rts,
        "renew_ok": P.renewable(req_wts, wts),
        "expired": P.shared_expired(pts, rts),
        "write_ts": jnp.max(rts) + 1,
    }
