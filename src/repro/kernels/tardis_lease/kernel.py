"""Batched Tardis timestamp-manager rules as Pallas TPU kernels.

The TPU has no per-cacheline FSM, so the protocol's hot metadata path -- a
timestamp manager serving thousands of lease checks / renewals / write
jump-aheads against a block table -- becomes a lane-vectorized array program
(DESIGN.md section 2.3).  Two kernels cover Tables I-III for a (rows, 128)
block table, restricted to the blocks selected by an int32 ``mask``:

``_lease_kernel`` (load / renew / SH_REQ path), per masked block:

  * expired     = pts > rts                      (Table II, shared line check)
  * renew_ok    = req_wts == wts                 (data-less RENEW_REP)
  * new_rts     = max(rts, wts + lease, pts + lease)   (Table III, SH_REQ)
  * row max of masked rts                        (writer jump-ahead reduce)
  * row max of consumed wts (mask & ~expired)    (reader pts advance,
                                                  Table I load: pts<-max(pts,wts))

``_advance_kernel`` (store / jump-ahead path): given the writer's new
timestamp ``ts = max(pts, max(masked rts) + 1)`` computed from the lease
pass's row maxima, sets ``wts = rts = ts`` on every masked block (Table I
store rule: the new version is valid exactly from the jump-ahead instant).

``_lease_many_kernel`` is the **multi-row mask path**: a wave of G
requesters, each selecting its own subset of the table (mask row g) at its
own program timestamp ``pts_g``, resolved in ONE pass.  Per-group flags and
pts-advance operands come back stacked on a leading G axis; the rts
extension is the union over selecting groups (``max_g`` of the per-group
Table III extensions -- order-independent, so the batched result is
bit-identical to issuing the G lease passes back to back).  Flags are
evaluated against the *pre-call* table, which is the wave semantics: every
requester of the wave observes the same table snapshot.

``_gather_kernel`` is the paged-KV materialization path: scalar-prefetched
block ids drive the input index map directly (the classic paged-attention
gather), so leased KV chunks stream from the pool into a replica's cache
without a host round-trip.  Both the gather and the scatter
(``scatter_rows``) take a **column window** -- a LANES-aligned
``col_lo``/``width`` pair that becomes a second grid dimension in the
index maps -- so a multi-pool engine (one named KV pool per cache stack,
interleaved inside each token row) can stream or append a single stack's
segment without touching its neighbors' bits.

pts/lease (and ts for the advance pass) arrive via scalar prefetch so a
serving engine can stream tables through the same compiled kernels; a
Tardis 2.0 predicted (per-block) lease instead rides as one more tensor
input on the same BlockSpec as the tables -- static policies keep the
scalar path and pay nothing for the feature.
Unselected blocks pass through untouched, which is also how ragged tables
are handled: the padding lanes simply carry mask == 0.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128


def _lease_step(pts, lease, wts_ref, rts_ref, reqwts_ref, mask_ref,
                new_rts_ref, flags_ref, rowmax_rts_ref, rowmax_wts_ref):
    wts = wts_ref[...]
    rts = rts_ref[...]
    req = reqwts_ref[...]
    mask = mask_ref[...] != 0

    expired = mask & (pts > rts)
    renew_ok = mask & (req == wts)
    ext = jnp.maximum(jnp.maximum(rts, wts + lease), pts + lease)

    new_rts_ref[...] = jnp.where(mask, ext, rts)
    flags_ref[...] = (renew_ok.astype(jnp.int32)
                      | (expired.astype(jnp.int32) << 1))
    # Writer jump-ahead operand: max rts over the selected blocks (pre-extend).
    rowmax_rts_ref[...] = jnp.max(jnp.where(mask, rts, -1), axis=1,
                                  keepdims=True)
    # Reader pts advance operand: max wts over selected *readable* blocks
    # (expired blocks renew first; their wts <= rts < pts cannot raise pts).
    consumed = jnp.where(mask & (pts <= rts), wts, 0)
    rowmax_wts_ref[...] = jnp.max(consumed, axis=1, keepdims=True)


def _lease_kernel(scalars_ref, wts_ref, rts_ref, reqwts_ref, mask_ref,
                  new_rts_ref, flags_ref, rowmax_rts_ref, rowmax_wts_ref):
    # static policy: one lease value rides the scalar prefetch
    _lease_step(scalars_ref[0], scalars_ref[1], wts_ref, rts_ref, reqwts_ref,
                mask_ref, new_rts_ref, flags_ref, rowmax_rts_ref,
                rowmax_wts_ref)


def _lease_pred_kernel(scalars_ref, wts_ref, rts_ref, reqwts_ref, mask_ref,
                       lease_ref, new_rts_ref, flags_ref, rowmax_rts_ref,
                       rowmax_wts_ref):
    # Tardis 2.0 predictor: per-block leases stream as a table input
    _lease_step(scalars_ref[0], lease_ref[...], wts_ref, rts_ref, reqwts_ref,
                mask_ref, new_rts_ref, flags_ref, rowmax_rts_ref,
                rowmax_wts_ref)


def _lease_many_step(lease, pts_at, wts_ref, rts_ref, reqwts_ref, masks_ref,
                     new_rts_ref, flags_ref, rowmax_rts_ref, rowmax_wts_ref):
    wts = wts_ref[...]
    rts = rts_ref[...]
    req = reqwts_ref[...]
    n_groups = masks_ref.shape[0]

    union = jnp.zeros_like(wts)
    new_rts = rts
    for g in range(n_groups):           # static: unrolled over the wave
        pts = pts_at(g)
        mask = masks_ref[g] != 0
        expired = mask & (pts > rts)
        renew_ok = mask & (req == wts)
        ext = jnp.maximum(jnp.maximum(rts, wts + lease), pts + lease)
        new_rts = jnp.where(mask, jnp.maximum(new_rts, ext), new_rts)
        union = jnp.where(mask, 1, union)
        flags_ref[g, ...] = (renew_ok.astype(jnp.int32)
                             | (expired.astype(jnp.int32) << 1))
        consumed = jnp.where(mask & (pts <= rts), wts, 0)
        rowmax_wts_ref[g, ...] = jnp.max(consumed, axis=1, keepdims=True)
    new_rts_ref[...] = new_rts
    rowmax_rts_ref[...] = jnp.max(jnp.where(union != 0, rts, -1), axis=1,
                                  keepdims=True)


def _lease_many_kernel(scalars_ref, wts_ref, rts_ref, reqwts_ref, masks_ref,
                       new_rts_ref, flags_ref, rowmax_rts_ref,
                       rowmax_wts_ref):
    # static policy: scalars are [lease, pts_0 .. pts_{G-1}]
    _lease_many_step(scalars_ref[0], lambda g: scalars_ref[1 + g], wts_ref,
                     rts_ref, reqwts_ref, masks_ref, new_rts_ref, flags_ref,
                     rowmax_rts_ref, rowmax_wts_ref)


def _lease_many_pred_kernel(scalars_ref, wts_ref, rts_ref, reqwts_ref,
                            masks_ref, lease_ref, new_rts_ref, flags_ref,
                            rowmax_rts_ref, rowmax_wts_ref):
    # Tardis 2.0 predictor: scalars are pts_0 .. pts_{G-1}, lease is a table
    _lease_many_step(lease_ref[...], lambda g: scalars_ref[g], wts_ref,
                     rts_ref, reqwts_ref, masks_ref, new_rts_ref, flags_ref,
                     rowmax_rts_ref, rowmax_wts_ref)


def _rowmax_kernel(scalars_ref, rts_ref, mask_ref, rowmax_rts_ref):
    del scalars_ref                     # shared plumbing; no scalars needed
    rts = rts_ref[...]
    mask = mask_ref[...] != 0
    rowmax_rts_ref[...] = jnp.max(jnp.where(mask, rts, -1), axis=1,
                                  keepdims=True)


def _advance_kernel(scalars_ref, wts_ref, rts_ref, mask_ref,
                    new_wts_ref, new_rts_ref):
    ts = scalars_ref[0]
    mask = mask_ref[...] != 0
    new_wts_ref[...] = jnp.where(mask, ts, wts_ref[...])
    new_rts_ref[...] = jnp.where(mask, ts, rts_ref[...])


def _grid_call(kernel, inputs, out_lanes, block_rows, interpret, scalars):
    """Shared pallas_call plumbing for the (rows, LANES) table kernels."""
    r = inputs[0].shape[0]
    block_rows = min(block_rows, r)
    assert r % block_rows == 0
    grid = (r // block_rows,)
    spec = pl.BlockSpec((block_rows, LANES), lambda i, _s: (i, 0))
    out_specs = [
        spec if lanes == LANES
        else pl.BlockSpec((block_rows, lanes), lambda i, _s: (i, 0))
        for lanes in out_lanes]
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[spec] * len(inputs),
            out_specs=out_specs),
        out_shape=[jax.ShapeDtypeStruct((r, lanes), jnp.int32)
                   for lanes in out_lanes],
        interpret=interpret,
    )(scalars, *inputs)


def lease_table(wts, rts, req_wts, mask, pts, lease, *, block_rows: int = 8,
                interpret: bool = False):
    """wts/rts/req_wts/mask: (R, 128) int32; pts: scalar.

    ``lease`` is a scalar (static policy -- rides the scalar prefetch, no
    extra table stream) or a per-block (R, 128) tensor (the Tardis 2.0
    predicted-lease path).  Returns (new_rts (R,128), flags (R,128),
    rowmax_rts (R,1), rowmax_wts (R,1)); flags bit0 = renew_ok, bit1 =
    expired, both zero outside the mask.
    """
    assert wts.shape[1] == LANES, wts.shape
    lease = jnp.asarray(lease, jnp.int32)
    if lease.ndim == 0:
        scalars = jnp.stack([jnp.asarray(pts, jnp.int32), lease])
        return _grid_call(_lease_kernel, (wts, rts, req_wts, mask),
                          (LANES, LANES, 1, 1), block_rows, interpret,
                          scalars)
    assert lease.shape == wts.shape, (lease.shape, wts.shape)
    scalars = jnp.stack([jnp.asarray(pts, jnp.int32)])
    return _grid_call(_lease_pred_kernel, (wts, rts, req_wts, mask, lease),
                      (LANES, LANES, 1, 1), block_rows, interpret, scalars)


def rowmax_table(rts, mask, *, block_rows: int = 8,
                 interpret: bool = False):
    """max(masked rts) per row -- the writer jump-ahead operand.

    The write path needs only this reduction from the lease pass, so it
    gets a dedicated 2-input/1-output kernel instead of streaming the
    full 5-input lease kernel (whose per-block lease tensor the jump-ahead
    never reads)."""
    assert rts.shape[1] == LANES, rts.shape
    scalars = jnp.zeros((1,), jnp.int32)
    (out,) = _grid_call(_rowmax_kernel, (rts, mask), (1,),
                        block_rows, interpret, scalars)
    return out


def advance_table(wts, rts, mask, ts, *, block_rows: int = 8,
                  interpret: bool = False):
    """Set wts = rts = ts on every masked block; returns (new_wts, new_rts)."""
    assert wts.shape[1] == LANES, wts.shape
    scalars = jnp.stack([jnp.asarray(ts, jnp.int32)])
    return _grid_call(_advance_kernel, (wts, rts, mask),
                      (LANES, LANES), block_rows, interpret, scalars)


def lease_table_many(wts, rts, req_wts, masks, pts_vec, lease, *,
                     block_rows: int = 8, interpret: bool = False):
    """Multi-row mask path: one pass over G per-group masks.

    wts/rts/req_wts: (R, 128) int32; masks: (G, R, 128) int32;
    pts_vec: (G,) int32 per-group program timestamps; lease: scalar
    (static policy -- rides the scalar prefetch) or (R, 128) int32
    per-block leases (the Tardis 2.0 predicted-lease path).

    Returns (new_rts (R,128) -- union extension, flags (G,R,128) -- bit0
    renew_ok / bit1 expired per group vs the pre-call table, rowmax_rts
    (R,1) over the union mask, rowmax_wts (G,R,1) per-group consumed
    maxima for the readers' pts advance).
    """
    assert wts.shape[1] == LANES, wts.shape
    g, r = masks.shape[0], wts.shape[0]
    assert masks.shape == (g, r, LANES), masks.shape
    lease = jnp.asarray(lease, jnp.int32)
    if lease.ndim == 0:
        kernel = _lease_many_kernel
        scalars = jnp.concatenate([lease[None],
                                   jnp.asarray(pts_vec, jnp.int32)])
        tables = (wts, rts, req_wts, masks)
    else:
        assert lease.shape == wts.shape, (lease.shape, wts.shape)
        kernel = _lease_many_pred_kernel
        scalars = jnp.asarray(pts_vec, jnp.int32)
        tables = (wts, rts, req_wts, masks, lease)
    block_rows = min(block_rows, r)
    assert r % block_rows == 0
    grid = (r // block_rows,)
    spec2 = pl.BlockSpec((block_rows, LANES), lambda i, _s: (i, 0))
    spec3 = pl.BlockSpec((g, block_rows, LANES), lambda i, _s: (0, i, 0))
    in_specs = [spec2, spec2, spec2, spec3] + [spec2] * (len(tables) - 4)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=in_specs,
            out_specs=[
                spec2,                                        # new_rts
                spec3,                                        # flags
                pl.BlockSpec((block_rows, 1), lambda i, _s: (i, 0)),
                pl.BlockSpec((g, block_rows, 1), lambda i, _s: (0, i, 0)),
            ]),
        out_shape=[
            jax.ShapeDtypeStruct((r, LANES), jnp.int32),
            jax.ShapeDtypeStruct((g, r, LANES), jnp.int32),
            jax.ShapeDtypeStruct((r, 1), jnp.int32),
            jax.ShapeDtypeStruct((g, r, 1), jnp.int32),
        ],
        interpret=interpret,
    )(scalars, *tables)


def _gather_kernel(idx_ref, pool_ref, out_ref):
    del idx_ref                      # consumed by the input index map
    out_ref[...] = pool_ref[...]


def _scatter_kernel(idx_ref, rows_ref, pool_ref, out_ref):
    del idx_ref, pool_ref            # idx drives the OUTPUT index map; the
    out_ref[...] = rows_ref[...]     # pool arrives via the in/out alias


def _col_blocks(col_lo: int, width: int):
    """Column-window blocking for the pool kernels: a *pool offset* inside
    an interleaved multi-stack token row becomes an extra grid dimension.

    The window [col_lo, col_lo + width) must be LANES-aligned (the
    LeaseEngine pads every stack's token-row segment to LANES).  When the
    offset is block-aligned the whole window moves in one DMA per row
    (``n_cols == 1`` -- the single-pool fast path is unchanged bits);
    otherwise the window streams in LANES-wide column blocks addressed by
    the index map's second coordinate.
    """
    assert col_lo % LANES == 0 and width % LANES == 0, (col_lo, width)
    bw = width if col_lo % width == 0 else LANES
    return bw, width // bw, col_lo // bw


def scatter_rows(pool, idx, rows, *, col_lo: int = 0,
                 interpret: bool = False):
    """Scatter ``rows`` into ``pool[idx, col_lo:col_lo+w]``: the append-KV
    path.

    pool (N, W), idx (n,) int32, rows (n, w) with ``col_lo + w <= W``.  The
    scalar-prefetched ids drive the *output* BlockSpec's index map and the
    pool buffer is aliased input->output, so each grid step DMAs exactly
    one updated row (or LANES-wide column block of it) into place and every
    untouched row -- and every column outside the window -- keeps its bits:
    a decoded token's KV lands in its page without a host round trip, and a
    per-stack append touches only that stack's segment of the interleaved
    token row.  Rows listed twice keep the last write (the grid is
    sequential).
    """
    n, width = rows.shape
    bw, n_cols, col0 = _col_blocks(col_lo, width)
    return pl.pallas_call(
        _scatter_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n, n_cols),
            in_specs=[
                pl.BlockSpec((1, bw), lambda i, j, idx_ref: (i, j)),
                pl.BlockSpec((1, bw),
                             lambda i, j, idx_ref: (idx_ref[i], col0 + j)),
            ],
            out_specs=pl.BlockSpec(
                (1, bw), lambda i, j, idx_ref: (idx_ref[i], col0 + j))),
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        input_output_aliases={2: 0},       # (scalars, rows, POOL) -> out
        interpret=interpret,
    )(jnp.asarray(idx, jnp.int32), rows, pool)


def gather_rows(pool, idx, *, col_lo: int = 0, width: int = None,
                interpret: bool = False):
    """Gather ``pool[idx, col_lo:col_lo+width]`` on device: pool (N, W),
    idx (n,) int32.

    The scalar-prefetched ids drive the input BlockSpec's index map, so each
    grid step DMAs exactly one leased block's payload row -- the paged-KV
    materialization path of the serving engine.  ``col_lo``/``width`` name
    a LANES-aligned column window (one stack's segment of an interleaved
    multi-pool token row); the default gathers the whole row exactly as
    before.
    """
    n = idx.shape[0]
    if width is None:
        width = pool.shape[1] - col_lo
    bw, n_cols, col0 = _col_blocks(col_lo, width)
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n, n_cols),
            in_specs=[pl.BlockSpec(
                (1, bw), lambda i, j, idx_ref: (idx_ref[i], col0 + j))],
            out_specs=pl.BlockSpec((1, bw), lambda i, j, _idx: (i, j))),
        out_shape=jax.ShapeDtypeStruct((n, width), pool.dtype),
        interpret=interpret,
    )(jnp.asarray(idx, jnp.int32), pool)
