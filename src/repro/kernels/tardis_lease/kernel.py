"""Batched Tardis timestamp-manager rules as a Pallas TPU kernel.

The TPU has no per-cacheline FSM, so the protocol's hot metadata path -- a
timestamp manager serving thousands of lease checks / renewals / write
jump-aheads against a block table -- becomes a lane-vectorized array program
(DESIGN.md section 2.3).  One kernel pass over a (rows, 128) block table
evaluates, per block:

  * expired     = pts > rts                      (Table II, shared line check)
  * renew_ok    = req_wts == wts                 (data-less RENEW_REP)
  * new_rts     = max(rts, wts + lease, pts + lease)   (Table III, SH_REQ)
  * row max of rts                               (writer jump-ahead reduce)

pts/lease arrive via scalar prefetch so a serving engine can stream tables
through the same compiled kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128


def _lease_kernel(scalars_ref, wts_ref, rts_ref, reqwts_ref,
                  new_rts_ref, flags_ref, rowmax_ref):
    pts = scalars_ref[0]
    lease = scalars_ref[1]
    wts = wts_ref[...]
    rts = rts_ref[...]
    req = reqwts_ref[...]

    expired = (pts > rts).astype(jnp.int32)
    renew_ok = (req == wts).astype(jnp.int32)
    new_rts = jnp.maximum(jnp.maximum(rts, wts + lease), pts + lease)

    new_rts_ref[...] = new_rts
    flags_ref[...] = renew_ok | (expired << 1)
    rowmax_ref[...] = jnp.max(rts, axis=1, keepdims=True)


def lease_table(wts, rts, req_wts, pts, lease, *, block_rows: int = 8,
                interpret: bool = False):
    """wts/rts/req_wts: (R, 128) int32; pts, lease: scalars.

    Returns (new_rts (R,128), flags (R,128), row_max (R,1)).
    """
    r, lanes = wts.shape
    assert lanes == LANES, lanes
    block_rows = min(block_rows, r)
    assert r % block_rows == 0
    grid = (r // block_rows,)
    spec = pl.BlockSpec((block_rows, LANES), lambda i, _s: (i, 0))
    scalars = jnp.stack([jnp.asarray(pts, jnp.int32),
                         jnp.asarray(lease, jnp.int32)])
    return pl.pallas_call(
        _lease_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[spec, spec, spec],
            out_specs=[spec, spec,
                       pl.BlockSpec((block_rows, 1), lambda i, _s: (i, 0))]),
        out_shape=[jax.ShapeDtypeStruct((r, LANES), jnp.int32),
                   jax.ShapeDtypeStruct((r, LANES), jnp.int32),
                   jax.ShapeDtypeStruct((r, 1), jnp.int32)],
        interpret=interpret,
    )(scalars, wts, rts, req_wts)
