"""Batched Tardis timestamp-manager rules as Pallas TPU kernels.

The TPU has no per-cacheline FSM, so the protocol's hot metadata path -- a
timestamp manager serving thousands of lease checks / renewals / write
jump-aheads against a block table -- becomes a lane-vectorized array program
(DESIGN.md section 2.3).  Two kernels cover Tables I-III for a (rows, 128)
block table, restricted to the blocks selected by an int32 ``mask``:

``_lease_kernel`` (load / renew / SH_REQ path), per masked block:

  * expired     = pts > rts                      (Table II, shared line check)
  * renew_ok    = req_wts == wts                 (data-less RENEW_REP)
  * new_rts     = max(rts, wts + lease, pts + lease)   (Table III, SH_REQ)
  * row max of masked rts                        (writer jump-ahead reduce)
  * row max of consumed wts (mask & ~expired)    (reader pts advance,
                                                  Table I load: pts<-max(pts,wts))

``_advance_kernel`` (store / jump-ahead path): given the writer's new
timestamp ``ts = max(pts, max(masked rts) + 1)`` computed from the lease
pass's row maxima, sets ``wts = rts = ts`` on every masked block (Table I
store rule: the new version is valid exactly from the jump-ahead instant).

pts/lease (and ts for the advance pass) arrive via scalar prefetch so a
serving engine can stream tables through the same compiled kernels.
Unselected blocks pass through untouched, which is also how ragged tables
are handled: the padding lanes simply carry mask == 0.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128


def _lease_kernel(scalars_ref, wts_ref, rts_ref, reqwts_ref, mask_ref,
                  new_rts_ref, flags_ref, rowmax_rts_ref, rowmax_wts_ref):
    pts = scalars_ref[0]
    lease = scalars_ref[1]
    wts = wts_ref[...]
    rts = rts_ref[...]
    req = reqwts_ref[...]
    mask = mask_ref[...] != 0

    expired = mask & (pts > rts)
    renew_ok = mask & (req == wts)
    ext = jnp.maximum(jnp.maximum(rts, wts + lease), pts + lease)

    new_rts_ref[...] = jnp.where(mask, ext, rts)
    flags_ref[...] = (renew_ok.astype(jnp.int32)
                      | (expired.astype(jnp.int32) << 1))
    # Writer jump-ahead operand: max rts over the selected blocks (pre-extend).
    rowmax_rts_ref[...] = jnp.max(jnp.where(mask, rts, -1), axis=1,
                                  keepdims=True)
    # Reader pts advance operand: max wts over selected *readable* blocks
    # (expired blocks renew first; their wts <= rts < pts cannot raise pts).
    consumed = jnp.where(mask & (pts <= rts), wts, 0)
    rowmax_wts_ref[...] = jnp.max(consumed, axis=1, keepdims=True)


def _advance_kernel(scalars_ref, wts_ref, rts_ref, mask_ref,
                    new_wts_ref, new_rts_ref):
    ts = scalars_ref[0]
    mask = mask_ref[...] != 0
    new_wts_ref[...] = jnp.where(mask, ts, wts_ref[...])
    new_rts_ref[...] = jnp.where(mask, ts, rts_ref[...])


def _grid_call(kernel, inputs, out_lanes, block_rows, interpret, scalars):
    """Shared pallas_call plumbing for the (rows, LANES) table kernels."""
    r = inputs[0].shape[0]
    block_rows = min(block_rows, r)
    assert r % block_rows == 0
    grid = (r // block_rows,)
    spec = pl.BlockSpec((block_rows, LANES), lambda i, _s: (i, 0))
    out_specs = [
        spec if lanes == LANES
        else pl.BlockSpec((block_rows, lanes), lambda i, _s: (i, 0))
        for lanes in out_lanes]
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[spec] * len(inputs),
            out_specs=out_specs),
        out_shape=[jax.ShapeDtypeStruct((r, lanes), jnp.int32)
                   for lanes in out_lanes],
        interpret=interpret,
    )(scalars, *inputs)


def lease_table(wts, rts, req_wts, mask, pts, lease, *, block_rows: int = 8,
                interpret: bool = False):
    """wts/rts/req_wts/mask: (R, 128) int32; pts, lease: scalars.

    Returns (new_rts (R,128), flags (R,128), rowmax_rts (R,1),
    rowmax_wts (R,1)); flags bit0 = renew_ok, bit1 = expired, both zero
    outside the mask.
    """
    assert wts.shape[1] == LANES, wts.shape
    scalars = jnp.stack([jnp.asarray(pts, jnp.int32),
                         jnp.asarray(lease, jnp.int32)])
    return _grid_call(_lease_kernel, (wts, rts, req_wts, mask),
                      (LANES, LANES, 1, 1), block_rows, interpret, scalars)


def advance_table(wts, rts, mask, ts, *, block_rows: int = 8,
                  interpret: bool = False):
    """Set wts = rts = ts on every masked block; returns (new_wts, new_rts)."""
    assert wts.shape[1] == LANES, wts.shape
    scalars = jnp.stack([jnp.asarray(ts, jnp.int32)])
    return _grid_call(_advance_kernel, (wts, rts, mask),
                      (LANES, LANES), block_rows, interpret, scalars)
