"""Public wrappers: flat block tables of any size, masked ops, jump-ahead.

``masked_lease_check`` / ``write_advance`` are the two transitions the
:class:`repro.core.lease_engine.LeaseEngine` executes on device;
``lease_check`` is the whole-table convenience form (mask = all blocks).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import LANES, advance_table, lease_table


def _pad2d(x, pad, fill=0):
    return jnp.pad(x, (0, pad), constant_values=fill).reshape(-1, LANES)


def _block_rows(rows: int) -> int:
    block = 8
    while rows % block:
        block //= 2
    return max(1, block)


@partial(jax.jit, static_argnames=("interpret",))
def masked_lease_check(wts, rts, req_wts, mask, pts, lease,
                       interpret: bool = False):
    """Lease-check / renew / extend the blocks selected by ``mask``.

    wts/rts/req_wts/mask: flat (N,) int32 tables.  Returns dict with
    per-block ``new_rts`` (extended only where masked), ``renew_ok`` /
    ``expired`` flags (False outside the mask), the writer's jump-ahead
    operand ``write_ts`` = max(masked rts) + 1, and the reader's program
    timestamp after consuming every masked readable block, ``new_pts``.
    """
    n = wts.shape[0]
    pad = (-n) % LANES
    wts2 = _pad2d(wts, pad)
    rts2 = _pad2d(rts, pad)
    req2 = _pad2d(req_wts, pad)
    mask2 = _pad2d(mask, pad)          # padding lanes carry mask == 0
    new_rts, flags, rowmax_rts, rowmax_wts = lease_table(
        wts2, rts2, req2, mask2, pts, lease,
        block_rows=_block_rows(wts2.shape[0]), interpret=interpret)
    return {
        "new_rts": new_rts.reshape(-1)[:n],
        "renew_ok": (flags.reshape(-1)[:n] & 1).astype(bool),
        "expired": ((flags.reshape(-1)[:n] >> 1) & 1).astype(bool),
        "write_ts": jnp.max(rowmax_rts) + 1,
        "new_pts": jnp.maximum(jnp.asarray(pts, jnp.int32),
                               jnp.max(rowmax_wts)),
    }


@partial(jax.jit, static_argnames=("interpret",))
def write_advance(wts, rts, mask, pts, interpret: bool = False):
    """Writer jump-ahead over the blocks selected by ``mask``.

    Two kernel passes: the lease kernel reduces max(masked rts) per row,
    then the advance kernel sets ``wts = rts = ts`` on every masked block
    with ``ts = max(pts, max(masked rts) + 1)`` (Table I store rule).
    Returns (new_wts, new_rts, ts), all int32.
    """
    n = wts.shape[0]
    pad = (-n) % LANES
    wts2 = _pad2d(wts, pad)
    rts2 = _pad2d(rts, pad)
    mask2 = _pad2d(mask, pad)
    rows = _block_rows(wts2.shape[0])
    _, _, rowmax_rts, _ = lease_table(
        wts2, rts2, wts2, mask2, 0, 0, block_rows=rows, interpret=interpret)
    ts = jnp.maximum(jnp.asarray(pts, jnp.int32), jnp.max(rowmax_rts) + 1)
    new_wts, new_rts = advance_table(wts2, rts2, mask2, ts, block_rows=rows,
                                     interpret=interpret)
    return new_wts.reshape(-1)[:n], new_rts.reshape(-1)[:n], ts


@partial(jax.jit, static_argnames=("interpret",))
def lease_check(wts, rts, req_wts, pts, lease, interpret: bool = False):
    """Whole-table form: every block selected (mask of ones)."""
    mask = jnp.ones_like(wts)
    return masked_lease_check(wts, rts, req_wts, mask, pts, lease,
                              interpret=interpret)
