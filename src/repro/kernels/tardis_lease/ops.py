"""Public wrapper: flat block tables of any size + writer jump-ahead."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import LANES, lease_table


@partial(jax.jit, static_argnames=("interpret",))
def lease_check(wts, rts, req_wts, pts, lease, interpret: bool = False):
    """wts/rts/req_wts: flat (N,) int32 block tables.

    Returns dict with per-block new_rts / expired / renew_ok and the
    writer's jump-ahead timestamp max(rts)+1 over the whole table.
    """
    n = wts.shape[0]
    pad = (-n) % LANES
    wts2 = jnp.pad(wts, (0, pad)).reshape(-1, LANES)
    rts2 = jnp.pad(rts, (0, pad), constant_values=-1).reshape(-1, LANES)
    req2 = jnp.pad(req_wts, (0, pad)).reshape(-1, LANES)
    rows = wts2.shape[0]
    block = 8
    while rows % block:
        block //= 2
    new_rts, flags, rowmax = lease_table(
        wts2, rts2, req2, pts, lease, block_rows=max(1, block),
        interpret=interpret)
    return {
        "new_rts": new_rts.reshape(-1)[:n],
        "renew_ok": (flags.reshape(-1)[:n] & 1).astype(bool),
        "expired": ((flags.reshape(-1)[:n] >> 1) & 1).astype(bool),
        "write_ts": jnp.max(rowmax) + 1,
    }
