"""Public wrappers: flat block tables of any size, masked ops, jump-ahead.

``masked_lease_check`` / ``write_advance`` are the two transitions the
:class:`repro.core.lease_engine.LeaseEngine` executes on device;
``lease_check`` is the whole-table convenience form (mask = all blocks).
``masked_lease_check_many`` is the per-wave batched form (G mask rows, one
kernel pass) and ``gather_blocks`` materializes paged-KV pool rows for a
set of leased block ids.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import (LANES, advance_table, gather_rows, lease_table,
                     lease_table_many, rowmax_table, scatter_rows)


def _pad2d(x, pad, fill=0):
    return jnp.pad(x, (0, pad), constant_values=fill).reshape(-1, LANES)


def _block_rows(rows: int) -> int:
    block = 8
    while rows % block:
        block //= 2
    return max(1, block)


@partial(jax.jit, static_argnames=("interpret",))
def masked_lease_check(wts, rts, req_wts, mask, pts, lease,
                       interpret: bool = False):
    """Lease-check / renew / extend the blocks selected by ``mask``.

    wts/rts/req_wts/mask: flat (N,) int32 tables; ``lease`` is a scalar or
    a per-block (N,) vector (the Tardis 2.0 predicted-lease path).  Returns
    dict with per-block ``new_rts`` (extended only where masked),
    ``renew_ok`` / ``expired`` flags (False outside the mask), the writer's
    jump-ahead operand ``write_ts`` = max(masked rts) + 1, and the reader's
    program timestamp after consuming every masked readable block,
    ``new_pts``.
    """
    n = wts.shape[0]
    pad = (-n) % LANES
    wts2 = _pad2d(wts, pad)
    rts2 = _pad2d(rts, pad)
    req2 = _pad2d(req_wts, pad)
    mask2 = _pad2d(mask, pad)          # padding lanes carry mask == 0
    lease2 = jnp.asarray(lease, jnp.int32)
    if lease2.ndim:                    # per-block predicted leases
        lease2 = _pad2d(lease2, pad)
    new_rts, flags, rowmax_rts, rowmax_wts = lease_table(
        wts2, rts2, req2, mask2, pts, lease2,
        block_rows=_block_rows(wts2.shape[0]), interpret=interpret)
    return {
        "new_rts": new_rts.reshape(-1)[:n],
        "renew_ok": (flags.reshape(-1)[:n] & 1).astype(bool),
        "expired": ((flags.reshape(-1)[:n] >> 1) & 1).astype(bool),
        "write_ts": jnp.max(rowmax_rts) + 1,
        "new_pts": jnp.maximum(jnp.asarray(pts, jnp.int32),
                               jnp.max(rowmax_wts)),
    }


@partial(jax.jit, static_argnames=("interpret",))
def masked_lease_check_many(wts, rts, req_wts, masks, pts_vec, lease,
                            interpret: bool = False):
    """Per-wave batched lease check: G mask rows resolved in one pass.

    wts/rts/req_wts: flat (N,) int32 tables; masks: (G, N) int32 -- one row
    per requester of the wave; pts_vec: (G,) int32 program timestamps;
    ``lease``: scalar or per-block (N,) vector.
    Returns per-block ``new_rts`` (the union of the per-group Table III
    extensions), per-group ``renew_ok`` / ``expired`` flags (G, N) evaluated
    against the pre-call table (the wave's shared snapshot), the writer's
    jump-ahead operand ``write_ts`` over the union mask, and per-group
    reader timestamps ``new_pts`` (G,).
    """
    n = wts.shape[0]
    g = masks.shape[0]
    pad = (-n) % LANES
    wts2 = _pad2d(wts, pad)
    rts2 = _pad2d(rts, pad)
    req2 = _pad2d(req_wts, pad)
    masks2 = jnp.pad(masks, ((0, 0), (0, pad))).reshape(g, -1, LANES)
    lease2 = jnp.asarray(lease, jnp.int32)
    if lease2.ndim:                    # per-block predicted leases
        lease2 = _pad2d(lease2, pad)
    new_rts, flags, rowmax_rts, rowmax_wts = lease_table_many(
        wts2, rts2, req2, masks2, pts_vec, lease2,
        block_rows=_block_rows(wts2.shape[0]), interpret=interpret)
    flags_flat = flags.reshape(g, -1)[:, :n]
    return {
        "new_rts": new_rts.reshape(-1)[:n],
        "renew_ok": (flags_flat & 1).astype(bool),
        "expired": ((flags_flat >> 1) & 1).astype(bool),
        "write_ts": jnp.max(rowmax_rts) + 1,
        "new_pts": jnp.maximum(jnp.asarray(pts_vec, jnp.int32),
                               jnp.max(rowmax_wts, axis=(1, 2))),
    }


@partial(jax.jit, static_argnames=("interpret",))
def write_advance(wts, rts, mask, pts, interpret: bool = False):
    """Writer jump-ahead over the blocks selected by ``mask``.

    Two kernel passes: the rowmax kernel reduces max(masked rts) per row,
    then the advance kernel sets ``wts = rts = ts`` on every masked block
    with ``ts = max(pts, max(masked rts) + 1)`` (Table I store rule).
    Returns (new_wts, new_rts, ts), all int32.
    """
    n = wts.shape[0]
    pad = (-n) % LANES
    wts2 = _pad2d(wts, pad)
    rts2 = _pad2d(rts, pad)
    mask2 = _pad2d(mask, pad)
    rows = _block_rows(wts2.shape[0])
    rowmax_rts = rowmax_table(rts2, mask2, block_rows=rows,
                              interpret=interpret)
    ts = jnp.maximum(jnp.asarray(pts, jnp.int32), jnp.max(rowmax_rts) + 1)
    new_wts, new_rts = advance_table(wts2, rts2, mask2, ts, block_rows=rows,
                                     interpret=interpret)
    return new_wts.reshape(-1)[:n], new_rts.reshape(-1)[:n], ts


@partial(jax.jit, static_argnames=("interpret",))
def lease_check(wts, rts, req_wts, pts, lease, interpret: bool = False):
    """Whole-table form: every block selected (mask of ones)."""
    mask = jnp.ones_like(wts)
    return masked_lease_check(wts, rts, req_wts, mask, pts, lease,
                              interpret=interpret)


@partial(jax.jit, static_argnames=("col_lo", "width", "interpret"))
def gather_blocks(pool, idx, col_lo: int = 0, width: int = None,
                  interpret: bool = False):
    """Materialize pool rows for leased block ids: pool (N, W), idx (n,).

    ``col_lo``/``width`` select one named stack's LANES-aligned column
    window of an interleaved multi-pool token row (default: the whole row).
    """
    return gather_rows(pool, idx, col_lo=col_lo, width=width,
                       interpret=interpret)


@partial(jax.jit, static_argnames=("col_lo", "width", "interpret"),
         donate_argnums=(0,))
def append_rows(pool, idx, rows, col_lo: int = 0, width: int = None,
                interpret: bool = False):
    """Scatter updated rows into ``pool[idx, col_lo:...]`` device-side (the
    append-KV path).

    pool (N, W); idx (n,) int32; rows (n, w) right-padded with zeros to
    ``width`` (default: the pool's full row width).  ``col_lo`` places the
    window at a stack's segment of an interleaved multi-pool token row --
    columns outside [col_lo, col_lo + width) keep their bits.  Returns the
    updated pool; the input pool buffer is donated/aliased so no full-pool
    copy happens on TPU.
    """
    if width is None:
        width = pool.shape[1] - col_lo
    w = rows.shape[1]
    if w != width:
        rows = jnp.pad(rows, ((0, 0), (0, width - w)))
    return scatter_rows(pool, idx, rows.astype(pool.dtype), col_lo=col_lo,
                        interpret=interpret)
