"""Sharded, versioned, atomic checkpointing with elastic restore.

Layout:  <dir>/step_<N>/
             manifest.json    {step, wts (Tardis version), tree structure,
                               leaf shapes/dtypes, shard map}
             shard_<i>.npz    leaf arrays (chunked across files)
         <dir>/LATEST         atomic pointer (written via rename)

Restore can target a *different* mesh than the save (elastic scaling): leaves
are loaded on host and re-placed with the target sharding via
``jax.device_put`` -- the resharding path a 1000-node deployment needs after
losing or gaining pods.  The manifest carries the parameter version as a
Tardis ``wts``; the elastic runtime publishes restored params at that logical
time so stale workers renew instead of re-broadcasting.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

_MAX_SHARD_BYTES = 512 << 20


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str, step: int, tree, *, wts: int = 0,
         keep: int = 3) -> str:
    """Write one checkpoint atomically; returns the checkpoint path."""
    leaves, treedef = _flatten(tree)
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    manifest: Dict[str, Any] = {
        "step": int(step), "wts": int(wts),
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "leaves": [], "shards": [],
    }
    shard, shard_bytes, shard_id = {}, 0, 0

    def flush():
        nonlocal shard, shard_bytes, shard_id
        if shard:
            np.savez(os.path.join(tmp, f"shard_{shard_id}.npz"), **shard)
            manifest["shards"].append(f"shard_{shard_id}.npz")
            shard, shard_bytes = {}, 0
            shard_id += 1

    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        manifest["leaves"].append(
            {"idx": i, "shape": list(arr.shape), "dtype": str(arr.dtype),
             "shard": shard_id})
        shard[f"leaf_{i}"] = arr
        shard_bytes += arr.nbytes
        if shard_bytes >= _MAX_SHARD_BYTES:
            flush()
    flush()
    json.dump(manifest, open(os.path.join(tmp, "manifest.json"), "w"))

    final = os.path.join(ckpt_dir, f"step_{step}")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                           # atomic publish
    _write_latest(ckpt_dir, f"step_{step}")
    _gc(ckpt_dir, keep)
    return final


def _write_latest(ckpt_dir: str, name: str):
    tmp = os.path.join(ckpt_dir, ".LATEST_tmp")
    with open(tmp, "w") as f:
        f.write(name)
    os.rename(tmp, os.path.join(ckpt_dir, "LATEST"))


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(
        (int(d.split("_")[1]), d) for d in os.listdir(ckpt_dir)
        if d.startswith("step_"))
    for _, d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    path = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(path):
        return None
    name = open(path).read().strip()
    if not os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
        return None
    return int(name.split("_")[1])


def _norm_index(idx, shape) -> Tuple[Tuple[int, int], ...]:
    """An addressable shard's ``.index`` as concrete (start, stop) pairs."""
    out = []
    for d, sl in enumerate(idx):
        a = 0 if sl.start is None else int(sl.start)
        b = shape[d] if sl.stop is None else int(sl.stop)
        out.append((a, b))
    return tuple(out)


def save_sharded(ckpt_dir: str, step: int, tree, *, wts: int = 0,
                 keep: int = 3) -> str:
    """Write one checkpoint **without gathering**: each leaf is saved as
    the pieces its NamedSharding already splits it into (one piece per
    distinct ``addressable_shards`` index -- replicas dedupe), each tagged
    with its (start, stop) box in the global shape.  A 1T-param tree never
    materializes on one host; :func:`restore_sharded` reassembles exactly
    the boxes each target device needs, so a restore onto a *different*
    mesh shape streams pieces instead of resharding a full copy."""
    leaves, treedef = _flatten(tree)
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    manifest: Dict[str, Any] = {
        "step": int(step), "wts": int(wts), "sharded": True,
        "treedef": str(treedef), "n_leaves": len(leaves),
        "leaves": [], "shards": [],
    }
    shard, shard_bytes, shard_id = {}, 0, 0

    def flush():
        nonlocal shard, shard_bytes, shard_id
        if shard:
            np.savez(os.path.join(tmp, f"shard_{shard_id}.npz"), **shard)
            manifest["shards"].append(f"shard_{shard_id}.npz")
            shard, shard_bytes = {}, 0
            shard_id += 1

    for i, leaf in enumerate(leaves):
        ashards = getattr(leaf, "addressable_shards", None)
        shape = tuple(getattr(leaf, "shape", np.shape(leaf)))
        pieces, seen = [], set()
        if ashards:
            for s in ashards:
                box = _norm_index(s.index, shape)
                if box in seen:
                    continue                    # replicated copy
                seen.add(box)
                pieces.append((box, np.asarray(jax.device_get(s.data))))
        else:
            box = tuple((0, d) for d in shape)
            pieces.append((box, np.asarray(jax.device_get(leaf))))
        entry = {"idx": i, "shape": list(shape),
                 "dtype": str(pieces[0][1].dtype), "pieces": []}
        for j, (box, arr) in enumerate(pieces):
            key = f"leaf_{i}_p{j}"
            entry["pieces"].append(
                {"key": key, "shard": shard_id,
                 "start": [a for a, _ in box], "stop": [b for _, b in box]})
            shard[key] = arr
            shard_bytes += arr.nbytes
            if shard_bytes >= _MAX_SHARD_BYTES:
                flush()
        manifest["leaves"].append(entry)
    flush()
    json.dump(manifest, open(os.path.join(tmp, "manifest.json"), "w"))

    final = os.path.join(ckpt_dir, f"step_{step}")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                           # atomic publish
    _write_latest(ckpt_dir, f"step_{step}")
    _gc(ckpt_dir, keep)
    return final


def _read_box(entry, shards, idx):
    """Assemble the slice ``idx`` of one leaf from its saved pieces."""
    shape = tuple(entry["shape"])
    want = _norm_index(idx, shape)
    out = np.empty([b - a for a, b in want], entry["dtype"])
    filled = 0
    for p in entry["pieces"]:
        box = list(zip(p["start"], p["stop"]))
        inter = [(max(a, pa), min(b, pb))
                 for (a, b), (pa, pb) in zip(want, box)]
        if any(x >= y for x, y in inter):
            continue                                # piece outside the box
        data = shards[p["key"]]
        src = tuple(slice(x - pa, y - pa)
                    for (x, y), (pa, _) in zip(inter, box))
        dst = tuple(slice(x - a, y - a)
                    for (x, y), (a, _) in zip(inter, want))
        out[dst] = data[src]
        filled += int(np.prod([y - x for x, y in inter]))
    assert filled == out.size, \
        f"leaf {entry['idx']}: pieces cover {filled}/{out.size} of {want}"
    return out


def restore_sharded(ckpt_dir: str, tree_like, *,
                    step: Optional[int] = None,
                    shardings=None) -> Tuple[Any, Dict[str, Any]]:
    """Restore a :func:`save_sharded` checkpoint piece-by-piece.

    With ``shardings`` (a matching pytree of NamedShardings for the
    *target* mesh), every leaf is built through
    ``jax.make_array_from_callback``: each target device asks for exactly
    its box and the callback stitches it from whichever saved pieces
    overlap -- no full-size host copy, and the saved mesh shape never has
    to match the target's (elastic restore).  Without ``shardings``,
    leaves assemble to full host arrays."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step}")
    manifest = json.load(open(os.path.join(path, "manifest.json")))
    if not manifest.get("sharded"):
        raise ValueError(f"{path} was written by save(), not save_sharded()")
    shards = {}
    for s in manifest["shards"]:
        shards.update(np.load(os.path.join(path, s)))
    leaves_like, treedef = _flatten(tree_like)
    assert len(leaves_like) == manifest["n_leaves"], \
        f"tree mismatch: {len(leaves_like)} vs {manifest['n_leaves']}"
    sh_leaves = (jax.tree.flatten(shardings)[0] if shardings is not None
                 else [None] * len(leaves_like))
    out = []
    for like, sh, entry in zip(leaves_like, sh_leaves, manifest["leaves"]):
        shape = tuple(entry["shape"])
        expect = tuple(getattr(like, "shape", shape))
        assert shape == expect, (entry["idx"], shape, expect)
        if sh is not None:
            out.append(jax.make_array_from_callback(
                shape, sh, lambda idx, e=entry: _read_box(e, shards, idx)))
        else:
            full = (slice(None),) * len(shape)
            out.append(jax.numpy.asarray(_read_box(entry, shards, full)))
    return jax.tree.unflatten(treedef, out), manifest


def restore(ckpt_dir: str, tree_like, *, step: Optional[int] = None,
            shardings=None) -> Tuple[Any, Dict[str, Any]]:
    """Load a checkpoint into the structure of ``tree_like``.

    ``shardings``: optional matching pytree of NamedShardings for the target
    mesh (elastic restore) -- leaves are device_put with them.
    Returns (tree, manifest).
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step}")
    manifest = json.load(open(os.path.join(path, "manifest.json")))
    shards = {}
    for s in manifest["shards"]:
        shards.update(np.load(os.path.join(path, s)))
    leaves_like, treedef = _flatten(tree_like)
    assert len(leaves_like) == manifest["n_leaves"], \
        f"tree mismatch: {len(leaves_like)} vs {manifest['n_leaves']}"
    sh_leaves = (jax.tree.flatten(shardings)[0] if shardings is not None
                 else [None] * len(leaves_like))
    out = []
    for i, (like, sh) in enumerate(zip(leaves_like, sh_leaves)):
        arr = shards[f"leaf_{i}"]
        expect = tuple(getattr(like, "shape", arr.shape))
        assert tuple(arr.shape) == expect, (i, arr.shape, expect)
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree.unflatten(treedef, out), manifest
