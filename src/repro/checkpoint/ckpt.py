"""Sharded, versioned, atomic checkpointing with elastic restore.

Layout:  <dir>/step_<N>/
             manifest.json    {step, wts (Tardis version), tree structure,
                               leaf shapes/dtypes, shard map}
             shard_<i>.npz    leaf arrays (chunked across files)
         <dir>/LATEST         atomic pointer (written via rename)

Restore can target a *different* mesh than the save (elastic scaling): leaves
are loaded on host and re-placed with the target sharding via
``jax.device_put`` -- the resharding path a 1000-node deployment needs after
losing or gaining pods.  The manifest carries the parameter version as a
Tardis ``wts``; the elastic runtime publishes restored params at that logical
time so stale workers renew instead of re-broadcasting.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

_MAX_SHARD_BYTES = 512 << 20


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str, step: int, tree, *, wts: int = 0,
         keep: int = 3) -> str:
    """Write one checkpoint atomically; returns the checkpoint path."""
    leaves, treedef = _flatten(tree)
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    manifest: Dict[str, Any] = {
        "step": int(step), "wts": int(wts),
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "leaves": [], "shards": [],
    }
    shard, shard_bytes, shard_id = {}, 0, 0

    def flush():
        nonlocal shard, shard_bytes, shard_id
        if shard:
            np.savez(os.path.join(tmp, f"shard_{shard_id}.npz"), **shard)
            manifest["shards"].append(f"shard_{shard_id}.npz")
            shard, shard_bytes = {}, 0
            shard_id += 1

    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        manifest["leaves"].append(
            {"idx": i, "shape": list(arr.shape), "dtype": str(arr.dtype),
             "shard": shard_id})
        shard[f"leaf_{i}"] = arr
        shard_bytes += arr.nbytes
        if shard_bytes >= _MAX_SHARD_BYTES:
            flush()
    flush()
    json.dump(manifest, open(os.path.join(tmp, "manifest.json"), "w"))

    final = os.path.join(ckpt_dir, f"step_{step}")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                           # atomic publish
    _write_latest(ckpt_dir, f"step_{step}")
    _gc(ckpt_dir, keep)
    return final


def _write_latest(ckpt_dir: str, name: str):
    tmp = os.path.join(ckpt_dir, ".LATEST_tmp")
    with open(tmp, "w") as f:
        f.write(name)
    os.rename(tmp, os.path.join(ckpt_dir, "LATEST"))


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(
        (int(d.split("_")[1]), d) for d in os.listdir(ckpt_dir)
        if d.startswith("step_"))
    for _, d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    path = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(path):
        return None
    name = open(path).read().strip()
    if not os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
        return None
    return int(name.split("_")[1])


def restore(ckpt_dir: str, tree_like, *, step: Optional[int] = None,
            shardings=None) -> Tuple[Any, Dict[str, Any]]:
    """Load a checkpoint into the structure of ``tree_like``.

    ``shardings``: optional matching pytree of NamedShardings for the target
    mesh (elastic restore) -- leaves are device_put with them.
    Returns (tree, manifest).
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step}")
    manifest = json.load(open(os.path.join(path, "manifest.json")))
    shards = {}
    for s in manifest["shards"]:
        shards.update(np.load(os.path.join(path, s)))
    leaves_like, treedef = _flatten(tree_like)
    assert len(leaves_like) == manifest["n_leaves"], \
        f"tree mismatch: {len(leaves_like)} vs {manifest['n_leaves']}"
    sh_leaves = (jax.tree.flatten(shardings)[0] if shardings is not None
                 else [None] * len(leaves_like))
    out = []
    for i, (like, sh) in enumerate(zip(leaves_like, sh_leaves)):
        arr = shards[f"leaf_{i}"]
        expect = tuple(getattr(like, "shape", arr.shape))
        assert tuple(arr.shape) == expect, (i, arr.shape, expect)
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree.unflatten(treedef, out), manifest
